"""END-TO-END DRIVER: train -> calibrate -> compress -> SAVE -> LOAD -> serve.

    PYTHONPATH=src python examples/serve_compressed.py --requests 12

The paper is an inference-efficiency method, so the end-to-end story is a
serving one — with a real artifact boundary in the middle: a trained
checkpoint goes through a registry strategy offline, the compressed model
is persisted as a durable artifact (atomic npz+meta), and the continuous-
batching engine then boots FROM THE ARTIFACT (``Engine.from_artifact``)
exactly as a separate serving process would, holding the LATENT cache
(half the resident bytes at 50% compression -> 2x the slots on the same
HBM).  Prints side-by-side dense vs compressed engine stats and verifies
greedy outputs stay consistent.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CompressionSpec, RankPolicy, calibrate, compress, \
    save_artifact
from repro.data import DataConfig, batch as data_batch, sequence
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop
from repro.serving import Engine, Request, SamplingParams


def build_model(steps: int):
    cfg = ModelConfig(
        name="serve-demo", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8, d_head=16,
        d_ff=352, vocab_size=512, dtype=jnp.float32, scan_layers=False,
        remat=False, attn_chunk=64)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, copy_frac=0.6)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in data_batch(dc, "train", step, 8).items()}
    out = train_loop(
        cfg, AdamWConfig(lr=3e-3),
        TrainConfig(warmup_steps=20, total_steps=steps,
                    ckpt_dir="experiments/serve_demo", ckpt_every=100),
        batch_fn, logger=lambda *_: None)
    return cfg, out["params"], dc


def compress_offline(cfg, params, keep: float, method: str):
    batches = [{"tokens": jnp.asarray(
        data_batch(DataConfig(vocab_size=cfg.vocab_size, seq_len=128),
                   "calib", s, 4)["tokens"]),
        "labels": jnp.full((4, 128), -1, jnp.int32)} for s in range(4)]
    calib = calibrate(cfg, params, batches, fisher=True)
    spec = CompressionSpec(
        method, rank_policy=RankPolicy(keep_ratio=keep, use_fisher=True))
    return compress(cfg, params, spec, calib)


def serve_engine(eng, prompts, new_tokens):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(eng.cache))
    outs = {r.uid: r.out_tokens for r in done}
    return {"tok_s": toks / dt, "cache_mb": cache_bytes / 2**20, "outs": outs,
            "syncs_per_tok": eng.metrics()["host_syncs_per_token"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--keep", type=float, default=0.5)
    ap.add_argument("--method", default="recalkv")
    ap.add_argument("--artifact-dir", default="experiments/serve_artifact")
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (deterministic dense-vs-compressed "
                         "agreement check)")
    args = ap.parse_args()

    print("[1/4] training the dense checkpoint ...")
    cfg, params, dc = build_model(args.train_steps)
    print(f"[2/4] offline compression ({args.method!r}, Algorithm 1) ...")
    artifact = compress_offline(cfg, params, args.keep, args.method)
    print(f"[3/4] persisting artifact to {args.artifact_dir} "
          f"(ranks {artifact.provenance['ranks_by_layer']}) ...")
    save_artifact(artifact, args.artifact_dir)

    g = np.random.default_rng(0)
    prompts = [np.asarray(sequence(dc, "valid", 50 + i)[: int(g.integers(8, 32))],
                          np.int32) for i in range(args.requests)]
    print("[4/4] serving", args.requests, "requests on both engines ...")
    sampling = SamplingParams(temperature=args.temperature)
    dense = serve_engine(
        Engine(cfg, params, max_slots=args.slots, max_len=args.max_len,
               sampling=sampling, sync_every=args.sync_every),
        prompts, args.new_tokens)
    # the compressed engine boots from disk — nothing in-memory crosses over
    comp = serve_engine(
        Engine.from_artifact(args.artifact_dir, max_slots=args.slots,
                             max_len=args.max_len, sampling=sampling,
                             sync_every=args.sync_every),
        prompts, args.new_tokens)

    agree = np.mean([
        np.mean(np.asarray(dense["outs"][i]) == np.asarray(comp["outs"][i]))
        for i in range(args.requests)])
    print(f"\ndense   : {dense['tok_s']:6.1f} tok/s  cache {dense['cache_mb']:.2f} MiB  "
          f"{dense['syncs_per_tok']:.3f} syncs/tok")
    print(f"{args.method:8s}: {comp['tok_s']:6.1f} tok/s  cache {comp['cache_mb']:.2f} MiB "
          f"({comp['cache_mb']/dense['cache_mb']:.0%} of dense)  "
          f"{comp['syncs_per_tok']:.3f} syncs/tok")
    print(f"greedy agreement vs dense: {agree:.0%}")
    print(f"artifact on disk: {os.path.abspath(args.artifact_dir)}")


if __name__ == "__main__":
    main()
