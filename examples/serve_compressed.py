"""END-TO-END DRIVER: train -> calibrate -> ReCalKV-compress -> serve.

    PYTHONPATH=src python examples/serve_compressed.py --requests 12

The paper is an inference-efficiency method, so the end-to-end story is a
serving one: a trained checkpoint goes through Algorithm 1 offline, and
the continuous-batching engine then serves batched requests from the
LATENT cache (half the resident bytes at 50% compression -> 2x the slots
on the same HBM).  Prints side-by-side dense vs compressed engine stats
and verifies greedy outputs stay consistent.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.compress as C
from repro.core import ReCalKVConfig
from repro.data import DataConfig, batch as data_batch, sequence
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop
from repro.serving import Engine, Request


def build_model(steps: int):
    cfg = ModelConfig(
        name="serve-demo", family="dense",
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8, d_head=16,
        d_ff=352, vocab_size=512, dtype=jnp.float32, scan_layers=False,
        remat=False, attn_chunk=64)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, copy_frac=0.6)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in data_batch(dc, "train", step, 8).items()}
    out = train_loop(
        cfg, AdamWConfig(lr=3e-3),
        TrainConfig(warmup_steps=20, total_steps=steps,
                    ckpt_dir="experiments/serve_demo", ckpt_every=100),
        batch_fn, logger=lambda *_: None)
    return cfg, out["params"], dc


def compress(cfg, params, keep: float):
    g_batches = [{"tokens": jnp.asarray(
        data_batch(DataConfig(vocab_size=cfg.vocab_size, seq_len=128),
                   "calib", s, 4)["tokens"]),
        "labels": jnp.full((4, 128), -1, jnp.int32)} for s in range(4)]
    stats = C.capture_calibration(cfg, params, g_batches)
    fk, fv = C.fisher_scores(cfg, params, g_batches[:2])
    return C.compress_model(cfg, params, stats,
                            ReCalKVConfig(keep_ratio=keep, group_size=4),
                            fk, fv)


def serve(cfg, params, prompts, slots, max_len, new_tokens):
    eng = Engine(cfg, params, max_slots=slots, max_len=max_len)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(eng.cache))
    outs = {r.uid: r.out_tokens for r in done}
    return {"tok_s": toks / dt, "cache_mb": cache_bytes / 2**20, "outs": outs}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=200)
    ap.add_argument("--keep", type=float, default=0.5)
    args = ap.parse_args()

    print("[1/3] training the dense checkpoint ...")
    cfg, params, dc = build_model(args.train_steps)
    print("[2/3] ReCalKV offline compression (Algorithm 1) ...")
    ccfg, cparams = compress(cfg, params, args.keep)

    g = np.random.default_rng(0)
    prompts = [np.asarray(sequence(dc, "valid", 50 + i)[: int(g.integers(8, 32))],
                          np.int32) for i in range(args.requests)]
    print("[3/3] serving", args.requests, "requests on both engines ...")
    dense = serve(cfg, params, prompts, args.slots, args.max_len,
                  args.new_tokens)
    comp = serve(ccfg, cparams, prompts, args.slots, args.max_len,
                 args.new_tokens)

    agree = np.mean([
        np.mean(np.asarray(dense["outs"][i]) == np.asarray(comp["outs"][i]))
        for i in range(args.requests)])
    print(f"\ndense   : {dense['tok_s']:6.1f} tok/s  cache {dense['cache_mb']:.2f} MiB")
    print(f"recalkv : {comp['tok_s']:6.1f} tok/s  cache {comp['cache_mb']:.2f} MiB "
          f"({comp['cache_mb']/dense['cache_mb']:.0%} of dense)")
    print(f"greedy agreement vs dense: {agree:.0%}")


if __name__ == "__main__":
    main()
