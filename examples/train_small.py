"""Train a small LM on the synthetic copy-corpus, with checkpoint/restart.

    PYTHONPATH=src python examples/train_small.py --steps 300

Exercises the full training substrate: WSD/cosine schedules, grad
accumulation, watchdog, atomic async checkpoints (kill it mid-run and
re-launch — it resumes from the last checkpoint).  The resulting
checkpoint is what examples/serve_compressed.py compresses.

``--dmodel 768 --layers 12`` reaches ~100M params for the full-size run
on real hardware; the CPU-friendly default is ~3M.
"""

import argparse

import jax.numpy as jnp

from repro.data import DataConfig, batch as data_batch
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dmodel", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="wsd")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="experiments/train_small")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-small", family="dense",
        num_layers=args.layers, d_model=args.dmodel, num_heads=args.heads,
        num_kv_heads=args.heads, d_head=args.dmodel // args.heads,
        d_ff=int(args.dmodel * 2.75), vocab_size=512, dtype=jnp.float32,
        scan_layers=False, remat=False, attn_chunk=64)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, copy_frac=0.6)
    tc = TrainConfig(
        microbatches=args.microbatches, schedule=args.schedule,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=100)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in data_batch(dc, "train", step, args.batch).items()}

    out = train_loop(cfg, AdamWConfig(lr=args.lr), tc, batch_fn)
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps (ckpts in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
