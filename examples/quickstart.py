"""Quickstart: compress a model's KV cache through repro.api in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small dense transformer, picks a strategy from the registry
(``recalkv`` = CKA->HSR grouping for keys, calibrated SVD + fused W~_o for
values), and shows the cache-size / output-fidelity trade-off plus the
durable-artifact round trip.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (CompressionSpec, RankPolicy, compress, list_strategies,
                       load_artifact, save_artifact)
from repro.configs import get_config
from repro.models import transformer as T

# 1. a dense model (any HF-style GQA/MHA checkpoint would slot in here)
cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                          dtype=jnp.float32, scan_layers=False)
params = T.init_params(cfg, jax.random.PRNGKey(0))

# 2. calibration: a handful of batches through the model, second moments only
g = np.random.default_rng(0)
batches = [{"tokens": jnp.asarray(g.integers(0, cfg.vocab_size, (4, 64))),
            "labels": jnp.full((4, 64), -1, jnp.int32)} for _ in range(4)]

# 3. pick a strategy (paper Algorithm 1) at 50% cache compression
print("registered strategies:", ", ".join(list_strategies()))
spec = CompressionSpec("recalkv",
                       rank_policy=RankPolicy(keep_ratio=0.5, group_size=2))
artifact = compress(cfg, params, spec, batches)
ccfg, cparams = artifact.cfg, artifact.params

# 4. compare: cache bytes + logit fidelity
toks = jnp.asarray(g.integers(0, cfg.vocab_size, (2, 32)))
size = lambda c: sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(T.init_decode_cache(c, 2, 64)))
l_d = T.logits_for(cfg, params, T.forward_hidden(cfg, params, toks)[0])
l_c = T.logits_for(ccfg, cparams, T.forward_hidden(ccfg, cparams, toks)[0])
agree = float(jnp.mean((jnp.argmax(l_d, -1) == jnp.argmax(l_c, -1))))

print(f"cache bytes/slot : dense {size(cfg):,} -> recalkv {size(ccfg):,} "
      f"({size(ccfg)/size(cfg):.0%})")
print(f"greedy agreement : {agree:.0%} of positions (random init — trained "
      f"checkpoints do much better, see benchmarks/table1)")

# 5. the artifact is durable: save, load in any process, decode
with tempfile.TemporaryDirectory() as d:
    save_artifact(artifact, d)
    art2 = load_artifact(d)
    print(f"artifact round-trip: method={art2.method} "
          f"ranks={art2.provenance['ranks_by_layer']}")
    logits, cache = T.prefill(art2.cfg, art2.params, toks,
                              jnp.full((2,), 32), max_len=64)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(32, 36):
        logits, cache = T.decode_step(art2.cfg, art2.params, cache, nxt,
                                      jnp.full((2,), t))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    print("decoded 4 tokens through the loaded latent cache:", np.asarray(nxt))
