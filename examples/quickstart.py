"""Quickstart: compress a model's KV cache with ReCalKV in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small dense transformer, runs Algorithm 1 (CKA->HSR grouping for
keys, calibrated SVD + fused W~_o for values), and shows the cache-size /
output-fidelity trade-off.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.compress as C
from repro.configs import get_config
from repro.core import ReCalKVConfig
from repro.models import transformer as T

# 1. a dense model (any HF-style GQA/MHA checkpoint would slot in here)
cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                          dtype=jnp.float32, scan_layers=False)
params = T.init_params(cfg, jax.random.PRNGKey(0))

# 2. calibration: a handful of batches through the model, second moments only
g = np.random.default_rng(0)
batches = [{"tokens": jnp.asarray(g.integers(0, cfg.vocab_size, (4, 64))),
            "labels": jnp.full((4, 64), -1, jnp.int32)} for _ in range(4)]
stats = C.capture_calibration(cfg, params, batches)

# 3. Algorithm 1: 50% cache compression
ccfg, cparams = C.compress_model(
    cfg, params, stats, ReCalKVConfig(keep_ratio=0.5, group_size=2))

# 4. compare: cache bytes + logit fidelity + decode
toks = jnp.asarray(g.integers(0, cfg.vocab_size, (2, 32)))
size = lambda c: sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(T.init_decode_cache(c, 2, 64)))
h_d, _ = T.forward_hidden(cfg, params, toks)
h_c, _ = T.forward_hidden(ccfg, cparams, toks)
l_d = T.logits_for(cfg, params, h_d)
l_c = T.logits_for(ccfg, cparams, h_c)
agree = float(jnp.mean((jnp.argmax(l_d, -1) == jnp.argmax(l_c, -1))))

print(f"cache bytes/slot : dense {size(cfg):,} -> recalkv {size(ccfg):,} "
      f"({size(ccfg)/size(cfg):.0%})")
print(f"greedy agreement : {agree:.0%} of positions (random init — trained "
      f"checkpoints do much better, see benchmarks/table1)")

logits, cache = T.prefill(ccfg, cparams, toks, jnp.full((2,), 32), max_len=64)
nxt = jnp.argmax(logits, -1).astype(jnp.int32)
for t in range(32, 36):
    logits, cache = T.decode_step(ccfg, cparams, cache, nxt, jnp.full((2,), t))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
print("decoded 4 tokens through the latent cache:", np.asarray(nxt))
