"""Kernel microbenchmarks (paper's efficiency figures).

Two views, because this container has no TPU:
  * WALL: XLA-path decode step timings on CPU — latent (ReCalKV) vs dense
    cache at the same model size; the ratio tracks the bytes ratio on
    bandwidth-bound hardware.
  * ANALYTIC: per-call FLOPs / HBM bytes / arithmetic intensity of each
    Pallas kernel at production shapes (what the TPU roofline sees).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.models import transformer as T


def decode_bench(arch="qwen3-4b", S=256, B=4):
    """Full decode_step timings: cache variant x attention backend.

    On CPU the pallas column runs the kernels in interpret mode (a
    correctness trace, not a speed claim — the einsum/pallas pair tracks
    the hot path's perf trajectory once a TPU runs the same rows)."""
    rows = []
    timings = {}
    variants = {"dense": ({}, {}),
                "recalkv": ({"recalkv_ratio": 0.5}, {}),
                "recalkv_int8": ({"recalkv_ratio": 0.5},
                                 {"cache_quant_bits": 8})}
    for tag, (kw, extra) in variants.items():
        for backend in ("einsum", "pallas"):
            cfg = dataclasses.replace(get_config(arch, smoke=True, **kw),
                                      dtype=jnp.float32,
                                      attn_backend=backend, **extra)
            params = T.init_params(cfg, jax.random.PRNGKey(0))
            cache = T.init_decode_cache(cfg, B, S)
            toks = jnp.zeros((B,), jnp.int32)
            cur = jnp.full((B,), S - 1, jnp.int32)
            step = jax.jit(lambda p, c, t, u: T.decode_step(cfg, p, c, t, u))
            us = common.timed(lambda: step(params, cache, toks, cur), repeats=5)
            cache_bytes = sum(l.size * l.dtype.itemsize
                              for l in jax.tree.leaves(cache))
            timings[tag, backend] = us
            rows.append({"name": f"kernel/decode_step/{tag}/{backend}",
                         "us_per_call": us,
                         "derived": f"cache_bytes={cache_bytes}"})
        rows.append({
            "name": f"kernel/decode_step/{tag}/pallas_vs_einsum_ratio",
            "us_per_call": 0,
            "derived": f"{timings[tag, 'pallas'] / timings[tag, 'einsum']:.3f}"})
    rows.append({
        "name": "kernel/decode_step/latent_vs_dense_ratio",
        "us_per_call": 0,
        "derived": (f"{timings['recalkv', 'einsum'] / timings['dense', 'einsum']:.3f}")})
    return rows


def analytic_rows():
    """Roofline terms for the latent_decode kernel at production shapes."""
    rows = []
    cases = {
        # arch-like: (B, S, G, rk, rv, s, qpk, dh)
        "danube_decode32k": (128, 4096, 2, 160, 160, 4, 4, 80),
        "qwen3moe_decode32k": (128, 32768, 1, 256, 256, 4, 16, 128),
        "gemma3_global32k": (128, 32768, 2, 512, 512, 4, 2, 256),
    }
    for name, (B, S, G, rk, rv, s, qpk, dh) in cases.items():
        Hg = s * qpk
        bytes_latent = B * S * G * (rk + rv) * 2           # the cache read
        bytes_dense = B * S * G * s * dh * 2 * 2           # dense k+v read
        flops_recon = 2 * B * S * G * rk * s * dh          # zk @ R_k
        flops_attn = 2 * B * S * G * Hg * dh + 2 * B * S * G * Hg * rv
        flops = flops_recon + flops_attn
        t_mem = bytes_latent / 819e9
        t_cmp = flops / 197e12
        ai = flops / bytes_latent
        rows.append({
            "name": f"kernel/latent_decode/{name}",
            "us_per_call": t_mem * 1e6 if t_mem > t_cmp else t_cmp * 1e6,
            "derived": (f"ai={ai:.0f}flops/B bytes_vs_dense="
                        f"{bytes_latent/bytes_dense:.2f} "
                        f"bound={'mem' if t_mem > t_cmp else 'compute'}"),
        })
    return rows


def interpret_validation_rows():
    """Record that every kernel matches its oracle (quick re-check)."""
    from repro.kernels import ops, ref
    from repro.kernels.latent_decode import latent_decode_attention
    rng = np.random.default_rng(0)
    B, S, G, rk, rv, s, qpk, dh = 2, 256, 2, 32, 32, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, G, s * qpk, dh)), jnp.float32)
    zk = jnp.asarray(rng.normal(size=(B, S, G, rk)), jnp.float32)
    zv = jnp.asarray(rng.normal(size=(B, S, G, rv)), jnp.float32)
    r_k = jnp.asarray(rng.normal(size=(G, rk, s * dh)) * rk ** -0.5, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = ops.rope_tables_for(pos, dh, 1e4)
    bias = ops.decode_bias(pos, jnp.full((B,), S - 1), None)
    o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
    o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                    scale=0.25, block_s=128, interpret=True)
    err = float(jnp.max(jnp.abs(o_ref - o_ker)))
    return [{"name": "kernel/latent_decode/interpret_allclose",
             "us_per_call": 0, "derived": f"max_err={err:.2e}"}]


def run(fast: bool = False):
    rows = []
    rows += decode_bench()
    rows += analytic_rows()
    rows += interpret_validation_rows()
    return rows


if __name__ == "__main__":
    common.emit(run())
