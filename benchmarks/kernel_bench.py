"""Kernel microbenchmarks (paper's efficiency figures).

Two views, because this container has no TPU:
  * WALL: XLA-path decode step timings on CPU — latent (ReCalKV) vs dense
    cache at the same model size; the ratio tracks the bytes ratio on
    bandwidth-bound hardware.
  * ANALYTIC: per-call FLOPs / HBM bytes / arithmetic intensity of each
    Pallas kernel at production shapes (what the TPU roofline sees).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.launch.mesh import mesh_from_spec
from repro.models import transformer as T
from repro.sharding import rules as R

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_kernels.json")

VARIANTS = {"dense": ({}, {}),
            "recalkv": ({"recalkv_ratio": 0.5}, {}),
            "recalkv_int8": ({"recalkv_ratio": 0.5},
                             {"cache_quant_bits": 8})}


def _build(arch, tag, backend):
    kw, extra = VARIANTS[tag]
    cfg = dataclasses.replace(get_config(arch, smoke=True, **kw),
                              dtype=jnp.float32,
                              attn_backend=backend, **extra)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def decode_bench(arch="qwen3-4b", S=256, B=4):
    """Full decode_step timings: cache variant x attention backend.

    On CPU the pallas column runs the kernels in interpret mode (a
    correctness trace, not a speed claim — the einsum/pallas pair tracks
    the hot path's perf trajectory once a TPU runs the same rows)."""
    rows = []
    timings = {}
    for tag in VARIANTS:
        for backend in ("einsum", "pallas"):
            cfg, params = _build(arch, tag, backend)
            cache = T.init_decode_cache(cfg, B, S)
            toks = jnp.zeros((B,), jnp.int32)
            cur = jnp.full((B,), S - 1, jnp.int32)
            step = jax.jit(lambda p, c, t, u: T.decode_step(cfg, p, c, t, u))
            us = common.timed(lambda: step(params, cache, toks, cur), repeats=5)
            cache_bytes = sum(l.size * l.dtype.itemsize
                              for l in jax.tree.leaves(cache))
            timings[tag, backend] = us
            rows.append({"name": f"kernel/decode_step/{tag}/{backend}",
                         "variant": tag, "backend": backend,
                         "layout": "ring", "spec_depth": 0, "mesh": "1x1",
                         "us_per_call": us,
                         "derived": f"cache_bytes={cache_bytes}"})
        rows.append({
            "name": f"kernel/decode_step/{tag}/pallas_vs_einsum_ratio",
            "us_per_call": 0,
            "derived": f"{timings[tag, 'pallas'] / timings[tag, 'einsum']:.3f}"})
    rows.append({
        "name": "kernel/decode_step/latent_vs_dense_ratio",
        "us_per_call": 0,
        "derived": (f"{timings['recalkv', 'einsum'] / timings['dense', 'einsum']:.3f}")})
    return rows


def verify_bench(arch="qwen3-4b", S=256, B=4, depth=2):
    """Multi-token verify_step timings at spec depth: variant x backend.

    The pallas rows run the multi-query kernel — all depth+1 verify
    queries score [ring | causal self block] in ONE pass; the einsum twin
    is the joint-softmax reference, and ``speedup_vs_einsum`` is the
    number the MQ kernel exists to move (< 1 in CPU interpret mode)."""
    rows = []
    timings = {}
    nq = depth + 1
    for tag in VARIANTS:
        for backend in ("einsum", "pallas"):
            cfg, params = _build(arch, tag, backend)
            cache = T.init_decode_cache(cfg, B, S)
            fed = jnp.zeros((B, nq), jnp.int32)
            cur = jnp.full((B,), S // 2, jnp.int32)
            fm = jnp.ones((B, nq), bool)
            step = jax.jit(
                lambda p, c, t, u, m: T.verify_step(cfg, p, c, t, u, m))
            us = common.timed(lambda: step(params, cache, fed, cur, fm),
                              repeats=5)
            timings[tag, backend] = us
            rows.append({"name": f"kernel/verify_step/{tag}/{backend}",
                         "variant": tag, "backend": backend,
                         "layout": "ring", "spec_depth": depth,
                         "mesh": "1x1", "us_per_call": us,
                         "derived": f"queries={nq}"})
        rows.append({
            "name": f"kernel/verify_step/{tag}/speedup_vs_einsum",
            "us_per_call": 0,
            "derived": f"{timings[tag, 'einsum'] / timings[tag, 'pallas']:.3f}"})
    return rows


def sharded_rows(arch="qwen3-4b", S=256, B=4, depth=2, shape="2x4"):
    """decode/verify timings with the kernels under shard_map over the
    mesh's "model" axis (ring slices sharded, LSE-merged partial
    softmax).  Needs the devices to exist in-process (forced-host in CI);
    returns no rows otherwise so single-device runs stay clean."""
    import math
    need = math.prod(int(v) for v in shape.split("x"))
    if jax.local_device_count() < need:
        print(f"# sharded rows skipped: {shape} needs {need} devices, "
              f"have {jax.local_device_count()}")
        return []
    mesh = mesh_from_spec(shape)
    rows = []
    nq = depth + 1
    for step_name, timing_depth in (("decode_step", 0),
                                    ("verify_step", depth)):
        timings = {}
        for backend in ("einsum", "pallas"):
            cfg, params = _build(arch, "recalkv", backend)
            params = jax.device_put(params, R.to_named(
                R.param_specs(params, mesh, grains=R.head_grains(cfg)),
                mesh))
            cache = T.init_decode_cache(cfg, B, S)
            cache = jax.device_put(
                cache, R.to_named(R.cache_specs(cache, mesh), mesh))
            cur = jnp.full((B,), S // 2, jnp.int32)
            if step_name == "decode_step":
                toks = jnp.zeros((B,), jnp.int32)
                step = jax.jit(lambda p, c, t, u: T.decode_step(
                    cfg, p, c, t, u, mesh=mesh))
                fn = lambda: step(params, cache, toks, cur)
            else:
                fed = jnp.zeros((B, nq), jnp.int32)
                fm = jnp.ones((B, nq), bool)
                step = jax.jit(lambda p, c, t, u, m: T.verify_step(
                    cfg, p, c, t, u, m, mesh=mesh))
                fn = lambda: step(params, cache, fed, cur, fm)
            us = common.timed(fn, repeats=5)
            timings[backend] = us
            rows.append({
                "name": f"kernel/{step_name}/recalkv/{backend}/mesh={shape}",
                "variant": "recalkv", "backend": backend, "layout": "ring",
                "spec_depth": timing_depth, "mesh": shape,
                "us_per_call": us, "derived": f"shards={mesh.shape['model']}"})
        rows.append({
            "name": f"kernel/{step_name}/recalkv/mesh={shape}"
                    f"/speedup_vs_einsum",
            "us_per_call": 0,
            "derived": f"{timings['einsum'] / timings['pallas']:.3f}"})
    return rows


def analytic_rows():
    """Roofline terms for the latent_decode kernel at production shapes."""
    rows = []
    cases = {
        # arch-like: (B, S, G, rk, rv, s, qpk, dh)
        "danube_decode32k": (128, 4096, 2, 160, 160, 4, 4, 80),
        "qwen3moe_decode32k": (128, 32768, 1, 256, 256, 4, 16, 128),
        "gemma3_global32k": (128, 32768, 2, 512, 512, 4, 2, 256),
    }
    for name, (B, S, G, rk, rv, s, qpk, dh) in cases.items():
        Hg = s * qpk
        bytes_latent = B * S * G * (rk + rv) * 2           # the cache read
        bytes_dense = B * S * G * s * dh * 2 * 2           # dense k+v read
        flops_recon = 2 * B * S * G * rk * s * dh          # zk @ R_k
        flops_attn = 2 * B * S * G * Hg * dh + 2 * B * S * G * Hg * rv
        flops = flops_recon + flops_attn
        t_mem = bytes_latent / 819e9
        t_cmp = flops / 197e12
        ai = flops / bytes_latent
        rows.append({
            "name": f"kernel/latent_decode/{name}",
            "us_per_call": t_mem * 1e6 if t_mem > t_cmp else t_cmp * 1e6,
            "derived": (f"ai={ai:.0f}flops/B bytes_vs_dense="
                        f"{bytes_latent/bytes_dense:.2f} "
                        f"bound={'mem' if t_mem > t_cmp else 'compute'}"),
        })
    return rows


def interpret_validation_rows():
    """Record that every kernel matches its oracle (quick re-check)."""
    from repro.kernels import ops, ref
    from repro.kernels.latent_decode import (latent_decode_attention,
                                             latent_decode_attention_mq)
    rng = np.random.default_rng(0)
    B, S, G, rk, rv, s, qpk, dh = 2, 256, 2, 32, 32, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, G, s * qpk, dh)), jnp.float32)
    zk = jnp.asarray(rng.normal(size=(B, S, G, rk)), jnp.float32)
    zv = jnp.asarray(rng.normal(size=(B, S, G, rv)), jnp.float32)
    r_k = jnp.asarray(rng.normal(size=(G, rk, s * dh)) * rk ** -0.5, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cos, sin = ops.rope_tables_for(pos, dh, 1e4)
    bias = ops.decode_bias(pos, jnp.full((B,), S - 1), None)
    o_ref = ref.latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, 0.25)
    o_ker = latent_decode_attention(q, zk, zv, r_k, cos, sin, bias,
                                    scale=0.25, block_s=128, interpret=True)
    err = float(jnp.max(jnp.abs(o_ref - o_ker)))
    rows = [{"name": "kernel/latent_decode/interpret_allclose",
             "us_per_call": 0, "derived": f"max_err={err:.2e}"}]

    # multi-query kernel vs the single-query kernel walked one verify
    # query at a time over the same extended ring (ring + nq appended
    # self columns, per-query bias from ops.verify_bias)
    nq = 3
    cur = jnp.asarray([200, 130], jnp.int32)
    pos = jnp.where(jnp.arange(S)[None, :] < cur[:, None],
                    jnp.arange(S)[None, :], -1)
    pos_q = cur[:, None] + jnp.arange(nq, dtype=jnp.int32)[None, :]
    pos_ext = jnp.concatenate([pos, pos_q], axis=1)
    feed = jnp.asarray([[True, True, True], [True, True, False]])
    bias_mq = ops.verify_bias(pos_ext, pos_q, feed, None, S)
    cos_e, sin_e = ops.rope_tables_for(jnp.maximum(pos_ext, 0), dh, 1e4)
    zk_s = jnp.asarray(rng.normal(size=(B, nq, G, rk)), jnp.float32)
    zv_s = jnp.asarray(rng.normal(size=(B, nq, G, rv)), jnp.float32)
    zk_e = jnp.concatenate([zk, zk_s], axis=1)
    zv_e = jnp.concatenate([zv, zv_s], axis=1)
    qs = jnp.asarray(rng.normal(size=(B, nq, G, s * qpk, dh)), jnp.float32)
    q_mq = qs.transpose(0, 2, 1, 3, 4).reshape(B, G, nq * s * qpk, dh)
    o_mq = latent_decode_attention_mq(
        q_mq, zk_e, zv_e, r_k, cos_e, sin_e, bias_mq, scale=0.25,
        block_s=128, interpret=True).reshape(B, G, nq, s * qpk, rv)
    err_mq = 0.0
    for j in range(nq):
        o_j = latent_decode_attention(
            qs[:, j], zk_e, zv_e, r_k, cos_e, sin_e, bias_mq[:, j],
            scale=0.25, block_s=128, interpret=True)
        err_mq = max(err_mq, float(jnp.max(jnp.abs(o_j - o_mq[:, :, j]))))
    rows.append({"name": "kernel/latent_decode_mq/interpret_allclose",
                 "us_per_call": 0,
                 "derived": f"max_err={err_mq:.2e} queries={nq}"})
    return rows


def run(fast: bool = False):
    rows = []
    rows += decode_bench()
    rows += verify_bench()
    rows += sharded_rows()
    rows += analytic_rows()
    rows += interpret_validation_rows()
    return rows


def append_trajectory(rows, out_path: str):
    """Append the timed rows to the BENCH_kernels.json trajectory (the
    regression gate's input; analytic/validation rows carry no identity
    keys and are skipped by the gate)."""
    traj = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            traj = json.load(f)
    traj.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "platform": jax.default_backend(),
        "rows": rows,
    })
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(traj, f, indent=1)
    os.replace(tmp, out_path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run()
    common.emit(rows)
    append_trajectory(rows, args.out)
    print(f"# trajectory row appended to {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
