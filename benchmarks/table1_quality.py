"""Table 1: perplexity, ReCalKV vs Palu(G-LRD) vs plain SVD, 50/60/70%.

Paper anchor (ordering, validated at unit scale): at every compression
ratio ReCalKV PPL <= Palu PPL, and degradation grows with ratio."""

from __future__ import annotations

import time

from benchmarks import common


def run(fast: bool = False):
    params = common.get_trained()
    stats, _ = common.calibration_stats(params)
    base_ppl = common.eval_ppl(common.CFG, params)
    rows = [{"name": "table1/original/ppl", "us_per_call": 0,
             "derived": f"{base_ppl:.3f}"}]
    ratios = (0.5,) if fast else (0.5, 0.4, 0.3)   # kept fraction = 1 - compression
    methods = {
        "plain_svd": dict(use_hsr=False, use_calibration=False,
                          use_whitening=False),
        "palu_glrd": dict(use_hsr=False, use_calibration=False,
                          use_whitening=True),
        "recalkv": dict(use_hsr=True, use_calibration=True,
                        use_whitening=True),
    }
    results = {}
    for keep in ratios:
        for name, kw in methods.items():
            t0 = time.perf_counter()
            ccfg, cparams = common.compress_with(params, stats,
                                                 keep_ratio=keep, **kw)
            compress_us = (time.perf_counter() - t0) * 1e6
            ppl = common.eval_ppl(ccfg, cparams)
            results[(keep, name)] = ppl
            comp_pct = int(round((1 - keep) * 100))
            rows.append({
                "name": f"table1/{name}/c{comp_pct}/ppl",
                "us_per_call": compress_us,
                "derived": f"{ppl:.3f}",
            })
    # paper-ordering assertions (recorded, not raised — benches must finish)
    ok = all(results[(k, "recalkv")] <= results[(k, "palu_glrd")] * 1.02
             for k in ratios)
    rows.append({"name": "table1/ordering_recalkv_le_palu", "us_per_call": 0,
                 "derived": "PASS" if ok else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
