"""Table 1: perplexity, ReCalKV vs Palu(G-LRD) vs plain SVD, 50/60/70%.

Methods are registry strategies (repro.api), not flag permutations.
Paper anchor (ordering, validated at unit scale): at every compression
ratio ReCalKV PPL <= Palu PPL, and degradation grows with ratio."""

from __future__ import annotations

import time

from benchmarks import common
from repro.api import CompressionSpec, RankPolicy

# paper-table row name -> registered strategy
METHODS = {
    "plain_svd": "grouped-svd",
    "palu_glrd": "whitened-svd",
    "recalkv": "recalkv",
}


def run(fast: bool = False):
    params = common.get_trained()
    calib = common.calibration_data(params)
    base_ppl = common.eval_ppl(common.CFG, params)
    rows = [{"name": "table1/original/ppl", "us_per_call": 0,
             "derived": f"{base_ppl:.3f}"}]
    ratios = (0.5,) if fast else (0.5, 0.4, 0.3)   # kept fraction = 1 - compression
    results = {}
    for keep in ratios:
        for name, method in METHODS.items():
            spec = CompressionSpec(method,
                                   rank_policy=RankPolicy(keep_ratio=keep))
            t0 = time.perf_counter()
            ccfg, cparams = common.compress_spec(params, spec, calib)
            compress_us = (time.perf_counter() - t0) * 1e6
            ppl = common.eval_ppl(ccfg, cparams)
            results[(keep, name)] = ppl
            comp_pct = int(round((1 - keep) * 100))
            rows.append({
                "name": f"table1/{name}/c{comp_pct}/ppl",
                "us_per_call": compress_us,
                "derived": f"{ppl:.3f}",
            })
    # paper-ordering assertions (recorded, not raised — benches must finish)
    ok = all(results[(k, "recalkv")] <= results[(k, "palu_glrd")] * 1.02
             for k in ratios)
    rows.append({"name": "table1/ordering_recalkv_le_palu", "us_per_call": 0,
                 "derived": "PASS" if ok else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
