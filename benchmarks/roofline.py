"""Roofline reporting: reads experiments/dryrun.jsonl, emits the per-cell
three-term table (also rendered to experiments/roofline.md for
EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun.jsonl")


def load(mesh="16x16"):
    if not os.path.exists(DRYRUN):
        return []
    best: dict[tuple, dict] = {}
    with open(DRYRUN) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("mesh") != mesh:
                continue
            best[(r["arch"], r["shape"])] = r   # last write wins (re-runs)
    return list(best.values())


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | variant | t_compute | t_memory | t_collective |"
           " bottleneck | useful/HLO | MFU bound | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped: {r['reason']} | — | — | — |\n")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('variant','?')} "
                       f"| ERROR | | | {r.get('error','')[:60]} | | | |\n")
            continue
        f = r["roofline"]
        mem = r["memory"].get("total_hbm_bytes", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} "
            f"| {f['t_compute_s']:.2e} | {f['t_memory_s']:.2e} "
            f"| {f['t_collective_s']:.2e} | **{f['bottleneck']}** "
            f"| {f['useful_flops_frac']:.2f} | {f['mfu_bound']:.3f} "
            f"| {mem:.1f} |\n")
    return "".join(out)


def run(fast: bool = False):
    rows = load()
    csv = []
    for r in rows:
        if r["status"] != "ok":
            csv.append({"name": f"roofline/{r['arch']}/{r['shape']}",
                        "us_per_call": 0,
                        "derived": r["status"] + ":" + r.get("reason", r.get("error", ""))[:40]})
            continue
        f = r["roofline"]
        dom = max(f["t_compute_s"], f["t_memory_s"], f["t_collective_s"])
        csv.append({"name": f"roofline/{r['arch']}/{r['shape']}/{r['variant']}",
                    "us_per_call": dom * 1e6,
                    "derived": (f"bottleneck={f['bottleneck']} "
                                f"mfu_bound={f['mfu_bound']:.3f} "
                                f"useful={f['useful_flops_frac']:.2f}")})
    md_path = os.path.join(os.path.dirname(DRYRUN), "roofline.md")
    if rows:
        with open(md_path, "w") as fh:
            fh.write("## Roofline (single-pod 16x16, per-device terms)\n\n")
            fh.write(markdown_table(rows))
    return csv


if __name__ == "__main__":
    from benchmarks import common
    common.emit(run())
