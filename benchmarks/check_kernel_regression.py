"""Perf-regression gate over the BENCH_kernels.json trajectory.

CI downloads the previous successful run's ``BENCH_kernels`` artifact and
compares this run's freshly-appended entry against the artifact's latest
entry: any matching (variant, backend, layout, spec_depth, mesh) timed
row whose ``us_per_call`` grew by more than ``--threshold`` (default 20%)
fails the job.  Rows without identity keys (analytic roofline terms,
interpret-validation checks, derived ratios) are never compared; rows
only one side has are reported but never fail; and when no prior
artifact exists (first run, expired retention, forked repo) the gate
SKIPS cleanly — it guards the trajectory, it must not block
bootstrapping it.

CPU microbenchmark timings on shared runners are noisy; the 20% default
is meant to catch structural regressions (a lost fusion, an interpret
kernel suddenly retracing per call), not scheduler jitter.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 0.20

# identity of a timed row within an entry; everything else is
# measurement.  Rows missing "variant" (analytic / validation / ratio
# rows) carry no identity and are skipped entirely.
ROW_KEY = ("variant", "backend", "layout", "spec_depth", "mesh")
_KEY_DEFAULTS = {"layout": "ring", "spec_depth": 0, "mesh": "1x1"}


def row_key(row: dict) -> tuple | None:
    if "variant" not in row or not row.get("us_per_call"):
        return None
    return tuple(row.get(k, _KEY_DEFAULTS.get(k)) for k in ROW_KEY)


def _fmt(key: tuple) -> str:
    return "/".join(str(v) for v in key)


def compare_entries(prev: dict, new: dict,
                    threshold: float = DEFAULT_THRESHOLD) -> dict:
    """Compare two trajectory entries.  Returns a report dict:
    ``regressions`` (matching rows past the threshold), ``compared``,
    ``only_prev`` / ``only_new`` (unmatched row keys, informational),
    and ``skipped_reason`` when the entries are not comparable (a
    platform change is a new baseline, not a regression)."""
    report = {"regressions": [], "compared": 0,
              "only_prev": [], "only_new": [], "skipped_reason": None}
    if prev.get("platform") != new.get("platform"):
        report["skipped_reason"] = (
            f"platform changed ({prev.get('platform')!r} -> "
            f"{new.get('platform')!r}): new baseline")
        return report
    prev_rows = {k: r for r in prev.get("rows", [])
                 if (k := row_key(r)) is not None}
    new_rows = {k: r for r in new.get("rows", [])
                if (k := row_key(r)) is not None}
    report["only_prev"] = sorted(_fmt(k) for k in prev_rows.keys()
                                 - new_rows.keys())
    report["only_new"] = sorted(_fmt(k) for k in new_rows.keys()
                                - prev_rows.keys())
    for key in sorted(prev_rows.keys() & new_rows.keys(), key=_fmt):
        p, n = prev_rows[key]["us_per_call"], new_rows[key]["us_per_call"]
        report["compared"] += 1
        if p > 0 and n > (1.0 + threshold) * p:
            report["regressions"].append({
                "row": _fmt(key), "prev_us_per_call": round(p, 1),
                "new_us_per_call": round(n, 1),
                "slowdown": round(n / p - 1.0, 3)})
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True,
                    help="previous run's BENCH_kernels.json (may not exist)")
    ap.add_argument("--new", required=True,
                    help="this run's BENCH_kernels.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fractional us_per_call growth that fails "
                         "(default 0.2)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.prev):
        print(f"[kernel-gate] no previous artifact at {args.prev}: skipping "
              f"(first run or expired retention)")
        return 0
    with open(args.prev) as f:
        prev_traj = json.load(f)
    with open(args.new) as f:
        new_traj = json.load(f)
    if not prev_traj or not new_traj:
        print("[kernel-gate] empty trajectory on one side: skipping")
        return 0

    report = compare_entries(prev_traj[-1], new_traj[-1],
                             threshold=args.threshold)
    if report["skipped_reason"]:
        print(f"[kernel-gate] skipped: {report['skipped_reason']}")
        return 0
    for side in ("only_prev", "only_new"):
        for k in report[side]:
            print(f"[kernel-gate] {side.replace('_', ' ')}: {k} "
                  f"(not compared)")
    if report["regressions"]:
        print(f"[kernel-gate] FAIL: {len(report['regressions'])} row(s) "
              f"slowed > {args.threshold:.0%} us/call:")
        for r in report["regressions"]:
            print(f"  {r['row']}: {r['prev_us_per_call']} -> "
                  f"{r['new_us_per_call']} us (+{r['slowdown']:.1%})")
        return 1
    print(f"[kernel-gate] OK: {report['compared']} matching rows within "
          f"{args.threshold:.0%} of the previous run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
