"""Table 3: ablation at a fixed aggressive ratio — HSR / calibration / both.

Paper anchor (ordering): none > hsr-only ~ calib-only > both, in PPL."""

from __future__ import annotations

from benchmarks import common

VARIANTS = {
    "none": dict(use_hsr=False, use_calibration=False),
    "hsr_only": dict(use_hsr=True, use_calibration=False),
    "calib_only": dict(use_hsr=False, use_calibration=True),
    "both": dict(use_hsr=True, use_calibration=True),
}


def run(fast: bool = False):
    params = common.get_trained()
    stats, _ = common.calibration_stats(params)
    keep = 0.3  # paper uses 80% compression; 70% keeps the tiny model sane
    rows = []
    ppls = {}
    # NOTE: whitening OFF for the ablation base — whitened SVD is already
    # the global optimum of the calibration objective (ALS then adds ~0;
    # see test_calibrate_matches_whitened_svd_quality), so the paper's
    # "calibration helps" row is only visible against an unwhitened base,
    # matching the paper's own plain-SVD ablation baseline.
    for name, kw in VARIANTS.items():
        ccfg, cp = common.compress_with(params, stats, keep_ratio=keep,
                                        use_whitening=False, **kw)
        ppls[name] = common.eval_ppl(ccfg, cp, 4 if fast else 8)
        rows.append({"name": f"table3/{name}/ppl", "us_per_call": 0,
                     "derived": f"{ppls[name]:.3f}"})
    ok = (ppls["both"] <= ppls["hsr_only"] * 1.02
          and ppls["both"] <= ppls["calib_only"] * 1.02
          and ppls["hsr_only"] <= ppls["none"] * 1.02
          and ppls["calib_only"] <= ppls["none"] * 1.02)
    rows.append({"name": "table3/ordering_components_help", "us_per_call": 0,
                 "derived": "PASS" if ok else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
