"""Table 3: ablation at a fixed aggressive ratio — HSR / calibration / both.

Every ablation row is a first-class registry strategy (the ReCalKV family
in ``repro.api.strategies``); the only shared override is whitening OFF —
whitened SVD is already the global optimum of the calibration objective
(ALS then adds ~0; see test_calibrate_matches_whitened_svd_quality), so
the paper's "calibration helps" row is only visible against an unwhitened
base, matching the paper's own plain-SVD ablation baseline.

Paper anchor (ordering): none > hsr-only ~ calib-only > both, in PPL."""

from __future__ import annotations

from benchmarks import common
from repro.api import CompressionSpec, RankPolicy

# paper-table row name -> registered strategy
VARIANTS = {
    "none": "grouped-svd",
    "hsr_only": "recalkv-hsr",
    "calib_only": "recalkv-calib",
    "both": "recalkv",
}
ABLATION_OPTIONS = {"use_whitening": False}


def run(fast: bool = False):
    params = common.get_trained()
    calib = common.calibration_data(params)
    keep = 0.3  # paper uses 80% compression; 70% keeps the tiny model sane
    policy = RankPolicy(keep_ratio=keep)
    rows = []
    ppls = {}
    for name, method in VARIANTS.items():
        spec = CompressionSpec(method, options=ABLATION_OPTIONS,
                               rank_policy=policy)
        ccfg, cp = common.compress_spec(params, spec, calib)
        ppls[name] = common.eval_ppl(ccfg, cp, 4 if fast else 8)
        rows.append({"name": f"table3/{name}/ppl", "us_per_call": 0,
                     "derived": f"{ppls[name]:.3f}"})
    ok = (ppls["both"] <= ppls["hsr_only"] * 1.02
          and ppls["both"] <= ppls["calib_only"] * 1.02
          and ppls["hsr_only"] <= ppls["none"] * 1.02
          and ppls["calib_only"] <= ppls["none"] * 1.02)
    rows.append({"name": "table3/ordering_components_help", "us_per_call": 0,
                 "derived": "PASS" if ok else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
