"""Benchmark driver — one module per paper table + kernels + roofline.

``PYTHONPATH=src python -m benchmarks.run [--fast] [--only tableN]``
prints ``name,us_per_call,derived`` CSV for every row of every table.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer ratios/batches (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes, e.g. table1,roofline")
    args = ap.parse_args(argv)

    from benchmarks import (kernel_bench, roofline, table1_quality,
                            table2_longcontext, table3_ablation, table4_quant)
    modules = {
        "table1": table1_quality,
        "table2": table2_longcontext,
        "table3": table3_ablation,
        "table4": table4_quant,
        "kernels": kernel_bench,
        "roofline": roofline,
    }
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows = mod.run(fast=args.fast)
            common.emit(rows)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the suite running
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=1).splitlines()[-1]}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
