"""Table 4: ReCalKV x per-token latent quantization (+ Hadamard).

The latent caches are fake-quantized (quantize->dequantize in the forward)
at 8/4/3 bits, with and without the randomized Hadamard rotation.  Paper
anchors: quantized ReCalKV degrades gracefully (4-bit ~ fp), Hadamard helps
at low bitwidths, and ReCalKV+quant stays below Palu+quant."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.models import transformer as T
from repro.quant import fake_quant, hadamard_inverse, hadamard_transform


def eval_ppl_quant(cfg, params, bits: int, hadamard: bool,
                   num_batches: int = 6) -> float:
    """PPL with the latent cache round-tripped through int quantization.

    We wrap the latent projections: z -> H z (optional) -> int-k -> H^-1.
    Implemented by monkey-patching the einsum outputs via a params
    transform: L_k/L_v are right-multiplied by the Hadamard matrix offline
    (exactly what a deployment would fuse), and fake-quant is applied to
    the transformed latents inside a custom forward."""
    from repro.data import batch as data_batch
    import repro.models.layers as L

    orig = L.self_attention_latent

    def patched(p, x, cfg2, positions, window, theta=None):
        B, Tn, _ = x.shape
        H, Hkv, dh = cfg2.num_heads, cfg2.num_kv_heads, cfg2.d_head
        rt = cfg2.recalkv
        s = max(1, min(rt.group_size, Hkv))
        q = (x @ p["wq"]).reshape(B, Tn, H, dh)
        zk = jnp.einsum("btd,gdr->btgr", x, p["l_k"])
        zv = jnp.einsum("btd,gdr->btgr", x, p["l_v"])

        def q_rt(z):
            if hadamard:
                z = hadamard_transform(z)
            z = fake_quant(z, bits)
            if hadamard:
                z = hadamard_inverse(z)
            return z
        zk, zv = q_rt(zk), q_rt(zv)
        k = jnp.einsum("btgr,grn->btgn", zk, p["r_k"]).reshape(B, Tn, Hkv, dh)
        q = L.maybe_head_norm(q, p.get("q_norm"), cfg2.norm_eps)
        k = L.maybe_head_norm(k, p.get("k_norm"), cfg2.norm_eps)
        cos, sin = L.rope_tables(positions, dh, theta or cfg2.rope_theta)
        q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
        o_lat = L.chunked_attention(q, k, zv, positions, positions,
                                    window=window, scale=dh ** -0.5,
                                    chunk=cfg2.attn_chunk, latent_v=True,
                                    group_size=s)
        return jnp.einsum("bthr,hrd->btd", o_lat, p["wo_fused"]), (zk, zv)

    L.self_attention_latent = patched
    try:
        tot = cnt = 0.0
        for step in range(num_batches):
            b = {k2: jnp.asarray(v)
                 for k2, v in data_batch(common.DC, "valid", step, 8).items()}
            hidden, _ = T.forward_hidden(cfg, params, b["tokens"])
            t, c = T.chunked_xent(cfg, params, hidden, b["labels"])
            tot += float(t)
            cnt += float(c)
    finally:
        L.self_attention_latent = orig
    return float(jnp.exp(tot / cnt))


def run(fast: bool = False):
    params = common.get_trained()
    stats, _ = common.calibration_stats(params)
    rows = []
    results = {}
    for method, kw in {
        "palu_glrd": dict(use_hsr=False, use_calibration=False),
        "recalkv": dict(use_hsr=True, use_calibration=True),
    }.items():
        ccfg, cp = common.compress_with(params, stats, keep_ratio=0.5, **kw)
        fp = common.eval_ppl(ccfg, cp, 4 if fast else 6)
        rows.append({"name": f"table4/{method}/fp/ppl", "us_per_call": 0,
                     "derived": f"{fp:.3f}"})
        results[(method, "fp")] = fp
        for bits in ((8,) if fast else (8, 4, 3)):
            for had in ((True,) if fast else (True, False)):
                tag = f"int{bits}{'_hadamard' if had else ''}"
                ppl = eval_ppl_quant(ccfg, cp, bits, had, 3 if fast else 6)
                results[(method, tag)] = ppl
                rows.append({"name": f"table4/{method}/{tag}/ppl",
                             "us_per_call": 0, "derived": f"{ppl:.3f}"})
    checks = [results[("recalkv", "int8_hadamard")]
              <= results[("recalkv", "fp")] * 1.05]
    if not fast:
        checks += [
            results[("recalkv", "int3_hadamard")]
            <= results[("recalkv", "int3")] * 1.05,           # hadamard helps
            results[("recalkv", "int4_hadamard")]
            <= results[("palu_glrd", "int4_hadamard")] * 1.02,  # beats palu
        ]
    rows.append({"name": "table4/orderings", "us_per_call": 0,
                 "derived": "PASS" if all(checks) else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
