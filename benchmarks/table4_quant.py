"""Table 4: ReCalKV x latent quantization (+ Hadamard), via the registry.

Each row is the ``quantized-latent`` composition strategy wrapping a base
strategy: the latent factors are fake-quantized (quantize->dequantize) at
8/4/3 bits, with and without a folded randomized-Hadamard rotation of the
latent space — the offline fusion a deployment would ship, so no runtime
patching is involved.  Paper anchors: quantized ReCalKV degrades
gracefully (8-bit ~ fp), Hadamard helps at low bitwidths, and
ReCalKV+quant stays below Palu+quant."""

from __future__ import annotations

from benchmarks import common
from repro.api import CompressionSpec, RankPolicy

# paper-table row name -> registered base strategy
METHODS = {"palu_glrd": "whitened-svd", "recalkv": "recalkv"}


def run(fast: bool = False):
    params = common.get_trained()
    calib = common.calibration_data(params)
    policy = RankPolicy(keep_ratio=0.5)
    rows = []
    results = {}
    for method, base in METHODS.items():
        ccfg, cp = common.compress_spec(
            params, CompressionSpec(base, rank_policy=policy), calib)
        fp = common.eval_ppl(ccfg, cp, 4 if fast else 6)
        rows.append({"name": f"table4/{method}/fp/ppl", "us_per_call": 0,
                     "derived": f"{fp:.3f}"})
        results[(method, "fp")] = fp
        # each cell re-runs the base SVD through the registry; at bench-model
        # scale that is seconds per cell (PPL evals dominate), and it keeps
        # every row a pure CompressionSpec with no side-channel state
        for bits in ((8,) if fast else (8, 4, 3)):
            for had in ((True,) if fast else (True, False)):
                tag = f"int{bits}{'_hadamard' if had else ''}"
                spec = CompressionSpec(
                    "quantized-latent",
                    options={"base": base, "bits": bits, "hadamard": had},
                    rank_policy=policy)
                qcfg, qp = common.compress_spec(params, spec, calib)
                ppl = common.eval_ppl(qcfg, qp, 3 if fast else 6)
                results[(method, tag)] = ppl
                rows.append({"name": f"table4/{method}/{tag}/ppl",
                             "us_per_call": 0, "derived": f"{ppl:.3f}"})
    checks = [results[("recalkv", "int8_hadamard")]
              <= results[("recalkv", "fp")] * 1.05]
    if not fast:
        checks += [
            results[("recalkv", "int3_hadamard")]
            <= results[("recalkv", "int3")] * 1.05,           # hadamard helps
            results[("recalkv", "int4_hadamard")]
            <= results[("palu_glrd", "int4_hadamard")] * 1.02,  # beats palu
        ]
    rows.append({"name": "table4/orderings", "us_per_call": 0,
                 "derived": "PASS" if all(checks) else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
