"""Perf-regression gate over the BENCH_serving.json trajectory.

CI downloads the previous successful run's ``BENCH_serving`` artifact and
compares this run's freshly-appended entry against the artifact's latest
entry: any matching (variant, backend, mesh, spec_depth, draft,
cache_layout, page_size, workload, overlap) row whose ``tokens_per_s``
dropped by more than ``--threshold`` (default 20%) fails the job.
Rows only one side has — a new variant, a renamed mesh — are
reported but never fail, and when no prior artifact exists (first run,
expired retention, forked repo) the gate SKIPS cleanly: the gate guards
the trajectory, it must not block bootstrapping it.

CPU throughput on shared runners is noisy; the 20% default is meant to
catch structural regressions (a lost fusion, an accidental per-token
sync), not scheduler jitter.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 0.20

# identity of a row within an entry; everything else is measurement.
# cache_layout/page_size/workload default for rows predating the paged
# cache, overlap for rows predating the overlapped pipeline,
# pipeline_depth/continuous for rows predating the N-deep continuous-
# batching pipeline (the classic double buffer IS depth 2, so old
# overlap rows keep matching their depth-2 descendants), and
# policy/lazy_pages for rows predating the pluggable admission layer
# (fifo without lazy reservation IS the old hardcoded behavior), so old
# baselines keep matching new rows of the same identity while brand-new
# identities (paged, shared-prefix workloads, overlap, depth-3
# continuous, non-fifo policies) skip cleanly as only_new.
ROW_KEY = ("variant", "backend", "mesh", "spec_depth", "draft",
           "cache_layout", "page_size", "workload", "overlap",
           "pipeline_depth", "continuous", "policy", "lazy_pages")
_KEY_DEFAULTS = {"cache_layout": "ring", "page_size": 0, "overlap": False,
                 "pipeline_depth": 2, "continuous": False,
                 "policy": "fifo", "lazy_pages": False}


def row_key(row: dict) -> tuple:
    return tuple(row.get(k, _KEY_DEFAULTS.get(k)) for k in ROW_KEY)


def _fmt(key: tuple) -> str:
    return "/".join("-" if v is None else str(v) for v in key)


def compare_entries(prev: dict, new: dict,
                    threshold: float = DEFAULT_THRESHOLD) -> dict:
    """Compare two trajectory entries.  Returns a report dict:
    ``regressions`` (matching rows past the threshold), ``compared``,
    ``only_prev`` / ``only_new`` (unmatched row keys, informational),
    and ``skipped_reason`` when the entries are not comparable (different
    arch or load config — a changed bench is a new baseline, not a
    regression)."""
    report = {"regressions": [], "compared": 0,
              "only_prev": [], "only_new": [], "skipped_reason": None}
    if prev.get("arch") != new.get("arch") or \
            prev.get("config") != new.get("config"):
        report["skipped_reason"] = (
            f"bench identity changed (arch {prev.get('arch')!r} -> "
            f"{new.get('arch')!r}, config {prev.get('config')} -> "
            f"{new.get('config')}): new baseline")
        return report
    prev_rows = {row_key(r): r for r in prev.get("rows", [])}
    new_rows = {row_key(r): r for r in new.get("rows", [])}
    report["only_prev"] = sorted(_fmt(k) for k in prev_rows.keys()
                                 - new_rows.keys())
    report["only_new"] = sorted(_fmt(k) for k in new_rows.keys()
                                - prev_rows.keys())
    for key in sorted(prev_rows.keys() & new_rows.keys(), key=_fmt):
        p, n = prev_rows[key]["tokens_per_s"], new_rows[key]["tokens_per_s"]
        report["compared"] += 1
        if p > 0 and n < (1.0 - threshold) * p:
            report["regressions"].append({
                "row": _fmt(key), "prev_tokens_per_s": p,
                "new_tokens_per_s": n, "drop": round(1.0 - n / p, 3)})
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True,
                    help="previous run's BENCH_serving.json (may not exist)")
    ap.add_argument("--new", required=True,
                    help="this run's BENCH_serving.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="fractional tokens/s drop that fails (default 0.2)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.prev):
        print(f"[perf-gate] no previous artifact at {args.prev}: skipping "
              f"(first run or expired retention)")
        return 0
    with open(args.prev) as f:
        prev_traj = json.load(f)
    with open(args.new) as f:
        new_traj = json.load(f)
    if not prev_traj or not new_traj:
        print("[perf-gate] empty trajectory on one side: skipping")
        return 0

    report = compare_entries(prev_traj[-1], new_traj[-1],
                             threshold=args.threshold)
    if report["skipped_reason"]:
        print(f"[perf-gate] skipped: {report['skipped_reason']}")
        return 0
    for side in ("only_prev", "only_new"):
        for k in report[side]:
            print(f"[perf-gate] {side.replace('_', ' ')}: {k} (not compared)")
    if report["regressions"]:
        print(f"[perf-gate] FAIL: {len(report['regressions'])} row(s) "
              f"dropped > {args.threshold:.0%} tokens/s:")
        for r in report["regressions"]:
            print(f"  {r['row']}: {r['prev_tokens_per_s']} -> "
                  f"{r['new_tokens_per_s']} tok/s (-{r['drop']:.1%})")
        return 1
    print(f"[perf-gate] OK: {report['compared']} matching rows within "
          f"{args.threshold:.0%} of the previous run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
