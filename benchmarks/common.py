"""Shared benchmark harness: one trained small model reused by every table.

The paper evaluates compression of *pretrained* checkpoints; offline we
train a ~1M-param llama-style MHA model on the copy-rich synthetic corpus
(copy spans make held-out loss sensitive to KV fidelity) and cache it under
experiments/bench_model so repeated benchmark runs skip training.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.api import CalibrationData, CompressionSpec, calibrate, compress
from repro.data import DataConfig, batch as data_batch
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench_model")

CFG = ModelConfig(
    name="bench-110m-proxy", family="dense",
    num_layers=4, d_model=128, num_heads=8, num_kv_heads=8, d_head=16,
    d_ff=352, vocab_size=512, dtype=jnp.float32, scan_layers=False,
    remat=False, attn_chunk=64, tie_embeddings=True,
)
DC = DataConfig(vocab_size=CFG.vocab_size, seq_len=128, copy_frac=0.6)
TRAIN_STEPS = 300


def _batch(split, step, bs=8):
    return {k: jnp.asarray(v) for k, v in data_batch(DC, split, step, bs).items()}


def get_trained(steps: int = TRAIN_STEPS):
    """Train (or load cached) the shared dense benchmark model."""
    params0 = T.init_params(CFG, jax.random.PRNGKey(0))
    latest = ckpt.latest_step(BENCH_DIR)
    if latest == steps:
        return ckpt.restore(BENCH_DIR, steps, {"params": params0})["params"]
    out = train_loop(
        CFG, AdamWConfig(lr=3e-3),
        TrainConfig(microbatches=1, warmup_steps=20, total_steps=steps,
                    schedule="cosine"),
        lambda s: _batch("train", s), logger=lambda *_: None)
    ckpt.save(BENCH_DIR, steps, {"params": out["params"]}, keep_last=1)
    return out["params"]


def calibration_data(params, num_batches: int = 6,
                     fisher: bool = False) -> CalibrationData:
    """Capture calibration once; every table reuses it across strategies."""
    calib = [_batch("calib", s, 4) for s in range(num_batches)]
    return calibrate(CFG, params, calib, fisher=fisher)


def eval_ppl(cfg, params, num_batches: int = 8) -> float:
    tot = cnt = 0.0
    for s in range(num_batches):
        b = _batch("valid", s)
        hidden, _ = T.forward_hidden(cfg, params, b["tokens"])
        t, c = T.chunked_xent(cfg, params, hidden, b["labels"])
        tot += float(t)
        cnt += float(c)
    return float(jnp.exp(tot / cnt))


def compress_spec(params, spec: CompressionSpec, calib: CalibrationData):
    """Registry-dispatched compression of the shared benchmark model."""
    art = compress(CFG, params, spec, calib)
    return art.cfg, art.params


def timed(fn, *args, repeats=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def emit(rows):
    """Print the required ``name,us_per_call,derived`` CSV."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r['derived']}")
