"""End-to-end serving benchmark: the engine's throughput trajectory.

Cache compression papers win or lose on serving throughput, not per-layer
reconstruction error — this bench drives the scheduler/sampler/executor
engine over a fixed synthetic request load for every cache variant
(dense / latent / int8-latent) x attention backend (einsum / pallas) and
records tokens/s plus host-syncs-per-decoded-token (the executor's fused
``sync_every``-token window must cost <= 1 host round-trip per window,
vs 1 per token for the seed engine's loop).

Each run APPENDS one trajectory row to ``BENCH_serving.json`` so the
numbers are comparable across PRs.  On CPU the pallas rows run the
kernels in interpret mode — a correctness trace whose ratio becomes a
speed claim only on TPU.

Overlap rows: the latent/einsum load is re-run on the double-buffered
overlapped pipeline with AOT warmup (``overlap=True, aot=True``), ring
and paged — ``speedup_vs_sync`` records the throughput ratio against the
matching blocking row in the same entry.

Continuous rows: a saturating mixed-length load (``continuous_mix``)
drives the depth-3 window pipeline with device-side mid-window slot
swaps — ``occupancy_device_mean`` (mean active slots per fused-scan
iteration), ``slot_swaps``, client-observed inter-token latency
(``itl_p50_ms``/``itl_p95_ms``) and the host-boundary stage shares
(``profile_shares``) are the recorded trajectory; ``--profile PATH``
additionally dumps the per-event boundary timeline as JSON.

Mesh rows: the latent load is re-run over engine mesh shapes (``1x1``
and ``2x4``) for BOTH backends — the pallas rows exercise the shard_map
kernel path (per-shard partial softmax + LSE merge over the "model"
axis) — so the sharded window's CPU overhead (collectives + forced host
devices) is a recorded trajectory, not an anecdote.  A shape needing
more devices than this process has is measured in a forced-host
subprocess (``--one-mesh-row``), since the device count must be fixed
before jax initializes.  The structural 1-sync-per-window assertion runs
on every row, mesh rows included.

Every pallas row records ``speedup_vs_einsum`` (its tokens/s over the
matching einsum row's): < 1 on CPU where the kernel runs in interpret
mode, the number to watch on TPU.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import mesh_from_spec
from repro.models import transformer as T
from repro.serving import Engine, Request

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serving.json")

VARIANTS = {
    "dense": ({}, {}),
    "latent": ({"recalkv_ratio": 0.5}, {}),
    "int8_latent": ({"recalkv_ratio": 0.5}, {"cache_quant_bits": 8}),
}

MESH_SHAPES = ("1x1", "2x4")


def bench_engine(arch: str, variant: str, backend: str, *, slots: int,
                 max_len: int, requests: int, new_tokens: int,
                 sync_every: int, mesh_spec: str | None = None,
                 spec_depth: int = 0, draft: str | None = None,
                 cache_layout: str = "ring", page_size: int | None = None,
                 n_pages: int | None = None, prompts=None,
                 workload: str | None = None, overlap: bool = False,
                 aot: bool = False, pipeline_depth: int = 2,
                 continuous: bool = False,
                 admission_thread: bool | None = None,
                 policy: str | None = None, lazy_pages: bool = False,
                 profile: bool = False, new_tokens_list=None,
                 stamp_tokens: bool = False,
                 profile_out: dict | None = None) -> dict:
    kw, extra = VARIANTS[variant]
    cfg = dataclasses.replace(get_config(arch, smoke=True, **kw),
                              dtype=jnp.float32, attn_backend=backend,
                              **extra)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_slots=slots, max_len=max_len,
                 sync_every=sync_every, mesh=mesh_from_spec(mesh_spec),
                 spec_depth=spec_depth, draft=draft,
                 cache_layout=cache_layout, page_size=page_size,
                 n_pages=n_pages, overlap=overlap, aot=aot,
                 pipeline_depth=pipeline_depth, continuous=continuous,
                 admission_thread=admission_thread, policy=policy,
                 lazy_pages=lazy_pages, profile=profile)
    if prompts is None:
        g = np.random.default_rng(1)
        prompts = [g.integers(0, cfg.vocab_size,
                              int(g.integers(4, max_len // 3))
                              ).astype(np.int32)
                   for _ in range(requests)]
    # inter-token latency as the CLIENT sees it: perf_counter stamps on
    # every on_token callback (backlog-thread domain under overlap), gaps
    # taken within each request's stream
    stamps: dict[int, list[float]] = {}
    for i, p in enumerate(prompts):
        nt = new_tokens if new_tokens_list is None else new_tokens_list[i]
        cb = None
        if stamp_tokens:
            cb = (lambda r, t, _u=i:
                  stamps.setdefault(_u, []).append(time.perf_counter()))
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=nt,
                           on_token=cb))
    finished = eng.run()
    eng.close()                      # settle backlog counters (no-op sync)
    m = eng.metrics()
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(eng.cache))
    assert len(finished) == len(prompts), "bench load did not drain"
    # the executor's structural contract: exactly one host sync per
    # sync_every-step decode window (plus one per admission wave) — syncs
    # no longer scale with decoded tokens as in the seed engine (and a
    # speculative window still costs ONE sync however many tokens it
    # verifies)
    assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m
    assert m["host_syncs"] < m["tokens"], m
    row = {
        "variant": variant,
        "backend": backend,
        "mesh": m["mesh"],
        # per-row platform: the forced-host 2x4 row runs in a CPU
        # subprocess even when the parent entry's platform is tpu/gpu
        "platform": jax.default_backend(),
        "tokens": m["tokens"],
        "tokens_per_s": round(m["tokens_per_s"], 2),
        "host_syncs_per_token": round(m["host_syncs_per_token"], 4),
        "decode_syncs_per_token": round(m["decode_syncs_per_token"], 4),
        "occupancy_mean": round(m["occupancy_mean"], 2),
        "cache_bytes": cache_bytes,
    }
    if overlap:
        # overlap identity + pipeline health.  The sync_every bound is
        # asserted on BUSY windows: the overlapped drain dispatches a few
        # windows against a stale host view that harvest as empty bubbles
        # (windows_idle) — those cost a sync but emit nothing, so the raw
        # decode_syncs_per_token can exceed 1/sync_every without any
        # structural regression.
        decode_tokens = round(m["windows"]
                              / max(m["decode_syncs_per_token"], 1e-12))
        busy = (m["windows"] - m["windows_idle"]) / max(decode_tokens, 1)
        assert busy <= 1.0 / sync_every + 1e-9, m
        row["overlap"] = True
        row["aot"] = aot
        row["pipeline_depth"] = m["pipeline_depth"]
        row["window_overlap"] = round(m["window_overlap"], 4)
        row["windows_idle"] = m["windows_idle"]
        row["busy_decode_syncs_per_token"] = round(busy, 4)
        row["ttft_s"] = round(m["ttft_s"], 4)
        row["occupancy_device_mean"] = round(m["occupancy_device_mean"], 2)
        # host-boundary stage shares (always-on counters): where the
        # boundary wall-clock actually goes — dispatch / harvest /
        # admission_stage / backlog_drain / bookkeep (+ the admission
        # worker's off-thread prefill time)
        row["profile_shares"] = {k: round(v, 3)
                                 for k, v in m["profile"]["shares"].items()}
    if continuous:
        row["continuous"] = True
        row["slot_swaps"] = m["slot_swaps"]
    if stamp_tokens:
        gaps = [b - a for s in stamps.values()
                for a, b in zip(s, s[1:])]
        if gaps:
            row["itl_p50_ms"] = round(
                float(np.percentile(gaps, 50)) * 1e3, 2)
            row["itl_p95_ms"] = round(
                float(np.percentile(gaps, 95)) * 1e3, 2)
    if spec_depth:
        row["spec_depth"] = spec_depth
        row["draft"] = m["draft"]
        row["accept_rate"] = round(m["accept_rate"], 4)
    if cache_layout == "paged":
        # effective footprint = pages actually touched at peak, not the
        # pool reservation — the number prefix sharing shrinks
        per_page = cache_bytes / eng.n_pages
        row["cache_layout"] = "paged"
        row["page_size"] = eng.page_size
        row["pool_bytes"] = cache_bytes
        row["cache_bytes"] = int(round(per_page * m["pages_peak"]))
        row["pages_peak"] = m["pages_peak"]
        row["pages_shared"] = m["pages_shared"]
        row["cow_forks"] = m["cow_forks"]
        row["prefill_calls"] = m["prefill_calls"]
        row["prefill_calls_saved"] = m["prefill_calls_saved"]
    if policy is not None:
        row["policy"] = m["policy"]
    if lazy_pages:
        row["lazy_pages"] = True
        row["preemptions"] = m["preemptions"]
    if workload:
        row["workload"] = workload
    if profile_out is not None:
        # the bounded per-event timeline (engine profile=True) plus the
        # aggregate shares — dumped by --profile as a standalone JSON
        profile_out["profile"] = m["profile"]
        profile_out["events"] = list(eng._prof_events)
    return row


def bench_device_loop(arch: str, variant: str, *, slots: int, max_len: int,
                      new_tokens: int) -> dict:
    """Raw ``T.decode_loop`` throughput — the executor's upper bound: one
    fused scan, no scheduler, no sampler state, one harvest at the end."""
    kw, extra = VARIANTS[variant]
    cfg = dataclasses.replace(get_config(arch, smoke=True, **kw),
                              dtype=jnp.float32, **extra)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    g = np.random.default_rng(1)
    toks = jnp.asarray(g.integers(0, cfg.vocab_size, (slots, 8)), jnp.int32)
    lens = jnp.full((slots,), 8, jnp.int32)
    logits, caches = T.prefill(cfg, params, toks, lens, max_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cur = lens.astype(jnp.int32)
    loop = jax.jit(lambda c, t, u: T.decode_loop(
        cfg, params, c, t, u, new_tokens))
    loop(caches, tok, cur)[3].block_until_ready()      # compile
    # best-of-3: the row is an UPPER bound and scheduler contention is
    # one-sided noise, so min-of-N is the right estimator (a single
    # ~10ms timed call swings >40% run-to-run on a busy host and flakes
    # the 20% perf gate)
    dt = float("inf")
    for _ in range(3):
        t0 = time.time()
        out = loop(caches, tok, cur)[3]
        out.block_until_ready()
        dt = min(dt, time.time() - t0)
    return {
        "variant": variant,
        "backend": "device_loop",
        "tokens": slots * new_tokens,
        "tokens_per_s": round(slots * new_tokens / dt, 2),
        "host_syncs_per_token": round(1.0 / (slots * new_tokens), 4),
    }


def _subprocess_mesh_row(arch: str, shape: str, *, backend: str = "einsum",
                         slots: int, max_len: int, requests: int,
                         new_tokens: int, sync_every: int) -> dict:
    """Measure a mesh shape needing more devices than this process has:
    re-exec this script with forced host devices (XLA device count is
    fixed at jax init, so it cannot change in-process)."""
    need = math.prod(int(v) for v in shape.split("x"))
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    xla_flags = " ".join(filter(None, [
        os.environ.get("XLA_FLAGS"),
        f"--xla_force_host_platform_device_count={need}"]))
    env = {**os.environ,
           "XLA_FLAGS": xla_flags,
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": src + os.pathsep + os.environ.get("PYTHONPATH", "")}
    cmd = [sys.executable, os.path.abspath(__file__),
           "--one-mesh-row", shape, "--arch", arch, "--backend", backend,
           "--slots", str(slots), "--max-len", str(max_len),
           "--requests", str(requests), "--new-tokens", str(new_tokens),
           "--sync-every", str(sync_every)]
    res = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=1800)
    if res.returncode != 0:
        raise RuntimeError(f"mesh row {shape} subprocess failed:\n"
                           f"{res.stderr[-3000:]}")
    line = [l for l in res.stdout.splitlines() if l.startswith("MESHROW ")][0]
    return json.loads(line[len("MESHROW "):])


def bench_mesh_rows(arch: str, *, slots: int, max_len: int, requests: int,
                    new_tokens: int, sync_every: int,
                    have_rows: list[dict] | None = None) -> list[dict]:
    """Latent load over engine mesh shapes x backends (in-process when
    the devices exist, forced-host subprocess otherwise).  The pallas
    rows run the shard_map kernel path (ring slices sharded over the
    "model" axis, LSE-merged partial softmax) and record
    ``speedup_vs_einsum`` against their einsum twin.  Rows already
    covered by ``have_rows`` are skipped — the variant matrix's own
    latent rows ARE the 1x1 measurement (the engine's default mesh is
    (1, 1)), so they are not re-run."""
    rows = []
    kw = dict(slots=slots, max_len=max_len, requests=requests,
              new_tokens=new_tokens, sync_every=sync_every)

    def have(shape, backend):
        for r in (have_rows or []) + rows:
            if (r.get("mesh") == shape and r["variant"] == "latent"
                    and r["backend"] == backend and not r.get("spec_depth")
                    and r.get("cache_layout", "ring") == "ring"
                    and not r.get("overlap") and not r.get("workload")):
                return r
        return None

    for shape in MESH_SHAPES:
        for backend in ("einsum", "pallas"):
            if have(shape, backend) is not None:
                continue
            need = math.prod(int(v) for v in shape.split("x"))
            t0 = time.time()
            if need <= jax.local_device_count():
                row = bench_engine(arch, "latent", backend, mesh_spec=shape,
                                   **kw)
            else:
                row = _subprocess_mesh_row(arch, shape, backend=backend,
                                           **kw)
            row["bench_seconds"] = round(time.time() - t0, 1)
            if backend == "pallas":
                base = have(shape, "einsum")
                if base is not None and base["tokens_per_s"] > 0:
                    row["speedup_vs_einsum"] = round(
                        row["tokens_per_s"] / base["tokens_per_s"], 2)
            rows.append(row)
            print(f"serving/latent/{backend}/mesh={shape}: "
                  f"{row['tokens_per_s']:.1f} tok/s, "
                  f"{row['host_syncs_per_token']:.3f} syncs/tok")
    return rows


def bench_paged_rows(arch: str, *, slots: int, max_len: int, requests: int,
                     new_tokens: int, sync_every: int) -> list[dict]:
    """Paged-layout rows: the standard load over the pooled cache (einsum
    and pallas), then a shared- vs unshared-system-prompt pair whose
    effective cache_bytes demonstrates prefix sharing — the shared row's
    peak footprint must be strictly below the unshared run's."""
    rows = []
    common = dict(slots=slots, max_len=max_len, requests=requests,
                  new_tokens=new_tokens, sync_every=sync_every)
    base = None
    for backend in ("einsum", "pallas"):
        t0 = time.time()
        row = bench_engine(arch, "latent", backend, cache_layout="paged",
                           **common)
        row["bench_seconds"] = round(time.time() - t0, 1)
        if backend == "einsum":
            base = row
        elif base["tokens_per_s"] > 0:
            row["speedup_vs_einsum"] = round(
                row["tokens_per_s"] / base["tokens_per_s"], 2)
        rows.append(row)
        print(f"serving/latent/{backend}/paged: "
              f"{row['tokens_per_s']:.1f} tok/s, "
              f"cache {row['cache_bytes']/2**20:.2f} MiB effective "
              f"(peak {row['pages_peak']} pages)")
    # shared-prefix pair: same lengths, one load shares a 3-page system
    # prompt across all requests, the other uses disjoint prompts
    ps = next(p for p in (8, 4, 2, 1) if max_len % p == 0)
    vocab = get_config(arch, smoke=True).vocab_size
    g = np.random.default_rng(2)
    sysp = g.integers(0, vocab, 3 * ps).astype(np.int32)
    shared = [np.concatenate([sysp,
                              g.integers(0, vocab, 4).astype(np.int32)])
              for _ in range(requests)]
    unshared = [g.integers(0, vocab, 3 * ps + 4).astype(np.int32)
                for _ in range(requests)]
    pair = {}
    for name, load in (("shared_prefix", shared),
                       ("unshared_prefix", unshared)):
        t0 = time.time()
        row = bench_engine(arch, "latent", "einsum", cache_layout="paged",
                           page_size=ps, prompts=load, workload=name,
                           **common)
        row["bench_seconds"] = round(time.time() - t0, 1)
        pair[name] = row
        rows.append(row)
        print(f"serving/latent/einsum/paged/{name}: "
              f"cache {row['cache_bytes']/2**20:.2f} MiB effective, "
              f"{row['pages_shared']} shares, {row['cow_forks']} forks")
    assert (pair["shared_prefix"]["cache_bytes"]
            < pair["unshared_prefix"]["cache_bytes"]), pair
    assert pair["shared_prefix"]["pages_shared"] > 0, pair
    rows.append(bench_mixed_length(arch, max_len=max_len,
                                   sync_every=sync_every))
    return rows


def bench_mixed_length(arch: str, *, max_len: int,
                       sync_every: int) -> dict:
    """Mixed-length admission: under the SAME pool budget a 4-slot ring
    engine reserves (4 full-length rings), the paged engine's
    reach-based page accounting admits more concurrent requests when the
    load mixes one near-cap prompt with many short ones."""
    kw, extra = VARIANTS["latent"]
    cfg = dataclasses.replace(get_config(arch, smoke=True, **kw),
                              dtype=jnp.float32, **extra)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ps = next(p for p in (8, 4, 2, 1) if max_len % p == 0)
    n_sp = max_len // ps
    budget = 4 * n_sp + 1                      # 4-slot ring equivalent
    new_tokens = 2 * sync_every
    g = np.random.default_rng(3)
    prompts = ([g.integers(0, cfg.vocab_size,
                           max_len - new_tokens - 1).astype(np.int32)]
               + [g.integers(0, cfg.vocab_size, 4).astype(np.int32)
                  for _ in range(7)])

    def drive(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p.copy(),
                               max_new_tokens=new_tokens))
        eng.step()                 # first admission wave + one window
        conc = eng.scheduler.occupancy
        eng.run()
        return conc, eng.metrics()

    t0 = time.time()
    ring_conc, _ = drive(Engine(cfg, params, max_slots=4, max_len=max_len,
                                sync_every=sync_every))
    paged_conc, m = drive(Engine(cfg, params, max_slots=8, max_len=max_len,
                                 sync_every=sync_every,
                                 cache_layout="paged", page_size=ps,
                                 n_pages=budget))
    assert paged_conc > ring_conc, (paged_conc, ring_conc)
    print(f"serving/latent/einsum/paged/mixed_length: {paged_conc} "
          f"concurrent slots vs {ring_conc} ring under {budget - 1} pages")
    return {
        "variant": "latent", "backend": "einsum", "mesh": m["mesh"],
        "platform": jax.default_backend(),
        "workload": "mixed_length",
        "cache_layout": "paged", "page_size": ps,
        "pool_pages": budget,
        "concurrent_slots": paged_conc,
        "ring_concurrent_slots": ring_conc,
        "tokens": m["tokens"],
        "tokens_per_s": round(m["tokens_per_s"], 2),
        "host_syncs_per_token": round(m["host_syncs_per_token"], 4),
        "decode_syncs_per_token": round(m["decode_syncs_per_token"], 4),
        "occupancy_mean": round(m["occupancy_mean"], 2),
        "pages_peak": m["pages_peak"],
        "bench_seconds": round(time.time() - t0, 1),
    }


def bench_overlap_rows(arch: str, *, slots: int, max_len: int,
                       requests: int, new_tokens: int, sync_every: int,
                       have_rows: list[dict]) -> list[dict]:
    """Overlapped-pipeline rows: the double-buffered engine, AOT-warmed,
    over the standard load (ring and paged).  AOT moves trace time out of
    the serving window and the double buffer overlaps host boundary work
    with device compute, so ``tokens_per_s`` here measures steady-state
    serving throughput; ``speedup_vs_sync`` records the ratio against the
    matching sync row from this same entry — the number the pipeline
    refactor exists to move.  Streams stay token-for-token identical to
    the sync rows (asserted in tests/test_async_serving.py)."""
    rows = []
    common = dict(slots=slots, max_len=max_len, requests=requests,
                  new_tokens=new_tokens, sync_every=sync_every)
    for cache_layout in ("ring", "paged"):
        t0 = time.time()
        row = bench_engine(arch, "latent", "einsum", overlap=True, aot=True,
                           cache_layout=cache_layout, **common)
        row["bench_seconds"] = round(time.time() - t0, 1)
        base = next((r for r in have_rows
                     if r["variant"] == "latent" and r["backend"] == "einsum"
                     and not r.get("overlap") and not r.get("spec_depth")
                     and r.get("cache_layout", "ring") == cache_layout
                     and not r.get("workload")), None)
        if base is not None and base["tokens_per_s"] > 0:
            row["speedup_vs_sync"] = round(
                row["tokens_per_s"] / base["tokens_per_s"], 2)
        rows.append(row)
        print(f"serving/latent/einsum/{cache_layout}/overlap+aot: "
              f"{row['tokens_per_s']:.1f} tok/s "
              f"({row.get('speedup_vs_sync', '?')}x sync), "
              f"overlap {row['window_overlap']:.2f}, "
              f"ttft {row['ttft_s'] * 1e3:.0f}ms")
    # the pipeline must WIN somewhere: at least one overlapped row
    # beats its sync baseline (the measured margin — 7-8x on this load,
    # AOT keeping trace time out of the serving window — lives in the
    # trajectory; asserting the full margin would gate CI on shared-
    # runner noise)
    assert any(r.get("speedup_vs_sync", 0) > 1.0 for r in rows), rows
    return rows


def bench_continuous_rows(arch: str, *, slots: int, max_len: int,
                          new_tokens: int, sync_every: int,
                          profile_out: dict | None = None) -> list[dict]:
    """Continuous-batching rows: a saturating mixed-length load (4x the
    slot count, short and long prompts, staggered ``max_new_tokens``) so
    slots free mid-window constantly — the load the device-side slot
    swap exists for.  Three rows on the IDENTICAL load: the blocking
    engine (baseline for ``speedup_vs_sync``), the depth-3 pipeline
    without continuous batching (its ``occupancy_device_mean`` shows
    slots idling until the next boundary), and depth-3 + continuous
    (staged requests install INSIDE the fused scan).  The continuous
    row must swap in-scan and lift device occupancy over the boundary-
    only pipeline — that ordering is structural (a freed slot stays
    empty for the rest of the window without the swap), not timing, so
    it is asserted.  ``itl_p50_ms``/``itl_p95_ms`` record client-
    observed inter-token latency from on_token stamps."""
    g = np.random.default_rng(7)
    vocab = get_config(arch, smoke=True).vocab_size
    n = 6 * slots
    prompts, new_list = [], []
    for i in range(n):
        # mostly short prompts (admission keeps pace with decode) with a
        # long one per wave-ish group, and decode lengths long and
        # staggered enough that slots free MID-window while staged
        # successors are already waiting to be swapped in
        plen = max_len // 3 if i % 8 == 0 else int(g.integers(4, 8))
        nt = (new_tokens + sync_every, new_tokens + 2 * sync_every,
              max_len - sync_every - 1)[i % 3]
        nt = min(nt, max_len - plen - 1)
        prompts.append(g.integers(0, vocab, plen).astype(np.int32))
        new_list.append(nt)
    common = dict(slots=slots, max_len=max_len, requests=n,
                  new_tokens=new_tokens, sync_every=sync_every,
                  prompts=prompts, new_tokens_list=new_list,
                  workload="continuous_mix")
    rows = []
    t0 = time.time()
    sync_row = bench_engine(arch, "latent", "einsum", stamp_tokens=True,
                            **common)
    sync_row["bench_seconds"] = round(time.time() - t0, 1)
    rows.append(sync_row)
    print(f"serving/latent/einsum/continuous_mix/sync: "
          f"{sync_row['tokens_per_s']:.1f} tok/s, "
          f"itl p50 {sync_row.get('itl_p50_ms', 0):.1f}ms")
    t0 = time.time()
    over_row = bench_engine(arch, "latent", "einsum", overlap=True,
                            aot=True, pipeline_depth=3, **common)
    over_row["bench_seconds"] = round(time.time() - t0, 1)
    rows.append(over_row)
    print(f"serving/latent/einsum/continuous_mix/overlap-d3: "
          f"{over_row['tokens_per_s']:.1f} tok/s, "
          f"device occupancy {over_row['occupancy_device_mean']:.2f}")
    t0 = time.time()
    cont_row = bench_engine(arch, "latent", "einsum", overlap=True,
                            aot=True, pipeline_depth=3, continuous=True,
                            profile=True, stamp_tokens=True,
                            profile_out=profile_out, **common)
    cont_row["bench_seconds"] = round(time.time() - t0, 1)
    if sync_row["tokens_per_s"] > 0:
        cont_row["speedup_vs_sync"] = round(
            cont_row["tokens_per_s"] / sync_row["tokens_per_s"], 2)
    rows.append(cont_row)
    print(f"serving/latent/einsum/continuous_mix/continuous-d3: "
          f"{cont_row['tokens_per_s']:.1f} tok/s "
          f"({cont_row.get('speedup_vs_sync', '?')}x sync), "
          f"{cont_row['slot_swaps']} in-scan swaps, "
          f"device occupancy {cont_row['occupancy_device_mean']:.2f} "
          f"vs {over_row['occupancy_device_mean']:.2f} boundary-only, "
          f"itl p95 {cont_row.get('itl_p95_ms', 0):.1f}ms")
    assert cont_row["slot_swaps"] > 0, cont_row
    assert (cont_row["occupancy_device_mean"]
            > over_row["occupancy_device_mean"]), (cont_row, over_row)
    assert cont_row.get("speedup_vs_sync", 0) > 1.0, (cont_row, sync_row)
    assert cont_row["tokens_per_s"] > over_row["tokens_per_s"], (cont_row,
                                                                over_row)
    return rows


def bench_policy_rows(arch: str, *, slots: int, max_len: int,
                      sync_every: int) -> list[dict]:
    """Admission-policy rows: the ``prefix_storm`` workload — 24 requests
    sharing 3 long system prompts (8 sharers each, interleaved arrival)
    with short unique tails — on the identical load under ``fifo`` and
    ``prefix-affinity``.  FIFO admits arrival-order waves, so every wave
    mixes prompt groups and every request rides a prefill row;
    prefix-affinity groups sharers into waves and, once a group's prompt
    pages are resident, later sharers admit with ZERO prefill (the tail
    streams through the decode loop's ingest buffer).  The policy must
    win on this load — strictly fewer admission prefill calls AND >=
    1.3x tokens/s — and the margin is asserted, not just recorded:
    prefill compute dominates the workload by construction (system
    prompt ~10x the decode budget), so the ordering is structural."""
    vocab = get_config(arch, smoke=True).vocab_size
    ps = next(p for p in (8, 4, 2, 1) if max_len % p == 0)
    sys_pages = max(1, (max_len - ps) // ps - 1)
    g = np.random.default_rng(11)
    sysps = [g.integers(0, vocab, sys_pages * ps).astype(np.int32)
             for _ in range(3)]
    prompts = []
    for i in range(24):
        # 1-token tails: the un-chunked ingest buffer is one column
        # wide, so a skip-admitted tail feeds in a single boundary
        tail = g.integers(0, vocab, 1).astype(np.int32)
        prompts.append(np.concatenate([sysps[i % 3], tail]))
    common = dict(slots=slots, max_len=max_len, requests=len(prompts),
                  new_tokens=4, sync_every=sync_every, prompts=prompts,
                  cache_layout="paged", page_size=ps,
                  workload="prefix_storm")
    rows, by = [], {}
    for policy in ("fifo", "prefix-affinity"):
        t0 = time.time()
        row = bench_engine(arch, "latent", "einsum", policy=policy,
                           **common)
        row["bench_seconds"] = round(time.time() - t0, 1)
        by[policy] = row
        rows.append(row)
        print(f"serving/latent/einsum/paged/prefix_storm/{policy}: "
              f"{row['tokens_per_s']:.1f} tok/s, "
              f"{row['prefill_calls']} prefill calls "
              f"({row['prefill_calls_saved']} saved)")
    fifo, aff = by["fifo"], by["prefix-affinity"]
    if fifo["tokens_per_s"] > 0:
        aff["speedup_vs_fifo"] = round(
            aff["tokens_per_s"] / fifo["tokens_per_s"], 2)
    assert aff["prefill_calls"] < fifo["prefill_calls"], (aff, fifo)
    assert aff["prefill_calls_saved"] > 0, aff
    assert aff.get("speedup_vs_fifo", 0) >= 1.3, (aff, fifo)
    return rows


SPEC_CONFIGS = ((2, "ngram"), (2, "layers:2"))


def run(arch: str = "qwen3-4b", *, slots: int = 4, max_len: int = 48,
        requests: int = 6, new_tokens: int = 16,
        sync_every: int = 8, mesh_rows: bool = True,
        profile_out: dict | None = None) -> dict:
    rows = []
    for variant in VARIANTS:
        base = None
        for backend in ("einsum", "pallas"):
            t0 = time.time()
            row = bench_engine(arch, variant, backend, slots=slots,
                               max_len=max_len, requests=requests,
                               new_tokens=new_tokens, sync_every=sync_every)
            row["bench_seconds"] = round(time.time() - t0, 1)
            if backend == "einsum":
                base = row
            elif base["tokens_per_s"] > 0:
                row["speedup_vs_einsum"] = round(
                    row["tokens_per_s"] / base["tokens_per_s"], 2)
            rows.append(row)
            print(f"serving/{variant}/{backend}: "
                  f"{row['tokens_per_s']:.1f} tok/s, "
                  f"{row['host_syncs_per_token']:.3f} syncs/tok, "
                  f"cache {row['cache_bytes']/2**20:.2f} MiB")
    # speculative rows: the latent cache's halved footprint buys slots;
    # speculation spends them on step count — accept rate is the recorded
    # trajectory.  Both backends run: the pallas rows drive the
    # multi-query verify kernel (streams are token-identical to einsum,
    # asserted in tests/test_verify_kernel.py); tokens/s on CPU interpret
    # mode is a correctness trace whose ratio becomes a speed claim on
    # real accelerators.
    for spec_depth, draft in SPEC_CONFIGS:
        base = None
        for backend in ("einsum", "pallas"):
            t0 = time.time()
            row = bench_engine(arch, "latent", backend, slots=slots,
                               max_len=max_len, requests=requests,
                               new_tokens=new_tokens, sync_every=sync_every,
                               spec_depth=spec_depth, draft=draft)
            row["bench_seconds"] = round(time.time() - t0, 1)
            if backend == "einsum":
                base = row
            elif base["tokens_per_s"] > 0:
                row["speedup_vs_einsum"] = round(
                    row["tokens_per_s"] / base["tokens_per_s"], 2)
            rows.append(row)
            print(f"serving/latent/{backend}/spec={spec_depth}/{draft}: "
                  f"{row['tokens_per_s']:.1f} tok/s, "
                  f"accept rate {row['accept_rate']:.2f}")
    rows += bench_paged_rows(arch, slots=slots, max_len=max_len,
                             requests=requests, new_tokens=new_tokens,
                             sync_every=sync_every)
    rows += bench_overlap_rows(arch, slots=slots, max_len=max_len,
                               requests=requests, new_tokens=new_tokens,
                               sync_every=sync_every, have_rows=rows)
    rows += bench_continuous_rows(arch, slots=slots, max_len=max_len,
                                  new_tokens=new_tokens,
                                  sync_every=sync_every,
                                  profile_out=profile_out)
    rows += bench_policy_rows(arch, slots=slots, max_len=max_len,
                              sync_every=sync_every)
    if mesh_rows:
        rows += bench_mesh_rows(arch, slots=slots, max_len=max_len,
                                requests=requests, new_tokens=new_tokens,
                                sync_every=sync_every, have_rows=rows)
    # saturating multi-slot load -> the acceptance bound is demonstrated:
    # <= 1 host sync per sync_every decoded tokens (mesh rows included;
    # overlap rows bound their BUSY windows — drain bubbles cost a sync
    # but emit nothing, see bench_engine)
    if requests >= slots >= 2 and new_tokens >= 2 * sync_every:
        for row in rows:
            bound = row.get("busy_decode_syncs_per_token",
                            row["decode_syncs_per_token"])
            assert bound <= 1.0 / sync_every + 1e-9, row
    row = bench_device_loop(arch, "latent", slots=slots, max_len=max_len,
                            new_tokens=new_tokens)
    rows.append(row)
    print(f"serving/latent/device_loop: {row['tokens_per_s']:.1f} tok/s "
          f"(raw fused-scan upper bound)")
    return {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "arch": arch,
        "platform": jax.default_backend(),
        "config": {"slots": slots, "max_len": max_len, "requests": requests,
                   "new_tokens": new_tokens, "sync_every": sync_every},
        "rows": rows,
    }


def append_trajectory(entry: dict, out_path: str):
    """Append this run's entry to the BENCH_serving.json trajectory."""
    traj = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            traj = json.load(f)
    traj.append(entry)
    tmp = out_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(traj, f, indent=1)
    os.replace(tmp, out_path)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--backend", default="einsum",
                    choices=("einsum", "pallas"),
                    help="attention backend for --one-mesh-row")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--mesh-rows", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="append mesh-shape rows (1x1, 2x4 forced-host)")
    ap.add_argument("--one-mesh-row", default=None, metavar="SHAPE",
                    help="internal: print one mesh row as MESHROW json "
                         "(run in a forced-host subprocess) and exit")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--profile", default=None, metavar="PATH",
                    help="dump the continuous-row host-boundary timeline "
                         "(per-event stage/t/dur + aggregate shares) as "
                         "JSON to PATH")
    args = ap.parse_args(argv)
    if args.one_mesh_row:
        row = bench_engine(args.arch, "latent", args.backend,
                           slots=args.slots, max_len=args.max_len,
                           requests=args.requests,
                           new_tokens=args.new_tokens,
                           sync_every=args.sync_every,
                           mesh_spec=args.one_mesh_row)
        print("MESHROW " + json.dumps(row))
        return
    profile_out = {} if args.profile else None
    entry = run(args.arch, slots=args.slots, max_len=args.max_len,
                requests=args.requests, new_tokens=args.new_tokens,
                sync_every=args.sync_every, mesh_rows=args.mesh_rows,
                profile_out=profile_out)
    append_trajectory(entry, args.out)
    print(f"trajectory row appended to {os.path.abspath(args.out)}")
    if args.profile:
        with open(args.profile, "w") as f:
            json.dump(profile_out, f, indent=1)
        print(f"host-boundary timeline ({len(profile_out['events'])} "
              f"events) written to {os.path.abspath(args.profile)}")


if __name__ == "__main__":
    main()
