"""Table 2 (LongBench proxy): long-range copy retrieval vs compression.

Offline stand-in for LongBench: sequences carry a verbatim copy span, so
next-token accuracy *inside the copied span* measures whether the
compressed KV cache still transports long-range information — the paper's
long-context claim.  Anchor: ReCalKV accuracy >= Palu at every ratio, gap
widening at high compression."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.api import CompressionSpec, RankPolicy
from repro.data import DataConfig, sequence
from repro.models import transformer as T

# paper-table row name -> registered strategy
METHODS = {"palu_glrd": "whitened-svd", "recalkv": "recalkv"}


def copy_accuracy(cfg, params, num_seqs: int = 24) -> float:
    dc = dataclasses.replace(common.DC, copy_frac=1.0)
    hits = total = 0
    for i in range(num_seqs):
        toks = sequence(dc, "valid", 1000 + i)
        t = jnp.asarray(toks[None, :], jnp.int32)
        hidden, _ = T.forward_hidden(cfg, params, t)
        logits = T.logits_for(cfg, params, hidden)
        pred = np.asarray(jnp.argmax(logits[0, :-1], -1))
        # score only inside the repeated span (positions identical to an
        # earlier span are the retrievable ones)
        tk = toks
        for dst in range(dc.seq_len // 2, dc.seq_len - dc.copy_len):
            seg = tk[dst:dst + dc.copy_len]
            src_region = tk[:dc.seq_len // 2]
            for s0 in range(0, len(src_region) - dc.copy_len):
                if np.array_equal(seg, src_region[s0:s0 + dc.copy_len]):
                    hits += int((pred[dst:dst + dc.copy_len - 1]
                                 == tk[dst + 1:dst + dc.copy_len]).sum())
                    total += dc.copy_len - 1
                    break
            else:
                continue
            break
    return hits / max(total, 1)


def run(fast: bool = False):
    params = common.get_trained()
    calib = common.calibration_data(params)
    rows = []
    acc0 = copy_accuracy(common.CFG, params, 12 if fast else 24)
    rows.append({"name": "table2/original/copy_acc", "us_per_call": 0,
                 "derived": f"{acc0:.3f}"})
    results = {}
    for keep in ((0.5,) if fast else (0.5, 0.3)):
        for name, method in METHODS.items():
            spec = CompressionSpec(method,
                                   rank_policy=RankPolicy(keep_ratio=keep))
            ccfg, cp = common.compress_spec(params, spec, calib)
            acc = copy_accuracy(ccfg, cp, 12 if fast else 24)
            results[(keep, name)] = acc
            comp = int(round((1 - keep) * 100))
            rows.append({"name": f"table2/{name}/c{comp}/copy_acc",
                         "us_per_call": 0, "derived": f"{acc:.3f}"})
    ok = all(results[(k, "recalkv")] >= results[(k, "palu_glrd")] - 0.02
             for k in ((0.5,) if fast else (0.5, 0.3)))
    rows.append({"name": "table2/ordering_recalkv_ge_palu", "us_per_call": 0,
                 "derived": "PASS" if ok else "FAIL"})
    return rows


if __name__ == "__main__":
    common.emit(run())
