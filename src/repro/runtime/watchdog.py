"""Per-step watchdog + step-time statistics (hang / straggler detection).

A host thread arms a deadline before each step; if the step doesn't
disarm in time the hook fires (default: raise in the main thread via a
flag the loop checks, and log loudly).  On a real cluster the hook would
escalate to the job controller (evict the straggler, restart from the
latest atomic checkpoint — both substrates exist in this repo).
"""

from __future__ import annotations

import threading
import time


class WatchdogTimeout(RuntimeError):
    pass


class Watchdog:
    def __init__(self, deadline_s: float, on_timeout=None):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self._timer: threading.Timer | None = None
        self.fired: str | None = None
        self.step_times: list[float] = []
        self._t0 = 0.0

    def _fire(self, label: str):
        self.fired = label
        if self.on_timeout:
            self.on_timeout(label)

    def arm(self, label: str = "step"):
        self.disarm()
        self.fired = None
        self._t0 = time.monotonic()
        self._timer = threading.Timer(
            self.deadline_s, self._fire, args=(label,))
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
            self.step_times.append(time.monotonic() - self._t0)
        if self.fired is not None:
            raise WatchdogTimeout(
                f"watchdog fired for {self.fired!r} after {self.deadline_s}s")

    def straggler_score(self) -> float:
        """Last step time / median — >2 suggests a straggling host."""
        if len(self.step_times) < 3:
            return 1.0
        xs = sorted(self.step_times)
        med = xs[len(xs) // 2]
        return self.step_times[-1] / max(med, 1e-9)
