"""Training runtime: grad-accumulation train_step + fault-tolerant loop.

``make_train_step`` builds the jittable step that the launcher pjits:

    microbatch scan  -> f32 grad accumulation (bounds activation memory;
                        XLA overlaps each microbatch's reduce with the next
                        microbatch's compute)
    error feedback   -> optional int8 gradient compression for the cross-pod
                        DP reduction (repro.optim.grad_compress)
    AdamW            -> bf16-moment option for 100B+ archs
    schedule         -> cosine / WSD scale from the step counter

``train_loop`` adds checkpoints (atomic+async), restart-from-latest,
a per-step watchdog deadline (straggler/hang detection), and NaN guards.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, apply_updates, init_state, with_error_feedback
from repro.optim.schedule import SCHEDULES
from repro.runtime.watchdog import Watchdog


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_compress: bool = False       # int8 + error feedback on DP grads
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    keep_last: int = 3
    step_deadline_s: float = 600.0    # watchdog: hang/straggler detection


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``opt_state`` carries {mu, nu, step[, residual]}.
    batch: tokens/labels (B, T) [+ source]; B must divide tc.microbatches.
    """
    sched = SCHEDULES[tc.schedule]

    def loss_of(params, mb):
        return T.loss_fn(cfg, params, mb)

    def train_step(params, opt_state, batch):
        k = tc.microbatches

        def split(x):
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            g_acc, l_acc = carry
            (l, _m), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + l), None

        if k == 1:
            (l, _m), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, jax.tree.map(lambda x: x[0], mbs))
            loss = l
        else:
            (grads, loss), _ = jax.lax.scan(body, (zero, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss / k

        residual = opt_state.get("residual")
        if tc.grad_compress:
            grads, residual = with_error_feedback(grads, residual)

        lr_scale = sched(opt_state["step"],
                         warmup=tc.warmup_steps, total=tc.total_steps)
        params, new_opt, metrics = apply_updates(
            params, grads, opt_state, opt_cfg, lr_scale)
        if tc.grad_compress:
            new_opt["residual"] = residual
        metrics["loss"] = loss
        return params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig,
                     key):
    params = T.init_params(cfg, key)
    opt_state = init_state(params, opt_cfg)
    if tc.grad_compress:
        opt_state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt_state


def train_loop(cfg: ModelConfig, opt_cfg: AdamWConfig, tc: TrainConfig,
               batch_fn: Callable[[int], dict], *, key=None,
               step_fn=None, params=None, opt_state=None,
               log_every: int = 50, logger=print) -> dict[str, Any]:
    """Run to tc.total_steps with checkpoint/restart + watchdog.

    ``batch_fn(step)`` supplies the global batch (stateless data pipeline —
    restart just replays the counter)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params, opt_state = init_train_state(cfg, opt_cfg, tc, key)
    step_fn = step_fn or jax.jit(make_train_step(cfg, opt_cfg, tc))

    start = 0
    if tc.ckpt_dir:
        latest = ckpt_lib.latest_step(tc.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(
                tc.ckpt_dir, latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            logger(f"[train] restored step {latest} from {tc.ckpt_dir}")

    wd = Watchdog(tc.step_deadline_s)
    losses = []
    pending = None
    for step in range(start, tc.total_steps):
        wd.arm(f"step {step}")
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        wd.disarm()
        if not (loss == loss):  # NaN guard
            raise FloatingPointError(f"NaN loss at step {step}")
        losses.append(loss)
        if step % log_every == 0:
            logger(f"[train] step {step} loss {loss:.4f} "
                   f"gnorm {float(metrics['grad_norm']):.3f}")
        if tc.ckpt_dir and (step + 1) % tc.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt_lib.save(
                tc.ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                keep_last=tc.keep_last, async_=True)
    if pending is not None:
        pending.join()
    if tc.ckpt_dir:
        ckpt_lib.save(tc.ckpt_dir, tc.total_steps,
                      {"params": params, "opt": opt_state},
                      keep_last=tc.keep_last)
    return {"params": params, "opt_state": opt_state, "losses": losses}
