from repro.runtime.train_loop import (
    TrainConfig,
    init_train_state,
    make_train_step,
    train_loop,
)
from repro.runtime.watchdog import Watchdog, WatchdogTimeout

__all__ = ["TrainConfig", "Watchdog", "WatchdogTimeout", "init_train_state",
           "make_train_step", "train_loop"]
