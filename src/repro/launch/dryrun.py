import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 pods x 256 v5e chips.
For every cell we

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., donate...).lower(**specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis());  print(compiled.cost_analysis())

and record memory / FLOPs / collective-bytes (parsed from the post-SPMD
HLO) into a resumable JSONL that §Roofline and benchmarks/roofline.py read.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --all --out experiments/dryrun.jsonl
    python -m repro.launch.dryrun --all --multi-pod both
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RECALKV_APPLICABLE, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis as H
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, init_state
from repro.runtime import TrainConfig, make_train_step
from repro.sharding import rules

KEY_SPEC = jax.ShapeDtypeStruct((2,), jnp.uint32)


def microbatches_for(cfg: ModelConfig, global_batch: int) -> int:
    n = cfg.param_count()
    k = 16 if n > 1e11 else 8 if n > 8e9 else 4 if n > 3e9 else 2
    while global_batch % k or (global_batch // k) % 16:
        k //= 2
        if k <= 1:
            return 1
    return max(k, 1)


def moment_dtype_for(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_count() > 5e10 else jnp.float32


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(T.init_params, cfg), KEY_SPEC)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    if kind == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
        if cfg.cross_source_len:
            spec["source"] = jax.ShapeDtypeStruct(
                (batch, cfg.cross_source_len, cfg.d_model), cfg.dtype)
        return spec
    if kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "lengths": jax.ShapeDtypeStruct((batch,), i32)}
        if cfg.cross_source_len:
            spec["source"] = jax.ShapeDtypeStruct(
                (batch, cfg.cross_source_len, cfg.d_model), cfg.dtype)
        return spec
    # decode: one new token against a seq_len cache
    caches = jax.eval_shape(
        functools.partial(T.init_decode_cache, cfg, batch, seq))
    return {"caches": caches,
            "tokens": jax.ShapeDtypeStruct((batch,), i32),
            "cur": jax.ShapeDtypeStruct((batch,), i32)}


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    seq, batch, kind = SHAPES[shape_name]
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * seq * batch
    if kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch


def build_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, donate).

    Output shardings are pinned (§Perf iteration 2): leaving them to
    propagation let XLA pick replicated layouts for the new decode caches,
    which forced the scan's ys-stacking dynamic-update-slice to
    rematerialize the full cache per device."""
    seq, batch, kind = SHAPES[shape_name]
    p_shapes = param_shapes(cfg)
    p_spec = rules.to_named(rules.param_specs(p_shapes, mesh), mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())

    if kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=moment_dtype_for(cfg))
        tc = TrainConfig(microbatches=microbatches_for(cfg, batch))
        o_shapes = jax.eval_shape(
            functools.partial(init_state, cfg=opt_cfg), p_shapes)
        o_spec = rules.to_named(rules.opt_specs(o_shapes, None, mesh), mesh)
        b_shapes = input_specs(cfg, shape_name)
        b_spec = rules.to_named(rules.batch_specs(b_shapes, mesh), mesh)
        fn = make_train_step(cfg, opt_cfg, tc)
        metrics_spec = {"grad_norm": repl, "lr": repl, "loss": repl}
        return (fn, (p_shapes, o_shapes, b_shapes),
                (p_spec, o_spec, b_spec),
                (p_spec, o_spec, metrics_spec), (0, 1))

    import math as _math
    dp_axes = rules.batch_axes(mesh)
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_n = _math.prod(mesh.shape[a] for a in dp_axes)

    def logits_sharding(n_batch: int):
        s0 = dp if n_batch % dp_n == 0 else None
        s1 = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        return NamedSharding(mesh, P(s0, s1))

    if kind == "prefill":
        b_shapes = input_specs(cfg, shape_name)
        b_spec = rules.to_named(rules.batch_specs(b_shapes, mesh), mesh)

        def fn(params, batch_in):
            return T.prefill(cfg, params, batch_in["tokens"],
                             batch_in["lengths"], max_len=seq,
                             source=batch_in.get("source"))
        cache_shapes = jax.eval_shape(
            fn, p_shapes, b_shapes)[1]
        c_spec = rules.to_named(rules.cache_specs(cache_shapes, mesh), mesh)
        return (fn, (p_shapes, b_shapes), (p_spec, b_spec),
                (logits_sharding(batch), c_spec), ())

    # decode
    spec = input_specs(cfg, shape_name)
    c_spec = rules.to_named(rules.cache_specs(spec["caches"], mesh), mesh)
    tok_spec = rules.to_named(rules.batch_specs(
        {"t": spec["tokens"]}, mesh), mesh)["t"]
    cur_spec = rules.to_named(rules.batch_specs(
        {"t": spec["cur"]}, mesh), mesh)["t"]

    fn = functools.partial(T.decode_step, cfg)
    return (fn, (p_shapes, spec["caches"], spec["tokens"], spec["cur"]),
            (p_spec, c_spec, tok_spec, cur_spec),
            (logits_sharding(batch), c_spec), (1,))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             variant: str = "auto", verbose: bool = True) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record."""
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "variant": variant}
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    seq, batch, kind = SHAPES[shape_name]
    use_recalkv = (variant == "recalkv" or
                   (variant == "auto" and kind != "train"
                    and RECALKV_APPLICABLE[arch]))
    rec["variant"] = "recalkv" if use_recalkv else "dense"
    try:
        cfg = get_config(arch, recalkv_ratio=0.5 if use_recalkv else None)
        if kind == "decode":
            # §Perf iteration 5: unrolled decode graphs avoid per-iteration
            # while-carry copies of the cache stack (serving stacks unroll).
            import dataclasses as _dc
            cfg = _dc.replace(cfg, scan_layers=False)
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, arg_shapes, in_sh, out_sh, donate = build_cell(cfg, shape_name, mesh)

        t0 = time.time()
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*arg_shapes)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

        mem = H.memory_report(compiled)
        cost = H.cost_report(compiled)          # XLA's own (loop-body-once)
        hlo_text = compiled.as_text()
        hc = hlo_cost.analyze(hlo_text)          # trip-count-aware model
        roof = H.Roofline(
            hlo_flops=hc.flops,
            hlo_bytes=hc.bytes,
            collective_bytes=hc.total_collective_bytes,
            model_flops=model_flops(cfg, shape_name),
            num_chips=mesh.devices.size,
        )
        rec.update(status="ok", memory=mem, xla_cost=cost,
                   collectives={k: v for k, v in hc.collective_bytes.items()},
                   top_flops=hc.top_flops[:10], top_bytes=hc.top_bytes[:10],
                   roofline=roof.as_dict())
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {rec['mesh']} "
                  f"({rec['variant']}): compile {rec['compile_s']}s, "
                  f"hbm/device {mem.get('total_hbm_bytes', 0)/2**30:.2f} GiB, "
                  f"bottleneck {roof.bottleneck} "
                  f"(c={roof.t_compute:.2e}s m={roof.t_memory:.2e}s "
                  f"n={roof.t_collective:.2e}s)")
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis:   {cost}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[dryrun] {arch} {shape_name} FAILED: {rec['error']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--variant", choices=["auto", "dense", "recalkv"],
                    default="auto")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCHS if (args.all or not args.arch) else (args.arch,)
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    with open(args.out, "a") as f:
        for arch in archs:
            for shape_name in shapes:
                for mp in pods:
                    mesh_name = "2x16x16" if mp else "16x16"
                    if (arch, shape_name, mesh_name) in done:
                        print(f"[dryrun] skip cached {arch} {shape_name} {mesh_name}")
                        continue
                    rec = run_cell(arch, shape_name, multi_pod=mp,
                                   variant=args.variant)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()


if __name__ == "__main__":
    main()
