"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Wires config -> data -> pjit'd train_step -> fault-tolerant loop.  On this
CPU container it drives the smoke configs end-to-end (the examples train a
~100M model for a few hundred steps); on TPU the same entry point runs the
full configs over the production mesh (pass --mesh 16x16).

Multi-host note: launch one process per host with the same arguments;
jax.distributed.initialize() picks up the cluster env (TPU pods set it
automatically) and the per-process code is identical — the data pipeline
is index-addressable so each process computes its own shard.
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data import DataConfig, batch as data_batch
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, make_train_step, train_loop
from repro.sharding import rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 (TPU only)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    # MiniCPM ships with WSD — honor it by default for that arch.
    schedule = "wsd" if args.arch == "minicpm-2b" else args.schedule
    opt_cfg = AdamWConfig(lr=args.lr)
    tc = TrainConfig(
        microbatches=args.microbatches, schedule=schedule,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
        grad_compress=args.grad_compress, ckpt_dir=args.ckpt_dir)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq)
    src = None
    if cfg.cross_source_len:
        g = np.random.default_rng(0)
        src = jnp.asarray(
            g.normal(size=(args.batch, cfg.cross_source_len, cfg.d_model)),
            cfg.dtype)

    def batch_fn(step):
        b = data_batch(dc, "train", step, args.batch)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if src is not None:
            out["source"] = src
        return out

    step_fn = make_train_step(cfg, opt_cfg, tc)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        p_shapes = jax.eval_shape(functools.partial(T.init_params, cfg), key)
        p_sh = rules.to_named(rules.param_specs(p_shapes, mesh), mesh)
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, None, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    out = train_loop(cfg, opt_cfg, tc, batch_fn, step_fn=step_fn)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f})")
    return out


if __name__ == "__main__":
    main()
