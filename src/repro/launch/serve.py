"""Serving launcher: continuous-batching demo over a (compressed) model.

``python -m repro.launch.serve --arch qwen3-4b --smoke --requests 8``
spins up the slot engine, feeds it synthetic prompts, and reports
throughput + cache-bytes, comparing dense vs ReCalKV cache footprints.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RECALKV_APPLICABLE, get_config
from repro.models import transformer as T
from repro.serving import Engine, Request


def cache_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--recalkv", type=float, default=None,
                    help="keep ratio, e.g. 0.5")
    ap.add_argument("--backend", choices=("einsum", "pallas"), default=None,
                    help="attention backend (pallas = fused kernels; "
                         "interpret mode off-TPU)")
    args = ap.parse_args(argv)

    kw = {"smoke": args.smoke}
    if args.recalkv is not None:
        if not RECALKV_APPLICABLE[args.arch]:
            raise SystemExit(f"ReCalKV inapplicable to {args.arch}")
        kw["recalkv_ratio"] = args.recalkv
    cfg = get_config(args.arch, **kw)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    src = None
    if cfg.cross_source_len:
        src = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(args.slots, cfg.cross_source_len, cfg.d_model)),
            cfg.dtype)
    eng = Engine(cfg, params, max_slots=args.slots, max_len=args.max_len,
                 source=src, backend=args.backend)
    print(f"[serve] {cfg.name}: cache {cache_bytes(eng.cache)/2**20:.1f} MiB "
          f"({args.slots} slots x {args.max_len} positions)")

    g = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(g.integers(4, args.max_len // 3))
        eng.submit(Request(
            uid=i, prompt=g.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.new_tokens))
    t0 = time.time()
    finished = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in finished)
    print(f"[serve] {len(finished)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    return finished


if __name__ == "__main__":
    main()
