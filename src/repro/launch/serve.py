"""Serving launcher: continuous-batching demo over a (compressed) model.

``python -m repro.launch.serve --arch qwen3-4b --smoke --requests 8``
spins up the scheduler/sampler/executor engine, feeds it synthetic
prompts, and reports throughput, host-sync rate, slot occupancy and
queue depth, comparing dense vs ReCalKV cache footprints.

``--mesh 2,4`` serves over a (data=2, model=4) mesh — slots shard over
"data", the cache ring's sequence axis over "model" (force host devices
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to try it on
CPU).  Without ``--mesh`` the engine runs the same code path on a
degenerate (1, 1) mesh.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RECALKV_APPLICABLE, get_config
from repro.launch.mesh import mesh_from_spec
from repro.models import transformer as T
from repro.serving import Engine, Request, SamplingParams


def cache_bytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--recalkv", type=float, default=None,
                    help="keep ratio, e.g. 0.5")
    ap.add_argument("--backend", choices=("einsum", "pallas"), default=None,
                    help="attention backend (pallas = fused kernels; "
                         "interpret mode off-TPU)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode tokens per host sync (fused window size)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admit prompts in chunks of this many tokens")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0, help="0 = disabled")
    ap.add_argument("--top-p", type=float, default=1.0, help="1 = disabled")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="mesh shape, e.g. 2,4 (slots shard over data, "
                         "cache sequence over model); default single-device")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="speculative decoding: draft tokens verified per "
                         "window iteration (0 disables; streams are "
                         "invariant to this knob)")
    ap.add_argument("--draft", default=None,
                    help="draft proposer for --spec-depth > 0: 'ngram' "
                         "(prompt lookup, default) or 'layers:K' (self-"
                         "draft from the target's first K layers)")
    ap.add_argument("--cache-layout", choices=("ring", "paged"),
                    default="ring",
                    help="'paged' pools cache pages across slots with "
                         "copy-on-write prompt-prefix sharing; token "
                         "streams are identical to 'ring'")
    ap.add_argument("--page-size", type=int, default=None,
                    help="positions per cache page (paged layout only; "
                         "default: largest of 16/8/4/2/1 dividing "
                         "--max-len)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="physical pool pages incl. the null page (paged "
                         "only; default: ring-equivalent capacity)")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="double-buffered pipeline: two decode windows in "
                         "flight, token handling on a backlog thread "
                         "(--no-overlap for the blocking step loop; "
                         "streams are identical either way)")
    ap.add_argument("--aot", action="store_true",
                    help="AOT-compile the window + prefill buckets at "
                         "boot, so the first request pays load time "
                         "rather than trace time")
    ap.add_argument("--pipeline-depth", type=int, default=3,
                    help="in-flight decode windows under --overlap "
                         "(2 = the classic double buffer)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: staged requests install "
                         "into freed slots INSIDE the fused window "
                         "(device-side mid-window slot swap); streams "
                         "are identical to the sync engine")
    ap.add_argument("--admission-thread",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="stage admission prefill on a worker thread "
                         "(default: on whenever --overlap is)")
    ap.add_argument("--policy", default=None,
                    choices=("fifo", "prefix-affinity", "reach-packing"),
                    help="admission policy: 'fifo' (default, strict "
                         "head-of-line), 'prefix-affinity' (group shared-"
                         "prefix requests into one wave and skip their "
                         "prefill via resident pages; paged only), "
                         "'reach-packing' (admit short requests past a "
                         "blocked long one, bounded bypass)")
    ap.add_argument("--lazy-pages", action="store_true",
                    help="lazy page reservation: allocate cache pages as "
                         "generation reaches them instead of worst-case "
                         "up front, preempting a policy-chosen victim on "
                         "pool exhaustion (paged only; streams are "
                         "identical)")
    ap.add_argument("--staging-depth", type=int, default=None,
                    help="max requests staged ahead by the admission "
                         "worker (default 2x --slots)")
    ap.add_argument("--pin-prefixes", type=int, default=0,
                    help="pin the K hottest registered prefix pages "
                         "against pool recycling (paged layout only)")
    ap.add_argument("--adaptive-spec", action="store_true",
                    help="degrade cold-draft slots to plain decode at "
                         "window boundaries (needs --spec-depth > 0; "
                         "streams are invariant)")
    ap.add_argument("--profile", action="store_true",
                    help="record the host-boundary stage timeline "
                         "(metrics()['profile'])")
    args = ap.parse_args(argv)

    kw = {"smoke": args.smoke}
    if args.recalkv is not None:
        if not RECALKV_APPLICABLE[args.arch]:
            raise SystemExit(f"ReCalKV inapplicable to {args.arch}")
        kw["recalkv_ratio"] = args.recalkv
    cfg = get_config(args.arch, **kw)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    src = None
    if cfg.cross_source_len:
        src = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(args.slots, cfg.cross_source_len, cfg.d_model)),
            cfg.dtype)
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              top_p=args.top_p, seed=args.seed)
    eng = Engine(cfg, params, max_slots=args.slots, max_len=args.max_len,
                 source=src, backend=args.backend, sampling=sampling,
                 sync_every=args.sync_every,
                 prefill_chunk=args.prefill_chunk,
                 mesh=mesh_from_spec(args.mesh),
                 spec_depth=args.spec_depth, draft=args.draft,
                 cache_layout=args.cache_layout, page_size=args.page_size,
                 n_pages=args.n_pages, overlap=args.overlap, aot=args.aot,
                 pipeline_depth=args.pipeline_depth if args.overlap else 2,
                 continuous=args.continuous,
                 admission_thread=args.admission_thread,
                 pin_prefixes=args.pin_prefixes,
                 policy=args.policy, lazy_pages=args.lazy_pages,
                 staging_depth=args.staging_depth,
                 adaptive_spec=args.adaptive_spec, profile=args.profile)
    spec = (f", spec_depth={args.spec_depth} ({eng.metrics()['draft']})"
            if args.spec_depth else "")
    layout = ("" if args.cache_layout == "ring" else
              f", paged (page_size={eng.page_size}, "
              f"{eng.n_pages} pages)")
    mode = (f"overlapped x{args.pipeline_depth}" if args.overlap
            else "sync") + \
        (", continuous" if args.continuous else "") + \
        (", aot" if args.aot else "")
    print(f"[serve] {cfg.name}: cache {cache_bytes(eng.cache)/2**20:.1f} MiB "
          f"({args.slots} slots x {args.max_len} positions), "
          f"sync_every={args.sync_every}, mesh={eng.mesh_str} "
          f"({len(jax.devices())} devices), {mode}{spec}{layout}")

    g = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(g.integers(4, args.max_len // 3))
        eng.submit(Request(
            uid=i, prompt=g.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.new_tokens))
    finished = eng.run()
    eng.close()
    m = eng.metrics()
    print(f"[serve] {len(finished)} requests, {m['tokens']} tokens in "
          f"{m['run_seconds']:.1f}s ({m['tokens_per_s']:.1f} tok/s), "
          f"ttft {m['ttft_s']*1e3:.1f}ms")
    print(f"[serve] host syncs/token {m['host_syncs_per_token']:.3f} "
          f"(decode windows: {m['decode_syncs_per_token']:.3f}), "
          f"occupancy {m['occupancy_mean']:.2f}/{args.slots}, "
          f"queue depth {m['queue_depth_mean']:.2f}")
    if args.overlap:
        print(f"[serve] overlap: {m['window_overlap']:.2f} of windows "
              f"dispatched before the prior completed, "
              f"{m['windows_idle']} idle windows, "
              f"device occupancy {m['occupancy_device_mean']:.2f}"
              f"/{args.slots}"
              + (f", {m['slot_swaps']} in-scan swaps"
                 if args.continuous else ""))
    if args.spec_depth:
        print(f"[serve] speculation: accept rate {m['accept_rate']:.2f} "
              f"({m['draft_accepted']}/{m['draft_proposed']} draft tokens "
              f"accepted)")
    if args.cache_layout == "paged":
        print(f"[serve] pages: peak {m['pages_peak']}/{m['pages_total']}, "
              f"{m['pages_shared']} shares, {m['cow_forks']} COW forks, "
              f"{m['prefix_resurrections']} prefix resurrections")
        print(f"[serve] admission: policy={m['policy']}, "
              f"{m['prefill_calls']} prefill calls "
              f"({m['prefill_calls_saved']} saved), "
              f"{m['preemptions']} preemptions"
              + (f", {m['pages_parked']} pages parked"
                 if m['pages_parked'] else ""))
    if eng.unfinished["queued"] or eng.unfinished["in_flight"]:
        print(f"[serve] WARNING unfinished: {eng.unfinished}")
    return finished


if __name__ == "__main__":
    main()
