"""Launchers: mesh construction, multi-pod dry-run, train & serve CLIs.

NOTE: do not import repro.launch.dryrun from library code — it sets
XLA_FLAGS for 512 placeholder devices at import time (by design; the spec
requires it before any jax initialization).
"""

from repro.launch.mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
