"""Trip-count-aware HLO cost model (the dry-run 'profiler').

``compiled.cost_analysis()`` visits each computation ONCE — a while loop
body (every jax.lax.scan: layer stack, microbatches, attention chunks)
is counted a single time, under-reporting FLOPs/bytes/collective traffic
by the product of trip counts.  This module re-walks the optimized
post-SPMD HLO text with loop multipliers:

  cost(computation) = sum over instructions of
      op_cost + trip_count * cost(while body/cond)
               + cost(called computation)          (call / fusion: x1)
               + max(cost(branches))               (conditional)

FLOPs: dot ops (2 * result_elems * contracted_elems), traversing into
fusions.  Bytes: per-instruction operand+result bytes at the *fusion
boundary* (a fusion's internals stay in registers/VMEM); slice-type and
shape ops count only what they write; gather/scatter count moved slices,
not the whole table.  Collectives: operand-bytes by kind, x trips.

All shapes in post-SPMD HLO are per-partition, so sums are per-device —
exactly what the per-chip roofline terms need.  This is a *model*, not a
measurement: elementwise FLOPs are ignored (matmul-dominated programs)
and byte counts assume every fusion boundary hits HBM.  It is consistent
across iterations, which is what the §Perf loop needs.

Also exposes ``top_costs`` — the per-op-name aggregation used as the
profile when hillclimbing.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "custom-call", "partition-id",
    "replica-id", "rng-get-and-update-state", "opt-barrier",
}
_RESULT_ONLY = {"broadcast", "iota", "copy", "reshape", "transpose",
                "convert", "reverse", "pad", "slice", "dynamic-slice",
                "reduce", "rng", "rng-bit-generator"}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s]+?))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HEAD_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes_elems(type_str: str) -> tuple[int, int]:
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    if not total_e and type_str.strip().split("[")[0] in _DTYPE_BYTES:
        total_e, total_b = 1, _DTYPE_BYTES[type_str.strip().split("[")[0]]
    return total_b, total_e


_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _meta_key(ins: "Instr") -> str:
    """Aggregation key: trailing jax scope path if present, else name stem."""
    m = _OPNAME_RE.search(ins.rest)
    if m:
        path = m.group(1)
        path = re.sub(r"\[.*", "", path)          # drop eqn params
        parts = [p for p in path.split("/") if p]
        return "/".join(parts[-3:])
    return re.sub(r"\.\d+$", "", ins.name)


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the opening paren
    bytes_: int
    elems: int


@dataclasses.dataclass
class CostResult:
    flops: float
    bytes: float
    collective_bytes: dict[str, float]
    top_flops: list[tuple[str, float]]
    top_bytes: list[tuple[str, float]]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.shape_of: dict[str, str] = {}
        self._parse(hlo_text)
        self._memo: dict[str, tuple] = {}
        self.flops_by_meta: dict[str, float] = defaultdict(float)
        self.bytes_by_meta: dict[str, float] = defaultdict(float)

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            h = _COMP_HEAD_RE.match(line)
            if h and ("->" in line):
                cur = h.group(1)
                self.comps[cur] = []
                continue
            m = _INSTR_RE.match(line)
            if m and cur is not None:
                name, type_str, opcode, rest = m.groups()
                b, e = _type_bytes_elems(type_str)
                ins = Instr(name, type_str, opcode, rest, b, e)
                self.comps[cur].append(ins)
                self.shape_of[name] = type_str

    # -- helpers ------------------------------------------------------------

    def _operand_names(self, ins: Instr) -> list[str]:
        # operands appear before the closing paren of the op call
        depth, out = 1, []
        for i, ch in enumerate(ins.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out = _OPERAND_RE.findall(ins.rest[:i])
                    break
        else:
            out = _OPERAND_RE.findall(ins.rest)
        return out

    def _operand_bytes(self, ins: Instr) -> int:
        return sum(_type_bytes_elems(self.shape_of.get(o, ""))[0]
                   for o in self._operand_names(ins))

    def _instr(self, name: str) -> "Instr | None":
        if not hasattr(self, "_by_name"):
            self._by_name = {}
            for instrs in self.comps.values():
                for ins in instrs:
                    self._by_name[ins.name] = ins
        return self._by_name.get(name)

    def _trip_count(self, cond_name: str, init_name: str | None = None) -> int:
        """Scan bound for a lowered while loop.

        jax scans carry the bound as an s32 scalar in the init tuple and
        compare the induction variable against it in the condition.  We
        take the max of (a) s32 constants in the condition (and computations
        it fuses), (b) s32 constants feeding the init tuple."""
        def s32_const(ins: Instr) -> int | None:
            if (ins.opcode == "constant"
                    and ins.type_str.strip().startswith("s32[]")):
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    return int(m.group(1))
            return None

        best = 1
        for ins in self.comps.get(cond_name, []):
            v = s32_const(ins)
            if v is not None:
                best = max(best, v)
            cm = _CALLS_RE.search(ins.rest)
            if cm:
                for sub in self.comps.get(cm.group(1), []):
                    v = s32_const(sub)
                    if v is not None:
                        best = max(best, v)
        if init_name:
            init = self._instr(init_name)
            if init is not None and init.opcode == "tuple":
                for op_name in self._operand_names(init):
                    d = self._instr(op_name)
                    if (d is not None and d.opcode == "constant"
                            and d.type_str.strip().startswith("s32[]")):
                        m = re.match(r"(\d+)", d.rest)
                        if m:
                            best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, ins: Instr) -> float:
        ops = self._operand_names(ins)
        if not ops:
            return 0.0
        lhs_shape = self.shape_of.get(ops[0], "")
        dims_m = _SHAPE_RE.search(lhs_shape)
        if not dims_m:
            return 0.0
        lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
        cm = _CONTRACT_RE.search(ins.rest)
        contracted = 1
        if cm:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contracted *= lhs_dims[int(idx)]
        return 2.0 * ins.elems * contracted

    # -- traversal ----------------------------------------------------------

    def cost_of(self, comp_name: str, mult: float = 1.0,
                count_bytes: bool = True, _depth: int = 0):
        """(flops, bytes, collective_bytes dict) for one computation,
        scaled by the chained loop multiplier ``mult`` (so per-op
        attribution in *_by_meta carries trip counts correctly)."""
        if _depth > 64:  # malformed recursion guard
            return 0.0, 0.0, {}
        f, b = 0.0, 0.0
        c: dict[str, float] = defaultdict(float)

        def merge(sub):
            nonlocal f, b
            sf, sb, sc = sub
            f += sf
            b += sb
            for k, v in sc.items():
                c[k] += v

        for ins in self.comps.get(comp_name, []):
            op = ins.opcode
            kind = next((k for k in _COLLECTIVES
                         if op == k or op == k + "-start"), None)
            if kind is not None:
                size = self._operand_bytes(ins)
                c[kind] += size * mult
                b += (size + ins.bytes_) * mult
                continue
            if op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                init = (self._operand_names(ins) or [None])[0]
                trips = (self._trip_count(cond.group(1), init)
                         if cond else 1)
                if body:
                    merge(self.cost_of(body.group(1), mult * trips,
                                       count_bytes, _depth + 1))
                continue
            if op == "conditional":
                bm = _BRANCH_RE.search(ins.rest)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    costs = [self.cost_of(br, mult, count_bytes, _depth + 1)
                             for br in branches]
                    if costs:
                        merge(max(costs, key=lambda t: t[0] + t[1]))
                continue
            if op in ("call", "async-start"):
                cm2 = _CALLS_RE.search(ins.rest)
                if cm2:
                    merge(self.cost_of(cm2.group(1), mult, count_bytes,
                                       _depth + 1))
                continue
            if op == "fusion":
                cm2 = _CALLS_RE.search(ins.rest)
                if cm2:
                    # flops from inside; bytes at the fusion boundary
                    merge(self.cost_of(cm2.group(1), mult, False, _depth + 1))
                fb = (ins.bytes_ + self._operand_bytes(ins)) * mult
                if count_bytes:
                    b += fb
                    self.bytes_by_meta[_meta_key(ins)] += fb
                continue
            if op == "dot":
                df = self._dot_flops(ins) * mult
                f += df
                self.flops_by_meta[_meta_key(ins)] += df
                if count_bytes:
                    db = (ins.bytes_ + self._operand_bytes(ins)) * mult
                    b += db
                    self.bytes_by_meta[_meta_key(ins)] += db
                continue
            if op in _NO_COST:
                continue
            if not count_bytes:
                continue
            if op in _RESULT_ONLY:
                b += ins.bytes_ * mult
                continue
            if op == "gather":
                ops_ = self._operand_names(ins)
                idx_b = (_type_bytes_elems(self.shape_of.get(
                    ops_[1], ""))[0] if len(ops_) > 1 else 0)
                b += (ins.bytes_ + idx_b) * mult
                continue
            if op in ("scatter", "dynamic-update-slice"):
                ops_ = self._operand_names(ins)
                upd = sum(_type_bytes_elems(self.shape_of.get(o, ""))[0]
                          for o in ops_[1:])
                b += upd * 2 * mult
                continue
            # generic compute op: operands + result
            gb = (ins.bytes_ + self._operand_bytes(ins)) * mult
            b += gb
            self.bytes_by_meta[_meta_key(ins)] += gb
        return f, b, dict(c)

    def entry(self) -> str:
        # jax modules name the entry main.N; fall back to the largest comp
        for name in self.comps:
            if name.startswith("main"):
                return name
        if not self.comps:
            return ""
        return max(self.comps, key=lambda n: len(self.comps[n]))


def analyze(hlo_text: str, top_k: int = 12) -> CostResult:
    model = HloCostModel(hlo_text)
    f, b, c = model.cost_of(model.entry())
    top_f = sorted(model.flops_by_meta.items(), key=lambda kv: -kv[1])[:top_k]
    top_b = sorted(model.bytes_by_meta.items(), key=lambda kv: -kv[1])[:top_k]
    return CostResult(flops=f, bytes=b, collective_bytes=c,
                      top_flops=top_f, top_bytes=top_b)
