"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and bytes but NOT collective
bytes; we parse the optimized (per-partition) HLO text and sum operand
sizes of every communication op.  Shapes in post-SPMD HLO are already
per-device, so the sums feed the per-chip roofline directly.

Conventions per op kind (operand bytes actually crossing links):
  all-reduce          operand size (ring: ~2x, but we report operand bytes
                      and keep the 2(n-1)/n factor in the roofline model)
  all-gather          output / group_size * (group_size - 1) ~ output bytes
  reduce-scatter      input ~ output * group_size
  all-to-all          operand size
  collective-permute  operand size
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:40]:
            continue
        size = _shape_bytes(out_shape)
        gm = _GROUPS_RE.search(line)
        gsize = len(gm.group(1).split(",")) if gm else 1
        if kind == "all-gather" and gsize > 1:
            size = size * (gsize - 1) // gsize       # operand-sized chunks moved
        elif kind == "reduce-scatter" and gsize > 1:
            size = size * (gsize - 1)                # input = out * g, moved (g-1) chunks
        bytes_by[kind] += size
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


# ---- TPU v5e hardware model ------------------------------------------------

PEAK_BF16_FLOPS = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~3 links usable per axis)


@dataclasses.dataclass
class Roofline:
    hlo_flops: float            # per-device
    hlo_bytes: float            # per-device HBM traffic
    collective_bytes: float     # per-device link traffic
    model_flops: float          # 6*N*D (dense) / 6*N_active*D (MoE), global
    num_chips: int

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_BF16_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self) -> float:
        total = self.hlo_flops * self.num_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization if the dominant term were the wall clock."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.num_chips * PEAK_BF16_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "num_chips": self.num_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
        }


def memory_report(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def cost_report(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}
