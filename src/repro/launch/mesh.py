"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization; tests and
benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh, passing Auto axis_types only where the jax version
    has them (0.4.x predates jax.sharding.AxisType; Auto is its default
    behavior there anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, *, skip: bool = False,
                   degrade: bool = False):
    """Small host-device mesh for integration tests.

    Needs ``data * model`` addressable devices (force with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes).  When fewer exist:
      * default       — raise with the XLA_FLAGS hint (no silent surprises);
      * ``skip=True``    — ``pytest.skip`` (the shared guard for mesh tests,
        so every test file stops hand-rolling its own device-count check);
      * ``degrade=True`` — halve axes toward (1, 1) until the mesh fits,
        so opportunistic callers (benches) still get *a* mesh.
    """
    have = len(jax.devices())
    if data * model > have:
        msg = (f"mesh ({data}, {model}) needs {data * model} devices, "
               f"have {have}")
        if jax.default_backend() == "cpu":
            # only sensible advice on CPU — on an accelerator host forcing
            # host-platform devices would silently serve on CPU instead
            msg += (f"; set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={data * model} "
                    f"before jax initializes")
        if skip:
            import pytest
            pytest.skip(msg)
        if not degrade:
            raise RuntimeError(msg)
        while data * model > have and model > 1:
            model = (model + 1) // 2
        while data * model > have and data > 1:
            data = (data + 1) // 2
    return _make_mesh((data, model), ("data", "model"))


def single_device_mesh():
    """(1, 1) ("data", "model") mesh over the default device.

    The serving engine's fallback: with it, the mesh-sharded window is the
    ONLY code path — single-device serving is just the degenerate mesh,
    not a separate branch."""
    return _make_mesh((1, 1), ("data", "model"))


def mesh_from_spec(spec: str | None):
    """Parse a ``--mesh`` CLI spec ("DATA,MODEL" or "DATAxMODEL", e.g.
    "2,4" or "2x4") into a ("data", "model") mesh; None -> the
    single-device fallback."""
    if spec is None:
        return single_device_mesh()
    try:
        data, model = (int(p) for p in spec.replace("x", ",").split(","))
    except ValueError:
        raise ValueError(f"--mesh expects DATA,MODEL (e.g. 2,4), got "
                         f"{spec!r}") from None
    return make_test_mesh(data, model)
