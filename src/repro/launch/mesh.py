"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization; tests and
benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_test_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for integration tests (requires
    xla_force_host_platform_device_count >= data*model in that process)."""
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
