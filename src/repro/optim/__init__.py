from repro.optim.adamw import AdamWConfig, apply_updates, init_state
from repro.optim.grad_compress import (
    compressed_psum,
    dequantize_leaf,
    quantize_leaf,
    with_error_feedback,
)
from repro.optim.schedule import SCHEDULES, cosine, wsd

__all__ = [
    "AdamWConfig", "SCHEDULES", "apply_updates", "compressed_psum", "cosine",
    "dequantize_leaf", "init_state", "quantize_leaf", "with_error_feedback",
    "wsd",
]
