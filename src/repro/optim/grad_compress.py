"""Int8 gradient all-reduce with error feedback — cross-pod DP compression.

At 512+ chips the pod-to-pod data-parallel all-reduce runs over the slower
inter-pod links; quantizing the summands to int8 (per-leaf scale) cuts that
traffic 4x vs f32 / 2x vs bf16.  Error feedback (residual carried in the
train state) keeps the compression unbiased over steps.

``compressed_psum`` is shard_map-friendly: quantize -> integer psum ->
dequantize, so what crosses the links is int8 (+ one f32 scale).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_leaf(g: jax.Array):
    """Symmetric per-leaf int8 quantization.  Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str):
    """Quantized psum over ``axis_name`` (call inside shard_map).

    int8 summands are widened to int32 for the reduction (no overflow for
    <= 2^23 participants); scales are max-reduced so dequantization is
    conservative."""
    def one(g):
        q, scale = quantize_leaf(g)
        scale = lax.pmax(scale, axis_name)
        q32 = lax.psum(q.astype(jnp.int32), axis_name)
        n = lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (q32.astype(jnp.float32) * scale / n).astype(g.dtype)
    return jax.tree.map(one, grads)


def with_error_feedback(grads, residual):
    """Add the carried residual, quantize, carry the new residual.

    Returns (decompressed_grads, new_residual) — simulates what arrives on
    the other side of a compressed all-reduce while staying pjit-friendly
    (the actual int8 psum path is ``compressed_psum`` under shard_map)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def deq_leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_leaf(g32)
        return dequantize_leaf(q, scale).astype(g.dtype)

    def res_leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_leaf(g32)
        return g32 - dequantize_leaf(q, scale)

    # two passes (XLA CSEs the duplicate quantization) — keeps leaves as
    # arrays so empty-tuple subtrees in params never confuse tree mapping
    return (jax.tree.map(deq_leaf, grads, residual),
            jax.tree.map(res_leaf, grads, residual))
