"""AdamW with optional bf16 moments (+ deterministic stochastic rounding).

Self-contained (no optax dependency in the container).  Moments live in
``moment_dtype``; f32 is exact, bf16 halves optimizer HBM — required to fit
the 671B-class archs (DESIGN.md §7).  Stochastic rounding uses a
counter-keyed hash of the update step so restarts stay deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; scaled by the schedule fn
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def _stochastic_round(x: jax.Array, dtype, key) -> jax.Array:
    """Round f32 -> bf16 stochastically (unbiased moment accumulation)."""
    if dtype == jnp.float32:
        return x
    noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
    as_int = jax.lax.bitcast_convert_type(x, jnp.uint32)
    ulp = jax.lax.bitcast_convert_type(
        (as_int & jnp.uint32(0xFFFF0000)) + jnp.uint32(0x10000), jnp.float32
    ) - jax.lax.bitcast_convert_type(as_int & jnp.uint32(0xFFFF0000), jnp.float32)
    return (x + noise * ulp).astype(dtype)


def apply_updates(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    base = jax.random.PRNGKey(0)
    key = jax.random.fold_in(base, step)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for i, (p, g, mu, nu) in enumerate(zip(flat_p, flat_g, flat_mu, flat_nu)):
        g = g.astype(jnp.float32) * clip
        mu_f = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_f = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        upd = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        k = jax.random.fold_in(key, i)
        new_p.append(p_new)
        new_mu.append(_stochastic_round(mu_f, cfg.moment_dtype, k))
        new_nu.append(_stochastic_round(nu_f, cfg.moment_dtype,
                                        jax.random.fold_in(k, 1)))
    return (
        jax.tree.unflatten(treedef, new_p),
        {"mu": jax.tree.unflatten(treedef, new_mu),
         "nu": jax.tree.unflatten(treedef, new_nu),
         "step": step},
        {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)},
    )
