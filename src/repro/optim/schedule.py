"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM's schedule).

All return a multiplicative scale in [0, 1] applied to the peak LR, as a
jittable function of the (traced) step.
"""

from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, warmup: int, total: int, min_scale: float = 0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    frac = (step - warmup) / jnp.maximum(total - warmup, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = min_scale + (1 - min_scale) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, warmup: int, total: int, decay_frac: float = 0.1,
        min_scale: float = 0.01):
    """Warmup -> stable plateau -> sharp decay (arXiv:2404.06395)."""
    step = step.astype(jnp.float32)
    decay_start = total * (1 - decay_frac)
    warm = step / jnp.maximum(warmup, 1)
    decay = 1.0 - (1 - min_scale) * (step - decay_start) / jnp.maximum(
        total - decay_start, 1)
    scale = jnp.where(step < warmup, warm,
                      jnp.where(step < decay_start, 1.0, decay))
    return jnp.clip(scale, min_scale, 1.0)


SCHEDULES = {"cosine": cosine, "wsd": wsd}
