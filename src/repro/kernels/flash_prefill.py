"""Pallas TPU kernel: causal / sliding-window flash-attention prefill.

Standard online-softmax tiling: grid (B, H, nQ, nK) with the key axis
minor-most; (m, l, acc) scratch carries the running softmax across key
tiles of one query tile.  GQA folds into the key/value index map
(kv head = h // q_per_kv).  Sliding windows just tighten the in-block
position mask; fully-masked key tiles are skipped with @pl.when (no MXU
work issued) — the TPU analogue of the paper's bounded-reconstruction
concern for keys.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, block_q, block_k, n_k, seq_len):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)

    @pl.when(i_k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i_q * block_q
    k_start = i_k * block_k
    # static-ish skip bounds (depend only on grid indices)
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32)          # (Bq, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (Bk, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)          # (Bk, dv)
        s = (q @ k.T) * scale                           # (Bq, Bk)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kp < seq_len
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[:, 0] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[:, 0] = m_new

    @pl.when(i_k == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, :, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"),
)
def flash_prefill_attention(q, k, v, *, causal: bool = True,
                            window: int | None = None, scale: float | None = None,
                            block_q: int = 256, block_k: int = 256,
                            interpret: bool = False):
    """q: (B, T, H, dh); k: (B, T, Hkv, dh); v: (B, T, Hv, dv).

    Returns (B, T, H, dv).  ``Hv`` may differ from ``Hkv`` (latent values:
    one value group per ``Hkv // Hv`` kv heads — the query-head order is
    kv-major, so group = h // (H // Hv)).  Arbitrary T: the tail tile is
    zero-padded internally and masked via ``seq_len``.
    """
    B, T, H, dh = q.shape
    Hkv, dv = k.shape[2], v.shape[3]
    Hv = v.shape[2]
    qpk = H // Hkv
    qpv = H // Hv
    scale = scale if scale is not None else dh ** -0.5
    # Floor tile sizes to powers of two so they nest: the padded length is
    # then a single max-tile multiple instead of an lcm that can balloon
    # (e.g. blocks 100/64 -> lcm 1600 for a 100-token sequence).
    bq = 1 << (min(block_q, T).bit_length() - 1)
    bk = 1 << (min(block_k, T).bit_length() - 1)
    tile = max(bq, bk)
    Tp = -(-T // tile) * tile              # multiple of both tile sizes
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    n_q, n_k = Tp // bq, Tp // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_k=n_k, seq_len=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b, h, iq, ik, qpk=qpk: (b, ik, h // qpk, 0)),
            pl.BlockSpec((1, bk, 1, dv),
                         lambda b, h, iq, ik, qpv=qpv: (b, ik, h // qpv, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, H, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T] if Tp != T else out
