"""Pallas TPU kernel: int8-quantized latent-cache flash decode.

Identical dataflow to ``latent_decode`` but the cache tiles arrive as int8
latents with per-token/per-group scales (Table 4 integration: ReCalKV x
per-token quantization).  Dequantization happens in VMEM right before the
reconstruction matmul, so HBM traffic drops by another ~2x vs bf16 latents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.latent_decode import (attend_block, attend_block_mq,
                                         finish_tile, knorm_operand,
                                         lse_outputs, maybe_knorm, pad_ring,
                                         pad_ring_mq, split_out_refs)

NEG_INF = -1e30


def _dequant(q_ref, s_ref):
    """int8 latents x per-token/per-group scales -> f32 tile in VMEM."""
    return (q_ref[0, :, 0].astype(jnp.float32)
            * s_ref[0, :, 0][:, None].astype(jnp.float32))


def _kernel(q_ref, zkq_ref, zks_ref, zvq_ref, zvs_ref, rk_ref, kn_ref,
            cos_ref, sin_ref, bias_ref, o_ref, *rest,
            scale, s, qpk, dh, n_s, apply_knorm, norm_eps,
            return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)

    @pl.when(jnp.max(bias) > NEG_INF * 0.5)       # skip fully-masked tiles
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (Hg, dh)
        zk = _dequant(zkq_ref, zks_ref)                      # (Sb, r_k)
        rk = rk_ref[0].astype(jnp.float32)
        k = zk @ rk
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block(q, k, _dequant(zvq_ref, zvs_ref),
                     cos_ref[0].astype(jnp.float32),
                     sin_ref[0].astype(jnp.float32), bias,
                     scale=scale, s=s, qpk=qpk, dh=dh,
                     m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret", "norm_eps",
                              "return_lse"))
def latent_decode_attention_quant(q, zk_q, zk_scale, zv_q, zv_scale, r_k,
                                  cos, sin, bias, *, scale: float,
                                  block_s: int = 256, interpret: bool = False,
                                  k_norm: jax.Array | None = None,
                                  norm_eps: float = 1e-6,
                                  return_lse: bool = False):
    """zk_q/zv_q: int8 (B, S, G, r); zk_scale/zv_scale: (B, S, G) f32.
    Tail tiles are padded/masked internally; ``k_norm`` as in
    :func:`~repro.kernels.latent_decode.latent_decode_attention`."""
    B, G, Hg, dh = q.shape
    rk = zk_q.shape[3]
    rv = zv_q.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, bias.shape[1])
    S, bias, zk_q, zk_scale, zv_q, zv_scale, cos, sin = pad_ring(
        bias, block_s, zk_q, zk_scale, zv_q, zv_scale, cos, sin)
    n_s = S // bs
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)

    kernel = functools.partial(
        _kernel, scale=scale, s=s, qpk=qpk, dh=dh, n_s=n_s,
        apply_knorm=apply_knorm, norm_eps=norm_eps, return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, Hg, rv, q.dtype, return_lse)
    return pl.pallas_call(
        kernel,
        grid=(B, G, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i: (0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs), lambda b, g, i: (b, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk_q, zk_scale, zv_q, zv_scale, r_k, kn, cos, sin, bias)


def _mq_kernel_q(q_ref, zkq_ref, zks_ref, zvq_ref, zvs_ref, rk_ref, kn_ref,
                 cos_ref, sin_ref, bias_ref, o_ref, *rest,
                 scale, nq, s, qpk, dh, n_s, apply_knorm, norm_eps,
                 return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)             # (nq, Sb)

    @pl.when(jnp.max(bias) > NEG_INF * 0.5)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (nq*Hg, dh)
        zk = _dequant(zkq_ref, zks_ref)
        rk = rk_ref[0].astype(jnp.float32)
        k = zk @ rk
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block_mq(q, k, _dequant(zvq_ref, zvs_ref),
                        cos_ref[0].astype(jnp.float32),
                        sin_ref[0].astype(jnp.float32), bias,
                        scale=scale, nq=nq, s=s, qpk=qpk, dh=dh,
                        m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret", "norm_eps",
                              "return_lse"))
def latent_decode_attention_mq_quant(q, zk_q, zk_scale, zv_q, zv_scale, r_k,
                                     cos, sin, bias, *, scale: float,
                                     block_s: int = 256,
                                     interpret: bool = False,
                                     k_norm: jax.Array | None = None,
                                     norm_eps: float = 1e-6,
                                     return_lse: bool = False):
    """Multi-query int8 latent flash decode.

    q: (B, G, nq*Hg, dh) rows ordered (query, head); bias: (B, nq, S)
    per-query columns over [ring | nq appended self columns].  The self
    columns carry the quantize-then-dequantize verify-window latents, so
    in-kernel dequantization reproduces the einsum reader's
    ``latent_cache_arrays(entry)`` round-trip exactly."""
    B, G, QHg, dh = q.shape
    nq = bias.shape[1]
    Hg = QHg // nq
    rk = zk_q.shape[3]
    rv = zv_q.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, bias.shape[2])
    S, bias, zk_q, zk_scale, zv_q, zv_scale, cos, sin = pad_ring_mq(
        bias, block_s, zk_q, zk_scale, zv_q, zv_scale, cos, sin)
    n_s = S // bs
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)

    kernel = functools.partial(
        _mq_kernel_q, scale=scale, nq=nq, s=s, qpk=qpk, dh=dh, n_s=n_s,
        apply_knorm=apply_knorm, norm_eps=norm_eps, return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, QHg, rv, q.dtype, return_lse)
    return pl.pallas_call(
        kernel,
        grid=(B, G, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, QHg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i: (0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, nq, bs), lambda b, g, i: (b, 0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk_q, zk_scale, zv_q, zv_scale, r_k, kn, cos, sin, bias)
