"""Pallas TPU kernel: int8-quantized latent-cache flash decode.

Identical dataflow to ``latent_decode`` but the cache tiles arrive as int8
latents with per-token/per-group scales (Table 4 integration: ReCalKV x
per-token quantization).  Dequantization happens in VMEM right before the
reconstruction matmul, so HBM traffic drops by another ~2x vs bf16 latents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, zkq_ref, zks_ref, zvq_ref, zvs_ref, rk_ref,
            cos_ref, sin_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, s, qpk, dh, n_s):
    i_s = pl.program_id(2)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (Hg, dh)
    zk = (zkq_ref[0, :, 0].astype(jnp.float32)
          * zks_ref[0, :, 0][:, None].astype(jnp.float32))   # dequant (Sb, r_k)
    rk = rk_ref[0].astype(jnp.float32)
    k = zk @ rk
    sb = k.shape[0]
    k = k.reshape(sb, s, dh)

    half = dh // 2
    cos = cos_ref[0].astype(jnp.float32)[:, None, :]
    sin = sin_ref[0].astype(jnp.float32)[:, None, :]
    k1, k2 = k[..., :half], k[..., half:]
    kr = jnp.concatenate([k1 * cos - k2 * sin, k2 * cos + k1 * sin], axis=-1)

    qg = q.reshape(s, qpk, dh)
    scores = jnp.concatenate(
        [qg[si] @ kr[:, si, :].T for si in range(s)], axis=0
    ) * scale
    scores = scores + bias_ref[0][None, :].astype(jnp.float32)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=-1)

    zv = (zvq_ref[0, :, 0].astype(jnp.float32)
          * zvs_ref[0, :, 0][:, None].astype(jnp.float32))
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ zv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(i_s == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret"))
def latent_decode_attention_quant(q, zk_q, zk_scale, zv_q, zv_scale, r_k,
                                  cos, sin, bias, *, scale: float,
                                  block_s: int = 256, interpret: bool = False):
    """zk_q/zv_q: int8 (B, S, G, r); zk_scale/zv_scale: (B, S, G) f32."""
    B, G, Hg, dh = q.shape
    S, rk = zk_q.shape[1], zk_q.shape[3]
    rv = zv_q.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, S)
    if S % bs:
        raise ValueError(f"S={S} not divisible by block_s={bs}")
    n_s = S // bs
    half = dh // 2

    kernel = functools.partial(
        _kernel, scale=scale, s=s, qpk=qpk, dh=dh, n_s=n_s)
    return pl.pallas_call(
        kernel,
        grid=(B, G, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, g, i: (b, i, g)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs), lambda b, g, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, rv), lambda b, g, i: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, Hg, rv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk_q, zk_scale, zv_q, zv_scale, r_k, cos, sin, bias)
