"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Shapes (decode):
  q      (B, G, Hg, dh)   post-RoPE queries, grouped: Hg = s * q_per_kv
  zk     (B, S, G, r_k)   pre-RoPE key latents
  zv     (B, S, G, r_v)   value latents
  r_k    (G, r_k, s*dh)   key reconstruction factors
  cos/sin (B, S, dh/2)    rotation tables for the *stored* positions
  bias   (B, S)           additive mask (0 valid / -inf invalid)
  out    (B, G, Hg, r_v)  per-head latent attention outputs
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rotate(k: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """k: (..., S, s, dh); cos/sin: (..., S, dh/2) broadcast over s."""
    half = k.shape[-1] // 2
    k1, k2 = k[..., :half], k[..., half:]
    c, s_ = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([k1 * c - k2 * s_, k2 * c + k1 * s_], axis=-1)


def latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, scale):
    """Reference ReCalKV decode: reconstruct K, RoPE, softmax, latent AV."""
    B, G, Hg, dh = q.shape
    S = zk.shape[1]
    s = r_k.shape[-1] // dh
    qpk = Hg // s
    qf = q.astype(jnp.float32)
    k = jnp.einsum("bsgr,grn->bsgn", zk.astype(jnp.float32),
                   r_k.astype(jnp.float32))
    k = k.reshape(B, S, G, s, dh)
    k = rotate(k.swapaxes(1, 2), cos[:, None], sin[:, None])    # (B,G,S,s,dh)
    qg = qf.reshape(B, G, s, qpk, dh)
    logits = jnp.einsum("bgsjd,bgtsd->bgsjt", qg, k) * scale
    logits = logits + bias[:, None, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgsjt,btgr->bgsjr", w, zv.astype(jnp.float32))
    return o.reshape(B, G, Hg, zv.shape[-1])


def latent_decode_attention_quant(q, zk_q, zk_scale, zv_q, zv_scale, r_k,
                                  cos, sin, bias, scale):
    """Int8-latent variant: dequantize then defer to the fp oracle."""
    zk = zk_q.astype(jnp.float32) * zk_scale[..., None]
    zv = zv_q.astype(jnp.float32) * zv_scale[..., None]
    return latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, scale)


def flash_prefill_attention(q, k, v, *, causal=True, window=None, scale=None):
    """Reference causal/windowed prefill attention.

    q: (B, T, H, dh); k/v: (B, T, Hkv, dh).  Returns (B, T, H, dv).
    """
    B, T, H, dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else dh ** -0.5
    qr = q.astype(jnp.float32).reshape(B, T, Hkv, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k.astype(jnp.float32)) * scale
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    m = jnp.ones((T, T), bool)
    if causal:
        m &= j <= i
    if window is not None:
        m &= j > i - window
    logits = jnp.where(m[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, T, H, v.shape[-1])
