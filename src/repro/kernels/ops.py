"""Jit'd convenience wrappers around the Pallas kernels.

These adapt model-layer tensors (cache dicts, position arrays) to kernel
calling conventions, pick block sizes, and resolve interpret mode from the
platform: on CPU/GPU the kernels run through the Pallas interpreter (the
validation mode used by every test); on TPU the same calls lower through
Mosaic.  Pass ``interpret=True/False`` to override.

Decode-side wrappers accept a ``self_entry`` — the current token's K/V (or
latents), which the model keeps out of the ring until after the layer scan
(deferred writes).  The wrapper appends it as an extra ring column at
position ``cur`` before calling the kernel, so the joint softmax over
[cache | self] matches the model's two-part einsum softmax exactly.

Known cost of that design: the concat materializes a ring copy per layer
per step, and when the ring length is a tile multiple the S+1-th column
opens one extra (otherwise dead) key tile — the kernels skip fully-masked
tiles, so the extra tile costs a DMA but no MXU work.  Eliminating the
copy needs write-before-attend (ring writes inside the layer scan), which
trades back the scan-rematerialization cost deferred writes exist to
avoid (EXPERIMENTS.md §Perf iteration 3) — revisit on TPU profiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.latent_decode import (NEG_INF, latent_decode_attention,
                                         latent_decode_attention_paged)
from repro.kernels.latent_decode_q import latent_decode_attention_quant


def default_interpret() -> bool:
    """Interpret mode for the current platform: real lowering only on TPU."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def decode_bias(pos: jax.Array, cur: jax.Array, window: int | None) -> jax.Array:
    """Additive (B, S) mask from stored slot positions + current position."""
    valid = (pos >= 0) & (pos <= cur[:, None])
    if window is not None:
        valid &= pos > (cur[:, None] - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def rope_tables_for(pos: jax.Array, dh: int, theta: float):
    """cos/sin (B, S, dh/2) for stored (clamped) positions."""
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.maximum(pos, 0).astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def group_queries(q: jax.Array, num_groups: int) -> jax.Array:
    """(B, H, dh) -> (B, G, Hg, dh) in kernel head order (kv-major)."""
    B, H, dh = q.shape
    return q.reshape(B, num_groups, H // num_groups, dh)


def ungroup_outputs(o: jax.Array) -> jax.Array:
    """(B, G, Hg, rv) -> (B, H, rv)."""
    B, G, Hg, rv = o.shape
    return o.reshape(B, G * Hg, rv)


def _extend_ring(cache: dict, self_entry: dict | None, cur: jax.Array):
    """Append the (deferred-write) current token as one extra ring column.

    cache leaves are (B, S, ...); self_entry leaves are the matching
    (B, ...) slot values.  Returns (arrays, pos) with S+1 columns."""
    pos = cache["pos"]
    arrs = {k: v for k, v in cache.items() if k != "pos"}
    if self_entry is None:
        return arrs, pos
    arrs = {k: jnp.concatenate([v, self_entry[k][:, None].astype(v.dtype)],
                               axis=1)
            for k, v in arrs.items()}
    pos = jnp.concatenate([pos, cur[:, None].astype(pos.dtype)], axis=1)
    return arrs, pos


def latent_decode(q, cache, r_k, cur, *, theta: float, window: int | None,
                  scale: float, block_s: int = 256, use_kernel: bool = True,
                  interpret: bool | None = None, self_entry: dict | None = None,
                  k_norm: jax.Array | None = None, norm_eps: float = 1e-6):
    """End-to-end latent decode from a model cache dict.

    q: (B, H, dh) post-RoPE grouped-orderable queries;
    cache: {"zk","zv","pos"} — or the int8 ring {"zk_q","zk_s","zv_q",
    "zv_s","pos"} — as produced by the model layer.  ``self_entry`` holds
    the current token's latents in the same (quantized or not) layout.
    Returns (B, H, r_v) latent outputs.
    """
    arrs, pos = _extend_ring(cache, self_entry, cur)
    quant = "zk_q" in arrs
    S = pos.shape[1]
    G = (arrs["zk_q"] if quant else arrs["zk"]).shape[2]
    dh = q.shape[-1]
    cos, sin = rope_tables_for(pos, dh, theta)
    bias = decode_bias(pos, cur, window)
    qg = group_queries(q, G)
    if use_kernel:
        kw = dict(scale=scale, block_s=min(block_s, S),
                  interpret=_resolve_interpret(interpret),
                  k_norm=k_norm, norm_eps=norm_eps)
        if quant:
            o = latent_decode_attention_quant(
                qg, arrs["zk_q"], arrs["zk_s"], arrs["zv_q"], arrs["zv_s"],
                r_k, cos, sin, bias, **kw)
        else:
            o = latent_decode_attention(qg, arrs["zk"], arrs["zv"], r_k,
                                        cos, sin, bias, **kw)
    else:
        if quant:
            from repro.quant import dequantize
            zk = dequantize(arrs["zk_q"], arrs["zk_s"][..., None])
            zv = dequantize(arrs["zv_q"], arrs["zv_s"][..., None])
        else:
            zk, zv = arrs["zk"], arrs["zv"]
        if k_norm is not None:
            raise NotImplementedError("ref path applies no k-norm")
        o = ref.latent_decode_attention(qg, zk, zv, r_k, cos, sin, bias, scale)
    return ungroup_outputs(o)


def dense_decode(q, cache, cur, *, window: int | None, scale: float,
                 block_s: int = 256, interpret: bool | None = None,
                 self_entry: dict | None = None):
    """Dense-cache decode through the latent kernel.

    The dense ring {"k","v","pos"} is the degenerate latent cache: one kv
    head per group, identity reconstruction (r_k = I), identity rotation
    (keys are stored post-RoPE, so cos=1/sin=0).  q: (B, H, dh) post-RoPE;
    self_entry: {"k","v"} (B, Hkv, dh) post-RoPE/norm.  Returns (B, H, dh).
    """
    arrs, pos = _extend_ring(cache, self_entry, cur)
    k, v = arrs["k"], arrs["v"]
    B, S, Hkv, dh = k.shape
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    ones = jnp.ones((B, S, dh // 2), jnp.float32)
    bias = decode_bias(pos, cur, window)
    qg = group_queries(q, Hkv)
    o = latent_decode_attention(qg, k, v, eye, ones, jnp.zeros_like(ones),
                                bias, scale=scale, block_s=min(block_s, S),
                                interpret=_resolve_interpret(interpret))
    return ungroup_outputs(o)


def _paged_pos_view(pool_pos: jax.Array, ptab: jax.Array) -> jax.Array:
    """Slot-major (B, n_slot_pages*page_size) positions gathered through the
    page table — int32-cheap; the latents themselves stay page-major and
    only move inside the kernel."""
    B, n_sp = ptab.shape
    ps = pool_pos.shape[1]
    return jnp.take(pool_pos, ptab.reshape(-1), axis=0).reshape(B, n_sp * ps)


def _self_tile(entry: jax.Array, ps: int) -> jax.Array:
    """(B, ...) self entry -> (B, page_size, ...) tile with row 0 real and
    rows 1.. zero — the same [self | padding] block ``pad_ring`` yields
    for the ring kernel when the ring length is a tile multiple."""
    B = entry.shape[0]
    tile = jnp.zeros((B, ps) + entry.shape[1:], entry.dtype)
    return tile.at[:, 0].set(entry)


def _paged_tables(pos_view: jax.Array, cur: jax.Array, window: int | None,
                  dh: int, theta: float | None, ps: int):
    """Slot-major bias/cos/sin covering [table-gathered ring | self tile].

    Self-tile columns: col 0 gets bias 0 and the rotation for position
    ``cur`` (identity when theta is None — dense caches store post-RoPE
    keys), cols 1.. get bias -inf and zero tables, matching ``pad_ring``'s
    padding bitwise."""
    B = cur.shape[0]
    half = dh // 2
    bias_r = decode_bias(pos_view, cur, window)
    bias_s = jnp.full((B, ps), NEG_INF, jnp.float32).at[:, 0].set(0.0)
    if theta is None:
        cos_r = jnp.ones((B, pos_view.shape[1], half), jnp.float32)
        sin_r = jnp.zeros_like(cos_r)
        cos_1 = jnp.ones((B, 1, half), jnp.float32)
        sin_1 = jnp.zeros((B, 1, half), jnp.float32)
    else:
        cos_r, sin_r = rope_tables_for(pos_view, dh, theta)
        cos_1, sin_1 = rope_tables_for(cur[:, None], dh, theta)
    cos_s = jnp.zeros((B, ps, half), jnp.float32).at[:, :1].set(cos_1)
    sin_s = jnp.zeros((B, ps, half), jnp.float32).at[:, :1].set(sin_1)
    return (jnp.concatenate([bias_r, bias_s], axis=1),
            jnp.concatenate([cos_r, cos_s], axis=1),
            jnp.concatenate([sin_r, sin_s], axis=1))


def latent_decode_paged(q, cache, ptab, r_k, cur, *, theta: float,
                        window: int | None, scale: float,
                        interpret: bool | None = None,
                        self_entry: dict | None = None,
                        k_norm: jax.Array | None = None,
                        norm_eps: float = 1e-6):
    """Paged-pool latent decode: ``cache`` holds page-major {"zk","zv",
    "pos"} pools (n_pages, page_size, ...) and ``ptab`` (B, n_slot_pages)
    maps this batch's slot pages.  The kernel gathers latent pages via
    scalar prefetch; the self entry rides as one extra trailing tile (the
    deferred-write analogue of ``_extend_ring``).  Returns (B, H, r_v)."""
    ps = cache["pos"].shape[1]
    G = cache["zk"].shape[2]
    dh = q.shape[-1]
    pos_view = _paged_pos_view(cache["pos"], ptab)
    bias, cos, sin = _paged_tables(pos_view, cur, window, dh, theta, ps)
    qg = group_queries(q, G)
    o = latent_decode_attention_paged(
        ptab, qg, cache["zk"], cache["zv"], r_k,
        _self_tile(self_entry["zk"], ps), _self_tile(self_entry["zv"], ps),
        cos, sin, bias, scale=scale, interpret=_resolve_interpret(interpret),
        k_norm=k_norm, norm_eps=norm_eps)
    return ungroup_outputs(o)


def dense_decode_paged(q, cache, ptab, cur, *, window: int | None,
                       scale: float, interpret: bool | None = None,
                       self_entry: dict | None = None):
    """Paged dense decode through the paged latent kernel — the same
    degenerate-latent trick as ``dense_decode`` (identity reconstruction,
    cos=1/sin=0 since keys are stored post-RoPE), over page-major
    {"k","v","pos"} pools."""
    ps = cache["pos"].shape[1]
    k = cache["k"]
    Hkv, dh = k.shape[2], k.shape[3]
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    pos_view = _paged_pos_view(cache["pos"], ptab)
    bias, cos, sin = _paged_tables(pos_view, cur, window, dh, None, ps)
    qg = group_queries(q, Hkv)
    o = latent_decode_attention_paged(
        ptab, qg, k, cache["v"], eye,
        _self_tile(self_entry["k"], ps), _self_tile(self_entry["v"], ps),
        cos, sin, bias, scale=scale, interpret=_resolve_interpret(interpret))
    return ungroup_outputs(o)


def flash_prefill(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, block: int = 256,
                  interpret: bool | None = None):
    """Full-sequence flash attention for prefill/training forward paths.

    q: (B, T, H, dh); k: (B, T, Hkv, dh); v: (B, T, Hv, dv) — Hv may be the
    latent group count G.  Arbitrary T (tail tiles padded internally)."""
    return flash_prefill_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block, block_k=block,
        interpret=_resolve_interpret(interpret))


__all__ = [
    "decode_bias", "rope_tables_for", "group_queries", "ungroup_outputs",
    "default_interpret", "latent_decode", "dense_decode", "flash_prefill",
    "latent_decode_paged", "dense_decode_paged",
    "latent_decode_attention", "latent_decode_attention_quant",
    "latent_decode_attention_paged", "flash_prefill_attention",
]
