"""Jit'd convenience wrappers around the Pallas kernels.

These adapt model-layer tensors (cache dicts, position arrays) to kernel
calling conventions, pick block sizes, and resolve interpret mode from the
platform: on CPU/GPU the kernels run through the Pallas interpreter (the
validation mode used by every test); on TPU the same calls lower through
Mosaic.  Pass ``interpret=True/False`` to override.

Decode-side wrappers accept a ``self_entry`` — the current token's K/V (or
latents), which the model keeps out of the ring until after the layer scan
(deferred writes).  The wrapper appends it as an extra ring column at
position ``cur`` before calling the kernel, so the joint softmax over
[cache | self] matches the model's two-part einsum softmax exactly.

Known cost of that design: the concat materializes a ring copy per layer
per step, and when the ring length is a tile multiple the S+1-th column
opens one extra (otherwise dead) key tile — the kernels skip fully-masked
tiles, so the extra tile costs a DMA but no MXU work.  Eliminating the
copy needs write-before-attend (ring writes inside the layer scan), which
trades back the scan-rematerialization cost deferred writes exist to
avoid (EXPERIMENTS.md §Perf iteration 3) — revisit on TPU profiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.latent_decode import (NEG_INF, latent_decode_attention,
                                         latent_decode_attention_mq,
                                         latent_decode_attention_mq_paged,
                                         latent_decode_attention_paged)
from repro.kernels.latent_decode_q import (latent_decode_attention_mq_quant,
                                           latent_decode_attention_quant)
from repro.sharding import rules as R


def default_interpret() -> bool:
    """Interpret mode for the current platform: real lowering only on TPU."""
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def decode_bias(pos: jax.Array, cur: jax.Array, window: int | None) -> jax.Array:
    """Additive (B, S) mask from stored slot positions + current position."""
    valid = (pos >= 0) & (pos <= cur[:, None])
    if window is not None:
        valid &= pos > (cur[:, None] - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def rope_tables_for(pos: jax.Array, dh: int, theta: float):
    """cos/sin (B, S, dh/2) for stored (clamped) positions."""
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.maximum(pos, 0).astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def group_queries(q: jax.Array, num_groups: int) -> jax.Array:
    """(B, H, dh) -> (B, G, Hg, dh) in kernel head order (kv-major)."""
    B, H, dh = q.shape
    return q.reshape(B, num_groups, H // num_groups, dh)


def ungroup_outputs(o: jax.Array) -> jax.Array:
    """(B, G, Hg, rv) -> (B, H, rv)."""
    B, G, Hg, rv = o.shape
    return o.reshape(B, G * Hg, rv)


def _extend_ring(cache: dict, self_entry: dict | None, cur: jax.Array):
    """Append the (deferred-write) current token as one extra ring column.

    cache leaves are (B, S, ...); self_entry leaves are the matching
    (B, ...) slot values.  Returns (arrays, pos) with S+1 columns."""
    pos = cache["pos"]
    arrs = {k: v for k, v in cache.items() if k != "pos"}
    if self_entry is None:
        return arrs, pos
    arrs = {k: jnp.concatenate([v, self_entry[k][:, None].astype(v.dtype)],
                               axis=1)
            for k, v in arrs.items()}
    pos = jnp.concatenate([pos, cur[:, None].astype(pos.dtype)], axis=1)
    return arrs, pos


def _extend_ring_mq(cache: dict, self_entries: dict, pos_q: jax.Array):
    """Multi-query ``_extend_ring``: append the nq deferred verify-window
    tokens as nq extra ring columns.  self_entries leaves are (B, nq, ...)
    — the same layout each leaf has at one column, stacked; pos_q (B, nq)
    are their target positions."""
    pos = cache["pos"]
    arrs = {k: jnp.concatenate([v, self_entries[k].astype(v.dtype)], axis=1)
            for k, v in cache.items() if k != "pos"}
    pos = jnp.concatenate([pos, pos_q.astype(pos.dtype)], axis=1)
    return arrs, pos


def group_queries_mq(q: jax.Array, num_groups: int) -> jax.Array:
    """(B, nq, H, dh) -> (B, G, nq*Hg, dh), rows ordered (query, head) —
    the multi-query kernels' row layout (see latent_decode._mq_kernel)."""
    B, nq, H, dh = q.shape
    hg = H // num_groups
    q = q.reshape(B, nq, num_groups, hg, dh)
    return q.transpose(0, 2, 1, 3, 4).reshape(B, num_groups, nq * hg, dh)


def ungroup_outputs_mq(o: jax.Array, nq: int) -> jax.Array:
    """(B, G, nq*Hg, rv) -> (B, nq, H, rv)."""
    B, G, QHg, rv = o.shape
    hg = QHg // nq
    o = o.reshape(B, G, nq, hg, rv)
    return o.transpose(0, 2, 1, 3, 4).reshape(B, nq, G * hg, rv)


def verify_bias(pos_ext: jax.Array, pos_q: jax.Array, feed_mask: jax.Array,
                window: int | None, self_start: int) -> jax.Array:
    """Additive (B, nq, S_ext) mask for nq verify queries over extended
    columns [ring | self].  Ring-mask semantics apply everywhere — the
    self columns store pos_q, so causality (j >= n) and the window fall
    out of the stored-position compare — then ``feed_mask`` is AND'd onto
    the nq real self columns at ``self_start``.  Logit-level match for
    kv_cache._verify_masks' (ring_mask, self_mask) pair."""
    nq = pos_q.shape[1]
    valid = (pos_ext[:, None, :] >= 0) & (pos_ext[:, None, :] <= pos_q[:, :, None])
    if window is not None:
        valid &= pos_ext[:, None, :] > (pos_q[:, :, None] - window)
    sl = slice(self_start, self_start + nq)
    valid = valid.at[:, :, sl].set(
        valid[:, :, sl] & feed_mask[:, None, :].astype(bool))
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# shard_map routing: the kernels under SPMD
# ---------------------------------------------------------------------------
#
# Under pjit the einsum decode readers get sequence-parallel flash
# attention for free (the softmax over the "model"-sharded S axis becomes
# a psum pair).  A pallas_call cannot ride that: inside pjit it demands
# fully replicated operands, and partial-auto shard_map around it trips
# XLA's manual-subgroup check.  So the kernels go under a FULL-manual
# shard_map over every mesh axis: each shard runs the unmodified kernel
# on its local ring/page slice with ``return_lse`` on, the deferred self
# column is enabled on exactly one "model" shard, and the partial outputs
# merge with the same LSE algebra pjit would have synthesized.

def _seq_shardable(mesh, cols: int) -> bool:
    """Kernel-under-shard_map eligibility: >1 "model" shard and the
    sharded column count (ring length / page size) divides evenly."""
    n = R.kernel_seq_shards(mesh)
    return n > 1 and cols % n == 0


def _merge_partial_softmax(o, m, l):
    """LSE merge of per-shard partial flash outputs across "model": each
    shard's o = acc/l at running max m; reweight by l*exp(m - max) and
    renormalize.  A fully-masked shard has l == 0 and drops out."""
    mg = jax.lax.pmax(m, "model")
    w = l * jnp.exp(m - mg)                        # (B, G, rows, 1)
    num = jax.lax.psum(o.astype(jnp.float32) * w, "model")
    den = jax.lax.psum(w, "model")
    return (num / jnp.maximum(den, 1e-30)).astype(o.dtype)


def _shard_kernel_call(mesh, B: int, main, main_spec, slot, repl, body):
    """Run ``body`` under a full-manual shard_map over the serving mesh.

    main: the cache pytree, sharded by ``main_spec(leaf, batch)`` (ring
    leaves split (batch, model); paged pools split page rows on "model");
    slot: per-slot operands (q, cur, self entries, ...) split on batch
    only; repl: replicated params (R_k, k_norm).  body(main, slot, repl,
    self_on) returns the (o, m, l) partial-softmax triple; ``self_on`` is
    true on exactly one "model" shard so the deferred self token scores
    once.  Returns the merged grouped output, replicated over "model"."""
    batch = R.kernel_batch_axes(mesh, B)
    n_sh = R.kernel_seq_shards(mesh)
    in_specs = (
        jax.tree.map(lambda x: main_spec(x, batch), main),
        jax.tree.map(lambda x: R.kernel_slot_spec(x, batch), slot),
        jax.tree.map(R.kernel_repl_spec, repl),
    )

    def wrapped(main_l, slot_l, repl_l):
        self_on = jax.lax.axis_index("model") == n_sh - 1
        o, m, l = body(main_l, slot_l, repl_l, self_on)
        return _merge_partial_softmax(o, m, l)

    return shard_map(wrapped, mesh, in_specs=in_specs,
                     out_specs=P(batch, None, None, None),
                     check_rep=False)(main, slot, repl)


def _mask_self_cols(bias, self_on, start):
    """-inf the appended self columns unless this shard owns them.
    bias: (B, S) single-query or (B, nq, S) multi-query."""
    if self_on is None:
        return bias
    idx = (slice(None),) * (bias.ndim - 1) + (slice(start, None),)
    return bias.at[idx].set(jnp.where(self_on, bias[idx], NEG_INF))


# ---------------------------------------------------------------------------
# Ring-layout cores + public wrappers
# ---------------------------------------------------------------------------


def _latent_ring_core(qg, arrs, pos, r_k, cur, *, theta, window, scale,
                      block_s, interpret, k_norm, norm_eps,
                      self_on=None, with_lse=False):
    """Ring latent attention over an already-extended cache (grouped in,
    grouped out).  ``self_on``/``with_lse`` serve the shard_map caller:
    keep the appended self column on one shard only, and return the
    (o, m, l) triple for the cross-shard merge."""
    quant = "zk_q" in arrs
    S = pos.shape[1]
    dh = qg.shape[-1]
    cos, sin = rope_tables_for(pos, dh, theta)
    bias = _mask_self_cols(decode_bias(pos, cur, window), self_on, -1)
    kw = dict(scale=scale, block_s=min(block_s, S), interpret=interpret,
              k_norm=k_norm, norm_eps=norm_eps, return_lse=with_lse)
    if quant:
        return latent_decode_attention_quant(
            qg, arrs["zk_q"], arrs["zk_s"], arrs["zv_q"], arrs["zv_s"],
            r_k, cos, sin, bias, **kw)
    return latent_decode_attention(qg, arrs["zk"], arrs["zv"], r_k,
                                   cos, sin, bias, **kw)


def _dense_ring_core(qg, arrs, pos, cur, *, window, scale, block_s,
                     interpret, self_on=None, with_lse=False):
    """Dense ring decode as the degenerate latent case: identity
    reconstruction (r_k = I), identity rotation (keys stored post-RoPE)."""
    k, v = arrs["k"], arrs["v"]
    B, S, Hkv, dh = k.shape
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    ones = jnp.ones((B, S, dh // 2), jnp.float32)
    bias = _mask_self_cols(decode_bias(pos, cur, window), self_on, -1)
    return latent_decode_attention(qg, k, v, eye, ones, jnp.zeros_like(ones),
                                   bias, scale=scale, block_s=min(block_s, S),
                                   interpret=interpret, return_lse=with_lse)


def _latent_ring_core_mq(qg, arrs, pos_ext, r_k, pos_q, feed_mask, *,
                         theta, window, scale, block_s, interpret, k_norm,
                         norm_eps, self_on=None, with_lse=False):
    nq = pos_q.shape[1]
    quant = "zk_q" in arrs
    S = pos_ext.shape[1]
    dh = qg.shape[-1]
    cos, sin = rope_tables_for(pos_ext, dh, theta)
    bias = verify_bias(pos_ext, pos_q, feed_mask, window, S - nq)
    bias = _mask_self_cols(bias, self_on, S - nq)
    kw = dict(scale=scale, block_s=min(block_s, S), interpret=interpret,
              k_norm=k_norm, norm_eps=norm_eps, return_lse=with_lse)
    if quant:
        return latent_decode_attention_mq_quant(
            qg, arrs["zk_q"], arrs["zk_s"], arrs["zv_q"], arrs["zv_s"],
            r_k, cos, sin, bias, **kw)
    return latent_decode_attention_mq(qg, arrs["zk"], arrs["zv"], r_k,
                                      cos, sin, bias, **kw)


def _dense_ring_core_mq(qg, arrs, pos_ext, pos_q, feed_mask, *, window,
                        scale, block_s, interpret, self_on=None,
                        with_lse=False):
    nq = pos_q.shape[1]
    k, v = arrs["k"], arrs["v"]
    B, S, Hkv, dh = k.shape
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    ones = jnp.ones((B, S, dh // 2), jnp.float32)
    bias = verify_bias(pos_ext, pos_q, feed_mask, window, S - nq)
    bias = _mask_self_cols(bias, self_on, S - nq)
    return latent_decode_attention_mq(
        qg, k, v, eye, ones, jnp.zeros_like(ones), bias, scale=scale,
        block_s=min(block_s, S), interpret=interpret, return_lse=with_lse)


def latent_decode(q, cache, r_k, cur, *, theta: float, window: int | None,
                  scale: float, block_s: int = 256, use_kernel: bool = True,
                  interpret: bool | None = None, self_entry: dict | None = None,
                  k_norm: jax.Array | None = None, norm_eps: float = 1e-6,
                  mesh=None):
    """End-to-end latent decode from a model cache dict.

    q: (B, H, dh) post-RoPE grouped-orderable queries;
    cache: {"zk","zv","pos"} — or the int8 ring {"zk_q","zk_s","zv_q",
    "zv_s","pos"} — as produced by the model layer.  ``self_entry`` holds
    the current token's latents in the same (quantized or not) layout.
    With ``mesh`` (and >1 "model" shard dividing the ring length), the
    kernel runs under shard_map on each shard's ring slice with an LSE
    merge across shards.  Returns (B, H, r_v) latent outputs.
    """
    quant = "zk_q" in cache
    G = (cache["zk_q"] if quant else cache["zk"]).shape[2]
    qg = group_queries(q, G)
    itp = _resolve_interpret(interpret)
    if (use_kernel and self_entry is not None
            and _seq_shardable(mesh, cache["pos"].shape[1])):
        def body(cache_l, slot_l, repl_l, self_on):
            qg_l, cur_l, entry_l = slot_l
            r_k_l, kn_l = repl_l
            arrs, pos = _extend_ring(cache_l, entry_l, cur_l)
            return _latent_ring_core(
                qg_l, arrs, pos, r_k_l, cur_l, theta=theta, window=window,
                scale=scale, block_s=block_s, interpret=itp, k_norm=kn_l,
                norm_eps=norm_eps, self_on=self_on, with_lse=True)
        o = _shard_kernel_call(mesh, q.shape[0], cache, R.kernel_ring_spec,
                               (qg, cur, self_entry), (r_k, k_norm), body)
        return ungroup_outputs(o)
    arrs, pos = _extend_ring(cache, self_entry, cur)
    if use_kernel:
        o = _latent_ring_core(qg, arrs, pos, r_k, cur, theta=theta,
                              window=window, scale=scale, block_s=block_s,
                              interpret=itp, k_norm=k_norm, norm_eps=norm_eps)
    else:
        if quant:
            from repro.quant import dequantize
            zk = dequantize(arrs["zk_q"], arrs["zk_s"][..., None])
            zv = dequantize(arrs["zv_q"], arrs["zv_s"][..., None])
        else:
            zk, zv = arrs["zk"], arrs["zv"]
        if k_norm is not None:
            raise NotImplementedError("ref path applies no k-norm")
        dh = q.shape[-1]
        cos, sin = rope_tables_for(pos, dh, theta)
        bias = decode_bias(pos, cur, window)
        o = ref.latent_decode_attention(qg, zk, zv, r_k, cos, sin, bias, scale)
    return ungroup_outputs(o)


def dense_decode(q, cache, cur, *, window: int | None, scale: float,
                 block_s: int = 256, interpret: bool | None = None,
                 self_entry: dict | None = None, mesh=None):
    """Dense-cache decode through the latent kernel.

    The dense ring {"k","v","pos"} is the degenerate latent cache: one kv
    head per group, identity reconstruction (r_k = I), identity rotation
    (keys are stored post-RoPE, so cos=1/sin=0).  q: (B, H, dh) post-RoPE;
    self_entry: {"k","v"} (B, Hkv, dh) post-RoPE/norm.  ``mesh`` shards the
    ring as in :func:`latent_decode`.  Returns (B, H, dh).
    """
    Hkv = cache["k"].shape[2]
    qg = group_queries(q, Hkv)
    itp = _resolve_interpret(interpret)
    if (self_entry is not None
            and _seq_shardable(mesh, cache["pos"].shape[1])):
        def body(cache_l, slot_l, repl_l, self_on):
            qg_l, cur_l, entry_l = slot_l
            arrs, pos = _extend_ring(cache_l, entry_l, cur_l)
            return _dense_ring_core(
                qg_l, arrs, pos, cur_l, window=window, scale=scale,
                block_s=block_s, interpret=itp, self_on=self_on,
                with_lse=True)
        o = _shard_kernel_call(mesh, q.shape[0], cache, R.kernel_ring_spec,
                               (qg, cur, self_entry), (), body)
        return ungroup_outputs(o)
    arrs, pos = _extend_ring(cache, self_entry, cur)
    o = _dense_ring_core(qg, arrs, pos, cur, window=window, scale=scale,
                         block_s=block_s, interpret=itp)
    return ungroup_outputs(o)


def latent_decode_mq(q, cache, r_k, cur, feed_mask, self_entries, *,
                     theta: float, window: int | None, scale: float,
                     block_s: int = 256, interpret: bool | None = None,
                     k_norm: jax.Array | None = None, norm_eps: float = 1e-6,
                     mesh=None):
    """Multi-query (verify-step) latent decode over a ring cache.

    q: (B, nq, H, dh) queries pre-rotated at positions cur..cur+nq-1;
    feed_mask: (B, nq) bool — which candidate tokens were actually fed;
    self_entries: the nq deferred verify-window latents, same leaf layout
    as the cache at leading shape (B, nq, ...).  Scores all nq queries in
    one kernel pass against [ring | nq self columns] with a joint softmax
    matching the einsum verify readers.  Returns (B, nq, H, r_v)."""
    B, nq = feed_mask.shape
    quant = "zk_q" in cache
    G = (cache["zk_q"] if quant else cache["zk"]).shape[2]
    pos_q = cur[:, None] + jnp.arange(nq, dtype=cur.dtype)
    qg = group_queries_mq(q, G)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(cache_l, slot_l, repl_l, self_on):
            qg_l, pos_q_l, feed_l, entries_l = slot_l
            r_k_l, kn_l = repl_l
            arrs, pos_ext = _extend_ring_mq(cache_l, entries_l, pos_q_l)
            return _latent_ring_core_mq(
                qg_l, arrs, pos_ext, r_k_l, pos_q_l, feed_l, theta=theta,
                window=window, scale=scale, block_s=block_s, interpret=itp,
                k_norm=kn_l, norm_eps=norm_eps, self_on=self_on,
                with_lse=True)
        o = _shard_kernel_call(mesh, B, cache, R.kernel_ring_spec,
                               (qg, pos_q, feed_mask, self_entries),
                               (r_k, k_norm), body)
    else:
        arrs, pos_ext = _extend_ring_mq(cache, self_entries, pos_q)
        o = _latent_ring_core_mq(qg, arrs, pos_ext, r_k, pos_q, feed_mask,
                                 theta=theta, window=window, scale=scale,
                                 block_s=block_s, interpret=itp,
                                 k_norm=k_norm, norm_eps=norm_eps)
    return ungroup_outputs_mq(o, nq)


def dense_decode_mq(q, cache, cur, feed_mask, self_entries, *,
                    window: int | None, scale: float, block_s: int = 256,
                    interpret: bool | None = None, mesh=None):
    """Multi-query dense verify decode — degenerate-latent trick over the
    dense ring.  q and self_entries["k"] arrive post-RoPE (rotated at
    cur..cur+nq-1), so the identity tables apply.  Returns (B, nq, H, dh)."""
    B, nq = feed_mask.shape
    Hkv = cache["k"].shape[2]
    pos_q = cur[:, None] + jnp.arange(nq, dtype=cur.dtype)
    qg = group_queries_mq(q, Hkv)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(cache_l, slot_l, repl_l, self_on):
            qg_l, pos_q_l, feed_l, entries_l = slot_l
            arrs, pos_ext = _extend_ring_mq(cache_l, entries_l, pos_q_l)
            return _dense_ring_core_mq(
                qg_l, arrs, pos_ext, pos_q_l, feed_l, window=window,
                scale=scale, block_s=block_s, interpret=itp,
                self_on=self_on, with_lse=True)
        o = _shard_kernel_call(mesh, B, cache, R.kernel_ring_spec,
                               (qg, pos_q, feed_mask, self_entries), (), body)
    else:
        arrs, pos_ext = _extend_ring_mq(cache, self_entries, pos_q)
        o = _dense_ring_core_mq(qg, arrs, pos_ext, pos_q, feed_mask,
                                window=window, scale=scale, block_s=block_s,
                                interpret=itp)
    return ungroup_outputs_mq(o, nq)


def _paged_pos_view(pool_pos: jax.Array, ptab: jax.Array) -> jax.Array:
    """Slot-major (B, n_slot_pages*page_size) positions gathered through the
    page table — int32-cheap; the latents themselves stay page-major and
    only move inside the kernel."""
    B, n_sp = ptab.shape
    ps = pool_pos.shape[1]
    return jnp.take(pool_pos, ptab.reshape(-1), axis=0).reshape(B, n_sp * ps)


def _self_tile(entry: jax.Array, ps: int) -> jax.Array:
    """(B, ...) self entry -> (B, page_size, ...) tile with row 0 real and
    rows 1.. zero — the same [self | padding] block ``pad_ring`` yields
    for the ring kernel when the ring length is a tile multiple."""
    B = entry.shape[0]
    tile = jnp.zeros((B, ps) + entry.shape[1:], entry.dtype)
    return tile.at[:, 0].set(entry)


def _paged_tables(pos_view: jax.Array, cur: jax.Array, window: int | None,
                  dh: int, theta: float | None, ps: int):
    """Slot-major bias/cos/sin covering [table-gathered ring | self tile].

    Self-tile columns: col 0 gets bias 0 and the rotation for position
    ``cur`` (identity when theta is None — dense caches store post-RoPE
    keys), cols 1.. get bias -inf and zero tables, matching ``pad_ring``'s
    padding bitwise."""
    B = cur.shape[0]
    half = dh // 2
    bias_r = decode_bias(pos_view, cur, window)
    bias_s = jnp.full((B, ps), NEG_INF, jnp.float32).at[:, 0].set(0.0)
    if theta is None:
        cos_r = jnp.ones((B, pos_view.shape[1], half), jnp.float32)
        sin_r = jnp.zeros_like(cos_r)
        cos_1 = jnp.ones((B, 1, half), jnp.float32)
        sin_1 = jnp.zeros((B, 1, half), jnp.float32)
    else:
        cos_r, sin_r = rope_tables_for(pos_view, dh, theta)
        cos_1, sin_1 = rope_tables_for(cur[:, None], dh, theta)
    cos_s = jnp.zeros((B, ps, half), jnp.float32).at[:, :1].set(cos_1)
    sin_s = jnp.zeros((B, ps, half), jnp.float32).at[:, :1].set(sin_1)
    return (jnp.concatenate([bias_r, bias_s], axis=1),
            jnp.concatenate([cos_r, cos_s], axis=1),
            jnp.concatenate([sin_r, sin_s], axis=1))


def _self_tiles_mq(entry: jax.Array, ps: int, n_st: int) -> jax.Array:
    """(B, nq, ...) self entries -> (B, n_st*page_size, ...) tiles with
    rows 0..nq-1 real and the rest zero padding."""
    B, nq = entry.shape[:2]
    tiles = jnp.zeros((B, n_st * ps) + entry.shape[2:], entry.dtype)
    return tiles.at[:, :nq].set(entry)


def _mq_paged_setup(pool_pos, ptab, pos_q, feed_mask, window, dh, theta):
    """(n_st, ring_cols, bias, cos, sin) for the multi-query paged
    kernels: slot-major tables over [gathered ring | self tiles], with
    the self tiles' first nq columns carrying pos_q (padding rows get
    pos = -1 -> bias = -inf, same as unmapped slot pages)."""
    B, nq = pos_q.shape
    ps = pool_pos.shape[1]
    n_st = -(-nq // ps)
    pos_view = _paged_pos_view(pool_pos, ptab)
    L = pos_view.shape[1]
    pos_self = jnp.full((B, n_st * ps), -1,
                        pos_view.dtype).at[:, :nq].set(pos_q)
    pos_ext = jnp.concatenate([pos_view, pos_self], axis=1)
    bias = verify_bias(pos_ext, pos_q, feed_mask, window, L)
    half = dh // 2
    if theta is None:
        cos = jnp.ones((B, pos_ext.shape[1], half), jnp.float32)
        sin = jnp.zeros_like(cos)
    else:
        cos, sin = rope_tables_for(pos_ext, dh, theta)
    return n_st, L, bias, cos, sin


def _latent_paged_core(qg, pool, ptab, r_k, cur, entry, *, theta, window,
                       scale, interpret, k_norm, norm_eps,
                       self_on=None, with_lse=False):
    ps = pool["pos"].shape[1]
    dh = qg.shape[-1]
    pos_view = _paged_pos_view(pool["pos"], ptab)
    bias, cos, sin = _paged_tables(pos_view, cur, window, dh, theta, ps)
    bias = _mask_self_cols(bias, self_on, pos_view.shape[1])
    return latent_decode_attention_paged(
        ptab, qg, pool["zk"], pool["zv"], r_k,
        _self_tile(entry["zk"], ps), _self_tile(entry["zv"], ps),
        cos, sin, bias, scale=scale, interpret=interpret,
        k_norm=k_norm, norm_eps=norm_eps, return_lse=with_lse)


def _dense_paged_core(qg, pool, ptab, cur, entry, *, window, scale,
                      interpret, self_on=None, with_lse=False):
    ps = pool["pos"].shape[1]
    k = pool["k"]
    Hkv, dh = k.shape[2], k.shape[3]
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    pos_view = _paged_pos_view(pool["pos"], ptab)
    bias, cos, sin = _paged_tables(pos_view, cur, window, dh, None, ps)
    bias = _mask_self_cols(bias, self_on, pos_view.shape[1])
    return latent_decode_attention_paged(
        ptab, qg, k, pool["v"], eye,
        _self_tile(entry["k"], ps), _self_tile(entry["v"], ps),
        cos, sin, bias, scale=scale, interpret=interpret,
        return_lse=with_lse)


def _latent_paged_core_mq(qg, pool, ptab, r_k, pos_q, feed_mask, entries, *,
                          theta, window, scale, interpret, k_norm, norm_eps,
                          self_on=None, with_lse=False):
    ps = pool["pos"].shape[1]
    dh = qg.shape[-1]
    n_st, L, bias, cos, sin = _mq_paged_setup(
        pool["pos"], ptab, pos_q, feed_mask, window, dh, theta)
    bias = _mask_self_cols(bias, self_on, L)
    return latent_decode_attention_mq_paged(
        ptab, qg, pool["zk"], pool["zv"], r_k,
        _self_tiles_mq(entries["zk"], ps, n_st),
        _self_tiles_mq(entries["zv"], ps, n_st),
        cos, sin, bias, scale=scale, interpret=interpret,
        k_norm=k_norm, norm_eps=norm_eps, return_lse=with_lse)


def _dense_paged_core_mq(qg, pool, ptab, pos_q, feed_mask, entries, *,
                         window, scale, interpret, self_on=None,
                         with_lse=False):
    ps = pool["pos"].shape[1]
    k = pool["k"]
    Hkv, dh = k.shape[2], k.shape[3]
    eye = jnp.broadcast_to(jnp.eye(dh, dtype=k.dtype), (Hkv, dh, dh))
    n_st, L, bias, cos, sin = _mq_paged_setup(
        pool["pos"], ptab, pos_q, feed_mask, window, dh, None)
    bias = _mask_self_cols(bias, self_on, L)
    return latent_decode_attention_mq_paged(
        ptab, qg, k, pool["v"], eye,
        _self_tiles_mq(entries["k"], ps, n_st),
        _self_tiles_mq(entries["v"], ps, n_st),
        cos, sin, bias, scale=scale, interpret=interpret,
        return_lse=with_lse)


def latent_decode_paged(q, cache, ptab, r_k, cur, *, theta: float,
                        window: int | None, scale: float,
                        interpret: bool | None = None,
                        self_entry: dict | None = None,
                        k_norm: jax.Array | None = None,
                        norm_eps: float = 1e-6, mesh=None):
    """Paged-pool latent decode: ``cache`` holds page-major {"zk","zv",
    "pos"} pools (n_pages, page_size, ...) and ``ptab`` (B, n_slot_pages)
    maps this batch's slot pages.  The kernel gathers latent pages via
    scalar prefetch; the self entry rides as one extra trailing tile (the
    deferred-write analogue of ``_extend_ring``).  With ``mesh`` (and >1
    "model" shard dividing page_size), each shard runs on its slice of
    every page's rows — the page table stays global — with the same LSE
    merge as the ring path.  Returns (B, H, r_v)."""
    G = cache["zk"].shape[2]
    qg = group_queries(q, G)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(pool_l, slot_l, repl_l, self_on):
            qg_l, ptab_l, cur_l, entry_l = slot_l
            r_k_l, kn_l = repl_l
            return _latent_paged_core(
                qg_l, pool_l, ptab_l, r_k_l, cur_l, entry_l, theta=theta,
                window=window, scale=scale, interpret=itp, k_norm=kn_l,
                norm_eps=norm_eps, self_on=self_on, with_lse=True)
        o = _shard_kernel_call(mesh, q.shape[0], cache,
                               lambda x, b: R.kernel_pool_spec(x),
                               (qg, ptab, cur, self_entry),
                               (r_k, k_norm), body)
    else:
        o = _latent_paged_core(qg, cache, ptab, r_k, cur, self_entry,
                               theta=theta, window=window, scale=scale,
                               interpret=itp, k_norm=k_norm,
                               norm_eps=norm_eps)
    return ungroup_outputs(o)


def dense_decode_paged(q, cache, ptab, cur, *, window: int | None,
                       scale: float, interpret: bool | None = None,
                       self_entry: dict | None = None, mesh=None):
    """Paged dense decode through the paged latent kernel — the same
    degenerate-latent trick as ``dense_decode`` (identity reconstruction,
    cos=1/sin=0 since keys are stored post-RoPE), over page-major
    {"k","v","pos"} pools."""
    Hkv = cache["k"].shape[2]
    qg = group_queries(q, Hkv)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(pool_l, slot_l, repl_l, self_on):
            qg_l, ptab_l, cur_l, entry_l = slot_l
            return _dense_paged_core(
                qg_l, pool_l, ptab_l, cur_l, entry_l, window=window,
                scale=scale, interpret=itp, self_on=self_on, with_lse=True)
        o = _shard_kernel_call(mesh, q.shape[0], cache,
                               lambda x, b: R.kernel_pool_spec(x),
                               (qg, ptab, cur, self_entry), (), body)
    else:
        o = _dense_paged_core(qg, cache, ptab, cur, self_entry,
                              window=window, scale=scale, interpret=itp)
    return ungroup_outputs(o)


def latent_decode_mq_paged(q, cache, ptab, r_k, cur, feed_mask,
                           self_entries, *, theta: float,
                           window: int | None, scale: float,
                           interpret: bool | None = None,
                           k_norm: jax.Array | None = None,
                           norm_eps: float = 1e-6, mesh=None):
    """Multi-query (verify-step) latent decode over a paged pool — the
    paged counterpart of :func:`latent_decode_mq`: the nq deferred
    verify-window latents ride as ceil(nq/page_size) trailing self tiles.
    Returns (B, nq, H, r_v)."""
    B, nq = feed_mask.shape
    G = cache["zk"].shape[2]
    pos_q = cur[:, None] + jnp.arange(nq, dtype=cur.dtype)
    qg = group_queries_mq(q, G)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(pool_l, slot_l, repl_l, self_on):
            qg_l, ptab_l, pos_q_l, feed_l, entries_l = slot_l
            r_k_l, kn_l = repl_l
            return _latent_paged_core_mq(
                qg_l, pool_l, ptab_l, r_k_l, pos_q_l, feed_l, entries_l,
                theta=theta, window=window, scale=scale, interpret=itp,
                k_norm=kn_l, norm_eps=norm_eps, self_on=self_on,
                with_lse=True)
        o = _shard_kernel_call(mesh, B, cache,
                               lambda x, b: R.kernel_pool_spec(x),
                               (qg, ptab, pos_q, feed_mask, self_entries),
                               (r_k, k_norm), body)
    else:
        o = _latent_paged_core_mq(qg, cache, ptab, r_k, pos_q, feed_mask,
                                  self_entries, theta=theta, window=window,
                                  scale=scale, interpret=itp, k_norm=k_norm,
                                  norm_eps=norm_eps)
    return ungroup_outputs_mq(o, nq)


def dense_decode_mq_paged(q, cache, ptab, cur, feed_mask, self_entries, *,
                          window: int | None, scale: float,
                          interpret: bool | None = None, mesh=None):
    """Multi-query dense verify decode over page-major {"k","v","pos"}
    pools.  Returns (B, nq, H, dh)."""
    B, nq = feed_mask.shape
    Hkv = cache["k"].shape[2]
    pos_q = cur[:, None] + jnp.arange(nq, dtype=cur.dtype)
    qg = group_queries_mq(q, Hkv)
    itp = _resolve_interpret(interpret)
    if _seq_shardable(mesh, cache["pos"].shape[1]):
        def body(pool_l, slot_l, repl_l, self_on):
            qg_l, ptab_l, pos_q_l, feed_l, entries_l = slot_l
            return _dense_paged_core_mq(
                qg_l, pool_l, ptab_l, pos_q_l, feed_l, entries_l,
                window=window, scale=scale, interpret=itp,
                self_on=self_on, with_lse=True)
        o = _shard_kernel_call(mesh, B, cache,
                               lambda x, b: R.kernel_pool_spec(x),
                               (qg, ptab, pos_q, feed_mask, self_entries),
                               (), body)
    else:
        o = _dense_paged_core_mq(qg, cache, ptab, pos_q, feed_mask,
                                 self_entries, window=window, scale=scale,
                                 interpret=itp)
    return ungroup_outputs_mq(o, nq)


def flash_prefill(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, block: int = 256,
                  interpret: bool | None = None):
    """Full-sequence flash attention for prefill/training forward paths.

    q: (B, T, H, dh); k: (B, T, Hkv, dh); v: (B, T, Hv, dv) — Hv may be the
    latent group count G.  Arbitrary T (tail tiles padded internally)."""
    return flash_prefill_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block, block_k=block,
        interpret=_resolve_interpret(interpret))


__all__ = [
    "decode_bias", "verify_bias", "rope_tables_for",
    "group_queries", "ungroup_outputs",
    "group_queries_mq", "ungroup_outputs_mq",
    "default_interpret", "latent_decode", "dense_decode", "flash_prefill",
    "latent_decode_paged", "dense_decode_paged",
    "latent_decode_mq", "dense_decode_mq",
    "latent_decode_mq_paged", "dense_decode_mq_paged",
    "latent_decode_attention", "latent_decode_attention_quant",
    "latent_decode_attention_paged", "latent_decode_attention_mq",
    "latent_decode_attention_mq_quant", "latent_decode_attention_mq_paged",
    "flash_prefill_attention",
]
