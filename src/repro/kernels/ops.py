"""Jit'd convenience wrappers around the Pallas kernels.

These adapt model-layer tensors (cache dicts, position arrays) to kernel
calling conventions and pick block sizes.  ``interpret=True`` runs the
kernel bodies in Python on CPU — the validation mode used by every test;
on a real TPU the same calls lower through Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.latent_decode import latent_decode_attention
from repro.kernels.latent_decode_q import latent_decode_attention_quant


def decode_bias(pos: jax.Array, cur: jax.Array, window: int | None) -> jax.Array:
    """Additive (B, S) mask from stored slot positions + current position."""
    valid = (pos >= 0) & (pos <= cur[:, None])
    if window is not None:
        valid &= pos > (cur[:, None] - window)
    return jnp.where(valid, 0.0, -1e30).astype(jnp.float32)


def rope_tables_for(pos: jax.Array, dh: int, theta: float):
    """cos/sin (B, S, dh/2) for stored (clamped) positions."""
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.maximum(pos, 0).astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def group_queries(q: jax.Array, num_groups: int) -> jax.Array:
    """(B, H, dh) -> (B, G, Hg, dh) in kernel head order (kv-major)."""
    B, H, dh = q.shape
    return q.reshape(B, num_groups, H // num_groups, dh)


def ungroup_outputs(o: jax.Array) -> jax.Array:
    """(B, G, Hg, rv) -> (B, H, rv)."""
    B, G, Hg, rv = o.shape
    return o.reshape(B, G * Hg, rv)


def latent_decode(q, cache, r_k, cur, *, theta: float, window: int | None,
                  scale: float, block_s: int = 256, use_kernel: bool = True,
                  interpret: bool = True):
    """End-to-end latent decode from a model cache dict.

    q: (B, H, dh) post-RoPE grouped-orderable queries;
    cache: {"zk","zv","pos"} as produced by the model layer.
    Returns (B, H, r_v) latent outputs.
    """
    zk, zv, pos = cache["zk"], cache["zv"], cache["pos"]
    B, S, G, _ = zk.shape
    dh = q.shape[-1]
    cos, sin = rope_tables_for(pos, dh, theta)
    bias = decode_bias(pos, cur, window)
    qg = group_queries(q, G)
    if use_kernel:
        o = latent_decode_attention(qg, zk, zv, r_k, cos, sin, bias,
                                    scale=scale, block_s=min(block_s, S),
                                    interpret=interpret)
    else:
        o = ref.latent_decode_attention(qg, zk, zv, r_k, cos, sin, bias, scale)
    return ungroup_outputs(o)


__all__ = [
    "decode_bias", "rope_tables_for", "group_queries", "ungroup_outputs",
    "latent_decode", "latent_decode_attention", "latent_decode_attention_quant",
    "flash_prefill_attention",
]
