"""Pallas TPU kernel: ReCalKV latent-cache flash decode.

The paper's GPU flow reconstructs K into global memory, then runs attention.
The TPU-native version never materializes K in HBM: each grid step streams a
(Sb, r_k) latent tile into VMEM, reconstructs the key tile with an MXU
matmul against the resident R_k factor, applies RoPE from precomputed
cos/sin (stored-position) tables, runs online-softmax flash decoding, and
accumulates A @ z_v directly in value-latent space.  The fused W~_o
projection happens outside (one dense matmul on (B, Hq, r_v)).

Memory traffic per step ~= S * G * (r_k + r_v) bytes — exactly the
compressed cache size; the reconstruction FLOPs ride under the bandwidth
roofline (DESIGN.md §2).

Grid: (B, G, nS) — nS minor-most, so the VMEM scratch (m, l, acc) carries
the online softmax across key tiles of one (batch, group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def maybe_knorm(k, kn_ref, apply_knorm, norm_eps):
    """Per-head RMSNorm on reconstructed keys (qk-norm models store
    pre-norm latents: normalize between reconstruction and RoPE, same as
    the einsum reference path).  k: (Sb, s, dh); kn_ref: (1, dh)."""
    if not apply_knorm:
        return k
    kn = kn_ref[...].astype(jnp.float32)
    ms = jnp.mean(k * k, axis=-1, keepdims=True)
    return k * jax.lax.rsqrt(ms + norm_eps) * (1.0 + kn[None])


def knorm_operand(k_norm, dh):
    """(apply_knorm, kn array) pair for a pallas_call: the flag is trace-
    static, the array is a real operand either way (dummy when absent)."""
    if k_norm is None:
        return False, jnp.zeros((1, dh), jnp.float32)
    return True, k_norm.reshape(1, dh)


def attend_block(q, k, zv, cos, sin, bias, *, scale, s, qpk, dh,
                 m_ref, l_ref, acc_ref):
    """Shared online-softmax update over one reconstructed key tile.

    RoPE the (Sb, s, dh) keys by the stored-position tables, score the
    (s, qpk) query groups, rescale the running (m, l, acc) scratch.  Both
    decode kernels (bf16 and int8 latents) defer here after reconstructing
    (and dequantizing) their tile."""
    half = dh // 2
    c, si_ = cos[:, None, :], sin[:, None, :]          # (Sb, 1, dh/2)
    k1, k2 = k[..., :half], k[..., half:]
    kr = jnp.concatenate([k1 * c - k2 * si_, k2 * c + k1 * si_], axis=-1)

    qg = q.reshape(s, qpk, dh)
    # one MXU matmul per group-slot (s <= 4, unrolled statically)
    scores = jnp.concatenate(
        [qg[i] @ kr[:, i, :].T for i in range(s)], axis=0
    ) * scale                                          # (Hg, Sb)
    scores = scores + bias[None, :]

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])               # (Hg, Sb)
    l_ref[:, 0] = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ zv
    m_ref[:, 0] = m_new


def split_out_refs(rest, return_lse):
    """(mo_ref, lo_ref, m_ref, l_ref, acc_ref) from a kernel's trailing
    refs: with ``return_lse`` the pallas_call has two extra outputs (the
    running max and denominator) ahead of the VMEM scratch."""
    if return_lse:
        return rest
    m_ref, l_ref, acc_ref = rest
    return None, None, m_ref, l_ref, acc_ref


def finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref):
    """Write the finished output (and, for a partial-softmax caller, the
    raw m/l state the cross-shard LSE merge needs).  A fully-masked row
    finishes as exactly 0 (l == 0, acc == 0): under the merge it then
    contributes weight l * exp(m - m_g) == 0."""
    l = jnp.maximum(l_ref[:, :1], 1e-30)
    o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
    if mo_ref is not None:
        mo_ref[0, 0] = m_ref[...]
        lo_ref[0, 0] = l_ref[...]


def _kernel(q_ref, zk_ref, zv_ref, rk_ref, kn_ref, cos_ref, sin_ref, bias_ref,
            o_ref, *rest, scale, s, qpk, dh, n_s,
            apply_knorm, norm_eps, return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)

    # Skip fully-masked key tiles (empty ring regions, internal tail
    # padding): no MXU work, no softmax-state update.
    @pl.when(jnp.max(bias) > NEG_INF * 0.5)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (Hg, dh), Hg = s*qpk
        zk = zk_ref[0, :, 0].astype(jnp.float32)       # (Sb, r_k)
        rk = rk_ref[0].astype(jnp.float32)             # (r_k, s*dh)
        k = zk @ rk                                    # (Sb, s*dh)  reconstruct
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block(q, k, zv_ref[0, :, 0].astype(jnp.float32),
                     cos_ref[0].astype(jnp.float32),
                     sin_ref[0].astype(jnp.float32), bias,
                     scale=scale, s=s, qpk=qpk, dh=dh,
                     m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


def pad_ring(bias: jax.Array, block_s: int, *arrays: jax.Array):
    """Pad the ring (axis 1) up to a tile multiple.  Padded columns get
    bias = -inf (never attended); data arrays are zero-padded.  Returns
    (padded_len, bias, *arrays)."""
    S = bias.shape[1]
    bs = min(block_s, S)
    Sp = -(-S // bs) * bs
    if Sp == S:
        return S, bias, *arrays
    bias = jnp.pad(bias, ((0, 0), (0, Sp - S)), constant_values=NEG_INF)
    arrays = tuple(
        jnp.pad(a, ((0, 0), (0, Sp - S)) + ((0, 0),) * (a.ndim - 2))
        for a in arrays)
    return Sp, bias, *arrays


def lse_outputs(B, G, rows, rv, dtype, return_lse, prefetch=False):
    """(out_shape, out_specs) for a decode kernel: the finished (B, G,
    rows, r_v) output plus — when ``return_lse`` — the raw (m, l) softmax
    state as two (B, G, rows, 1) f32 outputs for a cross-shard merge."""
    if prefetch:
        def omap(b, g, i, pt):
            return (b, g, 0, 0)
    else:
        def omap(b, g, i):
            return (b, g, 0, 0)
    shapes = [jax.ShapeDtypeStruct((B, G, rows, rv), dtype)]
    specs = [pl.BlockSpec((1, 1, rows, rv), omap)]
    if not return_lse:
        return shapes[0], specs[0]
    shapes += [jax.ShapeDtypeStruct((B, G, rows, 1), jnp.float32)] * 2
    specs += [pl.BlockSpec((1, 1, rows, 1), omap)] * 2
    return shapes, specs


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret", "norm_eps",
                     "return_lse"),
)
def latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, *,
                            scale: float, block_s: int = 256,
                            interpret: bool = False,
                            k_norm: jax.Array | None = None,
                            norm_eps: float = 1e-6,
                            return_lse: bool = False):
    """q: (B, G, Hg, dh); zk: (B, S, G, r_k); zv: (B, S, G, r_v);
    r_k: (G, r_k, s*dh); cos/sin: (B, S, dh/2); bias: (B, S).
    Returns (B, G, Hg, r_v) latent outputs (feed to the fused W~_o).

    ``k_norm`` (dh,), when given, applies per-head RMSNorm to the
    reconstructed keys before RoPE (qk-norm models).  S need not divide
    ``block_s``: the tail tile is padded and masked internally.
    ``return_lse`` additionally returns the raw (m, l) online-softmax
    state — (B, G, Hg, 1) f32 each — so a shard_map caller holding only a
    sequence shard of the ring can LSE-merge partial outputs across
    shards (the manual-axes analogue of the einsum path's psum pair)."""
    B, G, Hg, dh = q.shape
    rk = zk.shape[3]
    rv = zv.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, bias.shape[1])
    S, bias, zk, zv, cos, sin = pad_ring(bias, block_s, zk, zv, cos, sin)
    n_s = S // bs
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)

    grid = (B, G, n_s)
    kernel = functools.partial(
        _kernel, scale=scale, s=s, qpk=qpk, dh=dh, n_s=n_s,
        apply_knorm=apply_knorm, norm_eps=norm_eps, return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, Hg, rv, q.dtype, return_lse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i: (0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs), lambda b, g, i: (b, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk, zv, r_k, kn, cos, sin, bias)


# ---------------------------------------------------------------------------
# Multi-query variant: S = spec_depth + 1 verify queries in one pass
# ---------------------------------------------------------------------------
#
# The verify step scores S consecutive queries (positions cur..cur+S-1)
# against the ring plus an S-column causal self block.  Rather than a
# second grid axis, the queries ride as extra ROWS: the q operand is
# (B, G, S*Hg, dh) with rows ordered (query, group-slot, head), the
# (m, l, acc) scratch grows to S*Hg rows, and the bias becomes per-query
# (B, S, cols) — each query carries its own causal/window column mask, so
# the joint softmax over [ring | self] matches kv_cache._joint_softmax at
# the logit level (masks enter as additive -inf bias exactly like the
# einsum reader's where(mask, logits, NEG_INF)).  The self block is
# appended by the wrapper as S extra ring columns (the multi-query
# generalization of the deferred-write self column).


def attend_block_mq(q, k, zv, cos, sin, bias, *, scale, nq, s, qpk, dh,
                    m_ref, l_ref, acc_ref):
    """Multi-query online-softmax update over one reconstructed key tile.

    q: (nq*s*qpk, dh) rows ordered (query, group-slot, head);
    bias: (nq, Sb) — per-QUERY column mask.  Reduces to ``attend_block``
    bit-for-bit at nq = 1 (same per-group-slot MXU matmuls, same running
    (m, l, acc) update over nq*Hg rows)."""
    half = dh // 2
    c, si_ = cos[:, None, :], sin[:, None, :]          # (Sb, 1, dh/2)
    k1, k2 = k[..., :half], k[..., half:]
    kr = jnp.concatenate([k1 * c - k2 * si_, k2 * c + k1 * si_], axis=-1)

    sb = k.shape[0]
    qg = q.reshape(nq, s, qpk, dh)
    scores = jnp.stack(
        [(qg[:, i].reshape(nq * qpk, dh) @ kr[:, i, :].T).reshape(nq, qpk, sb)
         for i in range(s)], axis=1
    ) * scale                                          # (nq, s, qpk, Sb)
    scores = scores + bias[:, None, None, :]
    scores = scores.reshape(nq * s * qpk, sb)          # rows (query, slot, head)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])               # (nq*Hg, Sb)
    l_ref[:, 0] = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ zv
    m_ref[:, 0] = m_new


def _mq_kernel(q_ref, zk_ref, zv_ref, rk_ref, kn_ref, cos_ref, sin_ref,
               bias_ref, o_ref, *rest, scale, nq, s, qpk, dh, n_s,
               apply_knorm, norm_eps, return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)             # (nq, Sb)

    @pl.when(jnp.max(bias) > NEG_INF * 0.5)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (nq*Hg, dh)
        zk = zk_ref[0, :, 0].astype(jnp.float32)
        rk = rk_ref[0].astype(jnp.float32)
        k = zk @ rk
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block_mq(q, k, zv_ref[0, :, 0].astype(jnp.float32),
                        cos_ref[0].astype(jnp.float32),
                        sin_ref[0].astype(jnp.float32), bias,
                        scale=scale, nq=nq, s=s, qpk=qpk, dh=dh,
                        m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


def pad_ring_mq(bias: jax.Array, block_s: int, *arrays: jax.Array):
    """Multi-query ``pad_ring``: bias is (B, nq, S) — padding applies to
    the column axis 2 (and axis 1 of the data arrays)."""
    S = bias.shape[2]
    bs = min(block_s, S)
    Sp = -(-S // bs) * bs
    if Sp == S:
        return S, bias, *arrays
    bias = jnp.pad(bias, ((0, 0), (0, 0), (0, Sp - S)),
                   constant_values=NEG_INF)
    arrays = tuple(
        jnp.pad(a, ((0, 0), (0, Sp - S)) + ((0, 0),) * (a.ndim - 2))
        for a in arrays)
    return Sp, bias, *arrays


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret", "norm_eps",
                     "return_lse"),
)
def latent_decode_attention_mq(q, zk, zv, r_k, cos, sin, bias, *,
                               scale: float, block_s: int = 256,
                               interpret: bool = False,
                               k_norm: jax.Array | None = None,
                               norm_eps: float = 1e-6,
                               return_lse: bool = False):
    """Multi-query latent flash decode.

    q: (B, G, nq*Hg, dh) with rows ordered (query, head) — nq verify
    queries pre-rotated at their target positions; zk/zv: (B, S, G, r)
    where S covers [ring | nq appended self columns]; bias: (B, nq, S)
    per-query additive mask.  Returns (B, G, nq*Hg, r_v), plus the (m, l)
    state when ``return_lse`` (see ``latent_decode_attention``)."""
    B, G, QHg, dh = q.shape
    nq = bias.shape[1]
    Hg = QHg // nq
    rk = zk.shape[3]
    rv = zv.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, bias.shape[2])
    S, bias, zk, zv, cos, sin = pad_ring_mq(bias, block_s, zk, zv, cos, sin)
    n_s = S // bs
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)

    grid = (B, G, n_s)
    kernel = functools.partial(
        _mq_kernel, scale=scale, nq=nq, s=s, qpk=qpk, dh=dh, n_s=n_s,
        apply_knorm=apply_knorm, norm_eps=norm_eps, return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, QHg, rv, q.dtype, return_lse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, QHg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i: (0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, nq, bs), lambda b, g, i: (b, 0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk, zv, r_k, kn, cos, sin, bias)


# ---------------------------------------------------------------------------
# Paged variant: gather stage over page indices via scalar prefetch
# ---------------------------------------------------------------------------
#
# In the paged cache layout the latents live page-major in a shared pool
# (n_pages, page_size, G, r) and a (B, n_slot_pages) int32 page table
# maps each slot-page to its physical page.  The gather is an extension
# of the ring kernel's tail-tile masking: the grid's minor axis walks the
# SLOT's pages in order, and each step's physical DMA source comes from
# the scalar-prefetched table (``PrefetchScalarGridSpec`` — the table is
# resident in SMEM before the grid starts, so block index_maps can read
# it).  The self token occupies one extra trailing tile — the same
# [self | -inf padding] column block ``pad_ring`` would produce for the
# ring kernel at block_s = page_size — so with the ring path tiled at
# page_size the two kernels see bitwise-identical tile sequences and
# produce bitwise-identical outputs.


def _paged_kernel(ptab_ref, q_ref, zk_ref, zv_ref, zks_ref, zvs_ref, rk_ref,
                  kn_ref, cos_ref, sin_ref, bias_ref, o_ref,
                  *rest, scale, s, qpk, dh, n_s,
                  apply_knorm, norm_eps, return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)
    is_self = i_s == n_s - 1

    # Same fully-masked-tile skip as the ring kernel: unmapped slot-pages
    # resolve to the null page (pos = -1 -> bias = -inf) and cost no MXU
    # work.  The self tile's column 0 has bias 0, so it always attends.
    @pl.when(jnp.max(bias) > NEG_INF * 0.5)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        zk = jnp.where(is_self, zks_ref[0, :, 0],
                       zk_ref[0, :, 0]).astype(jnp.float32)
        zv = jnp.where(is_self, zvs_ref[0, :, 0],
                       zv_ref[0, :, 0]).astype(jnp.float32)
        rk = rk_ref[0].astype(jnp.float32)
        k = zk @ rk
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block(q, k, zv, cos_ref[0].astype(jnp.float32),
                     sin_ref[0].astype(jnp.float32), bias,
                     scale=scale, s=s, qpk=qpk, dh=dh,
                     m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "interpret", "norm_eps", "return_lse"),
)
def latent_decode_attention_paged(ptab, q, zk, zv, r_k, zk_self, zv_self,
                                  cos, sin, bias, *, scale: float,
                                  interpret: bool = False,
                                  k_norm: jax.Array | None = None,
                                  norm_eps: float = 1e-6,
                                  return_lse: bool = False):
    """Paged-pool flash decode.

    ptab: (B, n_slot_pages) int32 page table (scalar-prefetched);
    zk/zv: (n_pages, page_size, G, r) page-major pools;
    zk_self/zv_self: (B, page_size, G, r) self tiles — row 0 holds the
    deferred-write latent for position cur, rows 1.. are padding;
    cos/sin/bias: (B, n_slot_pages*page_size + page_size, ...) SLOT-major
    tables (ring columns through the table, then the self tile's columns
    with bias [0, -inf...]).  The wrapper in ``kernels.ops`` builds these
    from the pool's gathered ``pos`` — int32-cheap next to the latents,
    which only ever move page-at-a-time inside the kernel.
    Returns (B, G, Hg, r_v) latent outputs."""
    B, n_sp = ptab.shape
    ps = zk.shape[1]
    _, G, Hg, dh = q.shape
    rk = zk.shape[3]
    rv = zv.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)
    n_s = n_sp + 1                       # slot pages + the self tile

    def pool_map(b, g, i, pt):
        # Clamped on the self step (i == n_sp): the DMA'd page is unused
        # there (the kernel reads the self tile), it just must be in range.
        return (pt[b, jnp.minimum(i, n_sp - 1)], 0, g, 0)

    grid = (B, G, n_s)
    kernel = functools.partial(
        _paged_kernel, scale=scale, s=s, qpk=qpk, dh=dh, n_s=n_s,
        apply_knorm=apply_knorm, norm_eps=norm_eps, return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, Hg, rv, q.dtype, return_lse,
                                       prefetch=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, i, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, ps, 1, rk), pool_map),
            pl.BlockSpec((1, ps, 1, rv), pool_map),
            pl.BlockSpec((1, ps, 1, rk), lambda b, g, i, pt: (b, 0, g, 0)),
            pl.BlockSpec((1, ps, 1, rv), lambda b, g, i, pt: (b, 0, g, 0)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i, pt: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i, pt: (0, 0)),
            pl.BlockSpec((1, ps, half), lambda b, g, i, pt: (b, i, 0)),
            pl.BlockSpec((1, ps, half), lambda b, g, i, pt: (b, i, 0)),
            pl.BlockSpec((1, ps), lambda b, g, i, pt: (b, i)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, rv), jnp.float32),
        ])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(ptab, q, zk, zv, zk_self, zv_self, r_k, kn, cos, sin, bias)


def _mq_paged_kernel(ptab_ref, q_ref, zk_ref, zv_ref, zks_ref, zvs_ref,
                     rk_ref, kn_ref, cos_ref, sin_ref, bias_ref, o_ref,
                     *rest, scale, nq, s, qpk, dh, n_sp, n_s,
                     apply_knorm, norm_eps, return_lse=False):
    i_s = pl.program_id(2)
    mo_ref, lo_ref, m_ref, l_ref, acc_ref = split_out_refs(rest, return_lse)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bias = bias_ref[0].astype(jnp.float32)             # (nq, ps)
    is_self = i_s >= n_sp

    @pl.when(jnp.max(bias) > NEG_INF * 0.5)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (nq*Hg, dh)
        zk = jnp.where(is_self, zks_ref[0, :, 0],
                       zk_ref[0, :, 0]).astype(jnp.float32)
        zv = jnp.where(is_self, zvs_ref[0, :, 0],
                       zv_ref[0, :, 0]).astype(jnp.float32)
        rk = rk_ref[0].astype(jnp.float32)
        k = zk @ rk
        sb = k.shape[0]
        k = maybe_knorm(k.reshape(sb, s, dh), kn_ref, apply_knorm, norm_eps)
        attend_block_mq(q, k, zv, cos_ref[0].astype(jnp.float32),
                        sin_ref[0].astype(jnp.float32), bias,
                        scale=scale, nq=nq, s=s, qpk=qpk, dh=dh,
                        m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(i_s == n_s - 1)
    def _finish():
        finish_tile(o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "interpret", "norm_eps", "return_lse"),
)
def latent_decode_attention_mq_paged(ptab, q, zk, zv, r_k, zk_self, zv_self,
                                     cos, sin, bias, *, scale: float,
                                     interpret: bool = False,
                                     k_norm: jax.Array | None = None,
                                     norm_eps: float = 1e-6,
                                     return_lse: bool = False):
    """Multi-query paged flash decode.

    Same pool/page-table contract as ``latent_decode_attention_paged``;
    the differences are multi-query: q is (B, G, nq*Hg, dh) rows ordered
    (query, head); zk_self/zv_self are (B, n_self_tiles*page_size, G, r)
    with the first nq rows holding the deferred verify-window latents
    (n_self_tiles = ceil(nq / page_size) — usually 1); bias is
    (B, nq, (n_slot_pages + n_self_tiles)*page_size) per-query columns.
    The grid walks slot pages then self tiles; on self steps the pool DMA
    is clamped/ignored and the resident self tile attends instead."""
    B, n_sp = ptab.shape
    ps = zk.shape[1]
    _, G, QHg, dh = q.shape
    nq = bias.shape[1]
    Hg = QHg // nq
    rk = zk.shape[3]
    rv = zv.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    half = dh // 2
    apply_knorm, kn = knorm_operand(k_norm, dh)
    n_st = zk_self.shape[1] // ps        # self tiles (>= ceil(nq/ps))
    n_s = n_sp + n_st

    def pool_map(b, g, i, pt):
        return (pt[b, jnp.minimum(i, n_sp - 1)], 0, g, 0)

    def self_map(b, g, i, pt):
        # Before the self region this indexes tile 0 (DMA'd but unused).
        return (b, jnp.maximum(i - n_sp, 0), g, 0)

    grid = (B, G, n_s)
    kernel = functools.partial(
        _mq_paged_kernel, scale=scale, nq=nq, s=s, qpk=qpk, dh=dh,
        n_sp=n_sp, n_s=n_s, apply_knorm=apply_knorm, norm_eps=norm_eps,
        return_lse=return_lse)
    out_shape, out_specs = lse_outputs(B, G, QHg, rv, q.dtype, return_lse,
                                       prefetch=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, QHg, dh), lambda b, g, i, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, ps, 1, rk), pool_map),
            pl.BlockSpec((1, ps, 1, rv), pool_map),
            pl.BlockSpec((1, ps, 1, rk), self_map),
            pl.BlockSpec((1, ps, 1, rv), self_map),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i, pt: (g, 0, 0)),
            pl.BlockSpec((1, dh), lambda b, g, i, pt: (0, 0)),
            pl.BlockSpec((1, ps, half), lambda b, g, i, pt: (b, i, 0)),
            pl.BlockSpec((1, ps, half), lambda b, g, i, pt: (b, i, 0)),
            pl.BlockSpec((1, nq, ps), lambda b, g, i, pt: (b, 0, i)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, 1), jnp.float32),
            pltpu.VMEM((QHg, rv), jnp.float32),
        ])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(ptab, q, zk, zv, zk_self, zv_self, r_k, kn, cos, sin, bias)
