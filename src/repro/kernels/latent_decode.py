"""Pallas TPU kernel: ReCalKV latent-cache flash decode.

The paper's GPU flow reconstructs K into global memory, then runs attention.
The TPU-native version never materializes K in HBM: each grid step streams a
(Sb, r_k) latent tile into VMEM, reconstructs the key tile with an MXU
matmul against the resident R_k factor, applies RoPE from precomputed
cos/sin (stored-position) tables, runs online-softmax flash decoding, and
accumulates A @ z_v directly in value-latent space.  The fused W~_o
projection happens outside (one dense matmul on (B, Hq, r_v)).

Memory traffic per step ~= S * G * (r_k + r_v) bytes — exactly the
compressed cache size; the reconstruction FLOPs ride under the bandwidth
roofline (DESIGN.md §2).

Grid: (B, G, nS) — nS minor-most, so the VMEM scratch (m, l, acc) carries
the online softmax across key tiles of one (batch, group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, zk_ref, zv_ref, rk_ref, cos_ref, sin_ref, bias_ref,
            o_ref, m_ref, l_ref, acc_ref, *, scale, s, qpk, dh, n_s):
    i_s = pl.program_id(2)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (Hg, dh), Hg = s*qpk
    zk = zk_ref[0, :, 0].astype(jnp.float32)       # (Sb, r_k)
    rk = rk_ref[0].astype(jnp.float32)             # (r_k, s*dh)
    k = zk @ rk                                    # (Sb, s*dh)  reconstruct
    sb = k.shape[0]
    k = k.reshape(sb, s, dh)

    half = dh // 2
    cos = cos_ref[0].astype(jnp.float32)[:, None, :]   # (Sb, 1, dh/2)
    sin = sin_ref[0].astype(jnp.float32)[:, None, :]
    k1, k2 = k[..., :half], k[..., half:]
    kr = jnp.concatenate([k1 * cos - k2 * sin, k2 * cos + k1 * sin], axis=-1)

    qg = q.reshape(s, qpk, dh)
    # one MXU matmul per group-slot (s <= 4, unrolled statically)
    scores = jnp.concatenate(
        [qg[si] @ kr[:, si, :].T for si in range(s)], axis=0
    ) * scale                                       # (Hg, Sb)
    scores = scores + bias_ref[0][None, :].astype(jnp.float32)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])            # (Hg, Sb)
    l_new = l_prev * corr + p.sum(axis=-1)

    zv = zv_ref[0, :, 0].astype(jnp.float32)        # (Sb, r_v)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ zv
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(i_s == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_s", "interpret"),
)
def latent_decode_attention(q, zk, zv, r_k, cos, sin, bias, *,
                            scale: float, block_s: int = 256,
                            interpret: bool = False):
    """q: (B, G, Hg, dh); zk: (B, S, G, r_k); zv: (B, S, G, r_v);
    r_k: (G, r_k, s*dh); cos/sin: (B, S, dh/2); bias: (B, S).
    Returns (B, G, Hg, r_v) latent outputs (feed to the fused W~_o)."""
    B, G, Hg, dh = q.shape
    S, rk = zk.shape[1], zk.shape[3]
    rv = zv.shape[3]
    sdh = r_k.shape[-1]
    s = sdh // dh
    qpk = Hg // s
    bs = min(block_s, S)
    if S % bs:
        raise ValueError(f"S={S} not divisible by block_s={bs}")
    n_s = S // bs
    half = dh // 2

    grid = (B, G, n_s)
    kernel = functools.partial(
        _kernel, scale=scale, s=s, qpk=qpk, dh=dh, n_s=n_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Hg, dh), lambda b, g, i: (b, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, rk), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, bs, 1, rv), lambda b, g, i: (b, i, g, 0)),
            pl.BlockSpec((1, rk, sdh), lambda b, g, i: (g, 0, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs, half), lambda b, g, i: (b, i, 0)),
            pl.BlockSpec((1, bs), lambda b, g, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, rv), lambda b, g, i: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, Hg, rv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, rv), jnp.float32),
        ],
        interpret=interpret,
    )(q, zk, zv, r_k, cos, sin, bias)
