"""Pallas TPU kernels for the compute hot-spots ReCalKV touches.

  latent_decode    ReCalKV flash decode over the latent cache (in-VMEM key
                   reconstruction — never materializes K in HBM)
  latent_decode_q  the same, over int8 latents (Table-4 quantized cache)
  flash_prefill    causal / sliding-window flash attention

Each kernel has a pure-jnp oracle in ref.py and a jit wrapper in ops.py;
the model's ``attn_backend="pallas"`` paths call the ops wrappers.
Interpret mode is platform-derived (ops.default_interpret): Python-level
validation off-TPU, Mosaic lowering on TPU.
"""

from repro.kernels.flash_prefill import flash_prefill_attention
from repro.kernels.latent_decode import latent_decode_attention
from repro.kernels.latent_decode_q import latent_decode_attention_quant
from repro.kernels.ops import (default_interpret, dense_decode, flash_prefill,
                               latent_decode)

__all__ = [
    "default_interpret",
    "dense_decode",
    "flash_prefill",
    "flash_prefill_attention",
    "latent_decode",
    "latent_decode_attention",
    "latent_decode_attention_quant",
]
