"""Fisher-information-guided compression-ratio allocation (Palu-style).

The paper (§3.4, Algorithm 1 lines 4-5) follows Palu: estimate the empirical
Fisher information of each K/V projection layer from calibration gradients,

    F(W) = sum_i  (dL/dW)_i^2        (diagonal empirical Fisher, summed)

and allocate *more rank* (a gentler compression ratio) to layers with higher
Fisher score, subject to a global target cache budget.

Allocation is a water-filling problem: find per-layer keep-ratios rho_l in
[rho_min, rho_max] proportional to normalized importance w_l = F_l^alpha
such that sum_l rho_l * n_l = target_ratio * sum_l n_l (n_l = layer cache
width).  We solve it with a scaling + clip + redistribute loop, then round
each rank to a TPU-friendly multiple.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RankAllocation:
    """Per-layer keep-ratios and ranks for one projection kind (K or V)."""

    ratios: tuple[float, ...]          # per-layer keep ratio in (0, 1]
    ranks: tuple[int, ...]             # per-group rank, rounded
    fisher: tuple[float, ...]          # the scores that produced them

    def mean_ratio(self) -> float:
        return float(np.mean(self.ratios))


def empirical_fisher(
    loss_fn: Callable[..., jax.Array],
    params,
    batches: Sequence,
) -> dict:
    """Diagonal empirical Fisher of ``params`` under ``loss_fn``.

    loss_fn(params, batch) -> scalar.  Returns a pytree matching ``params``
    with summed squared gradients accumulated over ``batches``.
    """
    grad_fn = jax.jit(jax.grad(loss_fn))
    fisher = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    for batch in batches:
        g = grad_fn(params, batch)
        fisher = jax.tree.map(
            lambda f, gi: f + gi.astype(jnp.float32) ** 2, fisher, g
        )
    return fisher


def layer_scores(fisher_tree: Mapping[str, jax.Array]) -> dict[str, float]:
    """Collapse each layer's Fisher tensor to a scalar importance score."""
    return {k: float(jnp.sum(v)) for k, v in fisher_tree.items()}


def allocate_ratios(
    scores: Sequence[float],
    target_ratio: float,
    *,
    alpha: float = 0.5,
    rho_min: float = 0.0625,
    rho_max: float = 1.0,
    max_iters: int = 64,
) -> list[float]:
    """Water-filling: keep-ratios proportional to scores^alpha, meeting the
    global budget exactly (up to clipping feasibility).

    ``target_ratio`` is the *kept* fraction of the cache (1 - compression).
    """
    n = len(scores)
    if n == 0:
        return []
    if not (0.0 < target_ratio <= 1.0):
        raise ValueError(f"target_ratio must be in (0, 1], got {target_ratio}")
    s = np.asarray(scores, dtype=np.float64)
    s = np.maximum(s, 1e-30) ** alpha
    w = s / s.mean()

    lo_feasible = rho_min
    hi_feasible = rho_max
    if not (lo_feasible <= target_ratio <= hi_feasible):
        # Budget outside the clip box: everything saturates.
        rho = np.full(n, np.clip(target_ratio, rho_min, rho_max))
        return rho.tolist()

    rho = np.clip(target_ratio * w, rho_min, rho_max)
    for _ in range(max_iters):
        deficit = target_ratio * n - rho.sum()
        if abs(deficit) < 1e-9 * n:
            break
        free = (rho > rho_min + 1e-12) if deficit < 0 else (rho < rho_max - 1e-12)
        if not free.any():
            break
        rho[free] += deficit / free.sum()
        rho = np.clip(rho, rho_min, rho_max)
    return rho.tolist()


def ratios_to_ranks(
    ratios: Sequence[float],
    group_width: int,
    *,
    multiple: int = 8,
    min_rank: int = 8,
) -> list[int]:
    """Convert keep-ratios to per-group ranks rounded for MXU tiling."""
    ranks = []
    for rho in ratios:
        r = int(round(group_width * rho / multiple)) * multiple
        ranks.append(max(min_rank, min(group_width, r)))
    return ranks


def allocate(
    scores: Sequence[float],
    target_ratio: float,
    group_width: int,
    **kwargs,
) -> RankAllocation:
    """Scores -> ratios -> rounded ranks, re-deriving the achieved ratios."""
    ratios = allocate_ratios(scores, target_ratio, **{
        k: v for k, v in kwargs.items() if k in ("alpha", "rho_min", "rho_max")
    })
    ranks = ratios_to_ranks(
        ratios, group_width,
        multiple=kwargs.get("multiple", 8), min_rank=kwargs.get("min_rank", 8),
    )
    achieved = [r / group_width for r in ranks]
    return RankAllocation(
        ratios=tuple(achieved), ranks=tuple(ranks), fisher=tuple(float(x) for x in scores)
    )
