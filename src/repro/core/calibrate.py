"""Offline Calibration (OCMF, first half) -- alternating closed-form updates.

Minimizes the *data-weighted* approximation error (paper eq. (6))

    E(L, R) = || X L R - X W ||_F^2,        C := X^T X

by alternating the two normal-equation solutions (eqs. (7)-(8), transposed to
our row-vector convention):

    R <- (L^T C L + lam I)^{-1} L^T C W        (data-weighted)
    L <- W R^T (R R^T + lam I)^{-1}            (C cancels exactly)

Each step is the exact minimizer of the biconvex objective in one factor, so
E is monotonically non-increasing.  A tiny ridge term keeps the solves
well-posed when the calibration covariance is rank-deficient (documented
deviation #3 in DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.svd import LowRankFactors


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    factors: LowRankFactors
    initial_error: jax.Array
    final_error: jax.Array
    errors: tuple[float, ...]  # per-iteration trace (python floats)


def _ridge(mat: jax.Array, lam_scale: float) -> jax.Array:
    k = mat.shape[0]
    lam = lam_scale * (jnp.trace(mat) / k + 1e-30)
    return mat + lam * jnp.eye(k, dtype=mat.dtype)


def weighted_error(W: jax.Array, L: jax.Array, R: jax.Array, C: jax.Array) -> jax.Array:
    D = (L @ R - W).astype(jnp.float32)
    return jnp.einsum("ij,ik,kj->", D, C.astype(jnp.float32), D)


def calibrate_factors(
    W: jax.Array,
    cov: jax.Array,
    init: LowRankFactors,
    num_iters: int = 8,
    lam_scale: float = 1e-6,
) -> CalibrationResult:
    """Alternating least-squares refinement of (L, R) against cov = X^T X."""
    W = W.astype(jnp.float32)
    C = cov.astype(jnp.float32)
    L, R = init.L.astype(jnp.float32), init.R.astype(jnp.float32)

    e0 = weighted_error(W, L, R, C)
    trace = [float(e0)]
    CW = C @ W
    for _ in range(num_iters):
        # R-step: exact weighted minimizer given L.
        LtCL = _ridge(L.T @ C @ L, lam_scale)
        R = jnp.linalg.solve(LtCL, L.T @ CW)
        # L-step: weighted minimizer given R (data term cancels).
        RRt = _ridge(R @ R.T, lam_scale)
        L = jnp.linalg.solve(RRt, R @ W.T).T
        trace.append(float(weighted_error(W, L, R, C)))

    return CalibrationResult(
        factors=LowRankFactors(L=L, R=R),
        initial_error=e0,
        final_error=jnp.asarray(trace[-1]),
        errors=tuple(trace),
    )
