"""ReCalKV core — the paper's contribution as composable JAX modules.

Offline (compression-time) components:
  svd        truncated / whitened / grouped SVD primitives
  cka        head-similarity metrics (covariance-based linear CKA)
  reorder    greedy HSR head grouping
  calibrate  alternating closed-form factor refinement (OCMF part 1)
  fusion     block fusion of R_v into W_o + permutation folding (OCMF part 2)
  fisher     empirical Fisher + water-filling rank allocation
  pipeline   Algorithm 1 end-to-end
"""

from repro.core.calibrate import CalibrationResult, calibrate_factors
from repro.core.cka import head_cka_from_cov, head_cka_matrix, linear_cka
from repro.core.fisher import RankAllocation, allocate, allocate_ratios, empirical_fisher
from repro.core.fusion import (
    fold_head_permutation,
    fuse_output_projection,
    fused_output_apply,
    inverse_permutation,
)
from repro.core.pipeline import (
    AttnWeights,
    CalibStats,
    CompressedAttention,
    ReCalKVConfig,
    collect_stats,
    compress_attention_layer,
    compress_model_layers,
    merge_stats,
)
from repro.core.reorder import greedy_group_heads, groups_to_permutation, identity_groups
from repro.core.svd import (
    LowRankFactors,
    effective_rank_for_ratio,
    grouped_svd,
    truncated_svd,
    whitened_svd,
)

__all__ = [
    "AttnWeights", "CalibStats", "CalibrationResult", "CompressedAttention",
    "LowRankFactors", "RankAllocation", "ReCalKVConfig",
    "allocate", "allocate_ratios", "calibrate_factors", "collect_stats",
    "compress_attention_layer", "compress_model_layers",
    "effective_rank_for_ratio", "empirical_fisher", "fold_head_permutation",
    "fuse_output_projection", "fused_output_apply", "greedy_group_heads",
    "grouped_svd", "groups_to_permutation", "head_cka_from_cov",
    "head_cka_matrix", "identity_groups", "inverse_permutation", "linear_cka",
    "merge_stats", "truncated_svd", "whitened_svd",
]
