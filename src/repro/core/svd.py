"""Truncated / whitened / grouped SVD primitives for ReCalKV.

Conventions (row-vector, JAX-style):
  activations  X  : (N, m)   -- N calibration tokens, m = input feature dim
  weight       W  : (m, n)   -- y = x @ W
  factors      W ~= L @ R,  L: (m, r), R: (r, n); the cache stores z = x @ L.

Whitening follows SVD-LLM: minimizing ||X W - X L R||_F is equivalent to
plain truncated SVD of (S^T W) where C = X^T X = S S^T (Cholesky).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LowRankFactors:
    """A rank-r factorization W ~= L @ R."""

    L: jax.Array  # (m, r)
    R: jax.Array  # (r, n)

    @property
    def rank(self) -> int:
        return self.L.shape[1]

    def reconstruct(self) -> jax.Array:
        return self.L @ self.R


def truncated_svd(W: jax.Array, rank: int) -> LowRankFactors:
    """Plain Eckart-Young truncated SVD, split symmetrically (eq. (1))."""
    W = W.astype(jnp.float32)
    U, s, Vt = jnp.linalg.svd(W, full_matrices=False)
    r = int(rank)
    sqrt_s = jnp.sqrt(s[:r])
    L = U[:, :r] * sqrt_s[None, :]
    R = sqrt_s[:, None] * Vt[:r, :]
    return LowRankFactors(L=L, R=R)


def _safe_cholesky(C: jax.Array, eps_scale: float = 1e-6) -> jax.Array:
    """Cholesky of a PSD covariance with adaptive diagonal jitter."""
    C = C.astype(jnp.float32)
    m = C.shape[0]
    jitter = eps_scale * (jnp.trace(C) / m + 1e-30)
    return jnp.linalg.cholesky(C + jitter * jnp.eye(m, dtype=C.dtype))


def whitened_svd(W: jax.Array, cov: jax.Array, rank: int) -> LowRankFactors:
    """Data-aware truncated SVD (SVD-LLM whitening).

    Minimizes ||X W - X L R||_F exactly for the rank budget, where
    cov = X^T X.  With cov = I this reduces to ``truncated_svd``.
    """
    W = W.astype(jnp.float32)
    S = _safe_cholesky(cov)  # C = S S^T, S lower-triangular
    SW = S.T @ W  # whitened weight
    U, s, Vt = jnp.linalg.svd(SW, full_matrices=False)
    r = int(rank)
    sqrt_s = jnp.sqrt(s[:r])
    # L = S^{-T} U_r sqrt(Sigma_r): solve S^T L = U_r * sqrt_s
    L = jax.scipy.linalg.solve_triangular(
        S.T, U[:, :r] * sqrt_s[None, :], lower=False
    )
    R = sqrt_s[:, None] * Vt[:r, :]
    return LowRankFactors(L=L, R=R)


def data_weighted_error(W: jax.Array, f: LowRankFactors, cov: jax.Array) -> jax.Array:
    """||X W - X L R||_F^2 expressed through cov = X^T X (no data needed)."""
    D = (f.L @ f.R - W).astype(jnp.float32)
    return jnp.einsum("ij,ik,kj->", D, cov.astype(jnp.float32), D)


def frobenius_error(W: jax.Array, f: LowRankFactors) -> jax.Array:
    return jnp.sum((f.reconstruct() - W.astype(jnp.float32)) ** 2)


def head_columns(W: jax.Array, num_heads: int) -> jax.Array:
    """Reshape (m, H*d_h) -> (H, m, d_h)."""
    m, n = W.shape
    d_h = n // num_heads
    return W.reshape(m, num_heads, d_h).transpose(1, 0, 2)


def grouped_svd(
    W: jax.Array,
    groups: Sequence[Sequence[int]],
    ranks: Sequence[int],
    num_heads: int,
    cov: jax.Array | None = None,
) -> list[LowRankFactors]:
    """Grouped low-rank decomposition (Palu G-LRD, eq. (4)).

    ``groups`` is a list of head-index tuples (the HSR ordering); for group g
    the columns of the listed heads are concatenated and factorized to
    ``ranks[g]``.  Whitened when ``cov`` is given.
    """
    per_head = head_columns(W, num_heads)  # (H, m, d_h)
    out: list[LowRankFactors] = []
    for g, r in zip(groups, ranks, strict=True):
        Wg = jnp.concatenate([per_head[h] for h in g], axis=1)  # (m, s*d_h)
        if cov is not None:
            out.append(whitened_svd(Wg, cov, r))
        else:
            out.append(truncated_svd(Wg, r))
    return out


def stack_group_factors(factors: Sequence[LowRankFactors]) -> tuple[jax.Array, jax.Array]:
    """Stack uniform-rank group factors: (G, m, r) and (G, r, s*d_h)."""
    ranks = {f.rank for f in factors}
    if len(ranks) != 1:
        raise ValueError(f"groups must share a rank to stack, got {sorted(ranks)}")
    L = jnp.stack([f.L for f in factors])
    R = jnp.stack([f.R for f in factors])
    return L, R


def effective_rank_for_ratio(
    width: int, keep_ratio: float, multiple: int = 8, min_rank: int = 8
) -> int:
    """Rank giving a ``keep_ratio`` cache footprint, rounded for TPU tiling."""
    r = int(round(width * keep_ratio / multiple)) * multiple
    return max(min_rank, min(width, r))
