"""Head-wise Similarity-aware Reordering (HSR) -- greedy grouping.

Given the (H, H) CKA similarity matrix, greedily seed each group with the
most-similar unassigned pair, then fill remaining slots with the head whose
*average* similarity to the current group members is highest (paper §3.2).

The result is a list of head-index groups; concatenated it is a permutation
of range(H).  At runtime the permutation is folded into the weights
(W_q / W_k column order and fused W~_o row order), so decode never permutes
activations -- the "inverse reordering" of Fig. 3 happens offline.
"""

from __future__ import annotations

import numpy as np


def greedy_group_heads(similarity: np.ndarray, group_size: int) -> list[list[int]]:
    """Greedy HSR grouping.  ``similarity`` is symmetric (H, H)."""
    S = np.asarray(similarity, dtype=np.float64)
    H = S.shape[0]
    if H % group_size != 0:
        raise ValueError(f"{H} heads not divisible by group size {group_size}")
    if group_size == 1:
        return [[h] for h in range(H)]

    unassigned = set(range(H))
    masked = S.copy()
    np.fill_diagonal(masked, -np.inf)
    groups: list[list[int]] = []
    while unassigned:
        # Seed: the highest-similarity unassigned pair.
        idx = sorted(unassigned)
        sub = masked[np.ix_(idx, idx)]
        i, j = np.unravel_index(np.argmax(sub), sub.shape)
        group = [idx[i], idx[j]]
        unassigned -= set(group)
        # Fill: maximize mean similarity to current members.
        while len(group) < group_size and unassigned:
            cand = sorted(unassigned)
            scores = S[np.ix_(group, cand)].mean(axis=0)
            pick = cand[int(np.argmax(scores))]
            group.append(pick)
            unassigned.remove(pick)
        groups.append(sorted(group))
    return groups


def identity_groups(num_heads: int, group_size: int) -> list[list[int]]:
    """Palu-style contiguous grouping (the no-HSR baseline)."""
    if num_heads % group_size != 0:
        raise ValueError(f"{num_heads} heads not divisible by {group_size}")
    return [
        list(range(g * group_size, (g + 1) * group_size))
        for g in range(num_heads // group_size)
    ]


def groups_to_permutation(groups: list[list[int]]) -> np.ndarray:
    """Flatten groups into a head permutation (new order -> old index)."""
    perm = np.concatenate([np.asarray(g, dtype=np.int64) for g in groups])
    H = perm.shape[0]
    if sorted(perm.tolist()) != list(range(H)):
        raise ValueError("groups do not form a permutation")
    return perm


def within_group_similarity(similarity: np.ndarray, groups: list[list[int]]) -> float:
    """Mean pairwise CKA inside groups -- the quantity HSR maximizes."""
    total, count = 0.0, 0
    for g in groups:
        for a in range(len(g)):
            for b in range(a + 1, len(g)):
                total += float(similarity[g[a], g[b]])
                count += 1
    return total / max(count, 1)
