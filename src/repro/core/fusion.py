"""Matrix Fusion (OCMF, second half) + offline head-permutation folding.

Fusion (paper eq. (9)-(11)). With grouped value factors
``W_v[:, group g] ~= L_g R_g`` the cache stores the group latent
``z_g = x @ L_g`` and per-query-head attention output lives in latent space:
``o_h = A_h @ z_{g(h)}  (r_v floats)``.  The exact identity

    Output = sum_h (A_h V_h) W_o^{(h)}
           = sum_h (A_h z_{g(h)}) (R^{(h)} W_o^{(h)})

lets us precompute the *block-fused* output projection

    W~_o[h] = R_{g(h)}[:, head-slice of h] @ W_o[rows of head h]   (r_v, d)

so decode never reconstructs values (DESIGN.md §1.1: fusion must keep the
per-head block structure; a dense ``R_v W_o`` only type-checks single-head).

Permutation folding (Fig. 3, done offline).  HSR yields a kv-head permutation
``perm`` (new position -> old head index).  Instead of permuting activations
at runtime we permute the *weights* once:

  * W_k columns: kv-head blocks reordered by ``perm``;
  * W_v columns: same ``perm`` (K and V share the kv-head index, so value
    grouping rides on the same ordering — see DESIGN.md deviation #1);
  * W_q columns: each kv head serves a contiguous block of q_per_kv query
    heads; blocks follow ``perm``;
  * W_o rows: query-head blocks follow the same order.

Attention is permutation-equivariant over heads (the head sum commutes), so
the folded model is numerically identical up to float reassociation.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def head_slices(n_heads: int, d_h: int) -> list[slice]:
    return [slice(h * d_h, (h + 1) * d_h) for h in range(n_heads)]


def fuse_output_projection(
    R_v: jax.Array,            # (G, r_v, s * d_h) grouped value right-factors
    W_o: jax.Array,            # (H_q * d_h, d_model)
    num_q_heads: int,
    num_kv_heads: int,
) -> jax.Array:
    """Block-fused output projection W~_o: (H_q, r_v, d_model).

    Query head h reads value kv-head ``kv(h) = h // q_per_kv``; that head
    lives in group ``g = kv(h) // s`` at within-group slot ``j = kv(h) % s``.
    """
    G, r_v, sdh = R_v.shape
    d_model = W_o.shape[1]
    d_h = W_o.shape[0] // num_q_heads
    s = sdh // d_h
    if G * s != num_kv_heads:
        raise ValueError(f"R_v groups {G}x{s} != kv heads {num_kv_heads}")
    q_per_kv = num_q_heads // num_kv_heads

    blocks = []
    for h in range(num_q_heads):
        kv = h // q_per_kv
        g, j = kv // s, kv % s
        Rh = R_v[g, :, j * d_h : (j + 1) * d_h]          # (r_v, d_h)
        Woh = W_o[h * d_h : (h + 1) * d_h, :]            # (d_h, d_model)
        blocks.append(Rh @ Woh)
    return jnp.stack(blocks)                              # (H_q, r_v, d_model)


def fused_output_apply(o_latent: jax.Array, W_o_fused: jax.Array) -> jax.Array:
    """Apply the fused projection: (..., H_q, r_v) x (H_q, r_v, d) -> (..., d)."""
    return jnp.einsum("...hr,hrd->...d", o_latent, W_o_fused)


# ---------------------------------------------------------------------------
# Offline permutation folding
# ---------------------------------------------------------------------------

def _permute_blocks(W: jax.Array, perm: np.ndarray, block: int, axis: int) -> jax.Array:
    """Permute contiguous ``block``-sized chunks of ``W`` along ``axis``."""
    n = W.shape[axis]
    if n % block != 0:
        raise ValueError(f"axis size {n} not divisible by block {block}")
    nb = n // block
    if len(perm) != nb:
        raise ValueError(f"perm length {len(perm)} != num blocks {nb}")
    shape = list(W.shape)
    shape[axis : axis + 1] = [nb, block]
    Wb = W.reshape(shape)
    Wp = jnp.take(Wb, jnp.asarray(perm), axis=axis)
    return Wp.reshape(W.shape)


def fold_head_permutation(
    W_q: jax.Array,            # (d_model, H_q * d_h)
    W_k: jax.Array,            # (d_model, H_kv * d_h)
    W_v: jax.Array,            # (d_model, H_kv * d_h)
    W_o: jax.Array,            # (H_q * d_h, d_model)
    perm: Sequence[int],       # kv-head permutation, new position -> old index
    num_q_heads: int,
    num_kv_heads: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Bake the HSR kv-head permutation into the attention weights."""
    perm = np.asarray(perm, dtype=np.int64)
    d_h = W_k.shape[1] // num_kv_heads
    q_per_kv = num_q_heads // num_kv_heads
    Wk = _permute_blocks(W_k, perm, d_h, axis=1)
    Wv = _permute_blocks(W_v, perm, d_h, axis=1)
    # Query heads move in kv-sized blocks of q_per_kv heads.
    Wq = _permute_blocks(W_q, perm, q_per_kv * d_h, axis=1)
    Wo = _permute_blocks(W_o, perm, q_per_kv * d_h, axis=0)
    return Wq, Wk, Wv, Wo


def inverse_permutation(perm: Sequence[int]) -> np.ndarray:
    perm = np.asarray(perm, dtype=np.int64)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv
