"""Centered Kernel Alignment between attention-head representations.

We use the feature-space form of *linear* CKA (Kornblith et al., 2019):

    CKA(X, Y) = ||Yc^T Xc||_F^2 / (||Xc^T Xc||_F ||Yc^T Yc||_F)

which is identical to the Gram/HSIC formulation in the paper (eqs. (2)-(3))
but avoids materializing N x N Gram matrices for N calibration tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_cka(X: jax.Array, Y: jax.Array) -> jax.Array:
    """CKA between two representation matrices (N, d1), (N, d2)."""
    X = X.astype(jnp.float32)
    Y = Y.astype(jnp.float32)
    Xc = X - X.mean(axis=0, keepdims=True)
    Yc = Y - Y.mean(axis=0, keepdims=True)
    hsic_xy = jnp.sum((Yc.T @ Xc) ** 2)
    hsic_xx = jnp.sqrt(jnp.sum((Xc.T @ Xc) ** 2))
    hsic_yy = jnp.sqrt(jnp.sum((Yc.T @ Yc) ** 2))
    return hsic_xy / (hsic_xx * hsic_yy + 1e-12)


def head_cka_from_cov(W: jax.Array, cov_centered: jax.Array, num_heads: int) -> jax.Array:
    """Pairwise head CKA computed from the *centered input covariance* only.

    For per-head key features ``Z_h = Xc @ W_h`` (Xc token-centered), the
    linear-CKA cross term is ``||Z_j^T Z_i||_F^2 = ||W_i^T C W_j||_F^2`` with
    ``C = Xc^T Xc``.  This avoids ever materializing the (N, d_h) features --
    the calibration pass only accumulates C (d_model, d_model).

    W: (d_model, H * d_h) key projection;  cov_centered: (d_model, d_model).
    Returns the symmetric (H, H) CKA matrix with unit diagonal.
    """
    C = cov_centered.astype(jnp.float32)
    m, n = W.shape
    d_h = n // num_heads
    Wh = W.astype(jnp.float32).reshape(m, num_heads, d_h).transpose(1, 0, 2)  # (H, m, d_h)
    CW = jnp.einsum("mk,hkd->hmd", C, Wh)          # (H, m, d_h) = C @ W_h
    G = jnp.einsum("imd,jme->ijde", Wh, CW)        # (H, H, d_h, d_h) = W_i^T C W_j
    cross = jnp.sum(G**2, axis=(2, 3))             # (H, H)
    norms = jnp.sqrt(jnp.diagonal(cross))
    return cross / (norms[:, None] * norms[None, :] + 1e-12)


def head_cka_matrix(head_reps: jax.Array) -> jax.Array:
    """Pairwise CKA similarity matrix (eq. (5)).

    head_reps: (H, N, d_h) -- per-head key representations on calibration
    tokens.  Returns a symmetric (H, H) matrix with unit diagonal.

    Vectorized: for centered per-head features Zh, CKA(i, j) depends on the
    cross products Zi^T Zj; we compute all H^2 of them in one einsum.
    """
    Z = head_reps.astype(jnp.float32)
    Z = Z - Z.mean(axis=1, keepdims=True)  # center over tokens
    # cross[i, j] = ||Zj^T Zi||_F^2  (symmetric in i, j)
    G = jnp.einsum("ind,jne->ijde", Z, Z)  # (H, H, d, d) cross-covariances
    cross = jnp.sum(G**2, axis=(2, 3))  # (H, H)
    norms = jnp.sqrt(jnp.diagonal(cross))  # ||Zi^T Zi||_F
    return cross / (norms[:, None] * norms[None, :] + 1e-12)
