"""ReCalKV Algorithm 1 — end-to-end post-training compression pipeline.

Consumes per-layer attention weights + calibration statistics, produces
``CompressedAttention`` weight bundles that the model zoo plugs into its
latent-cache decode path.  Everything here runs *offline* (compression
time); the artifacts it emits add zero runtime branching.

Pipeline per layer (paper Algorithm 1):
  Keys   : CKA(head sim) -> greedy HSR grouping -> fold permutation into
           (W_q, W_k, W_v, W_o) -> grouped (whitened) SVD -> (L_k, R_k)
  Values : grouped SVD (key-aligned groups, DESIGN.md §1.1) -> offline
           ALS calibration -> block fusion of R_v into W_o -> (L_v, W~_o)
  Ranks  : Fisher-guided water-filling across layers (fisher.py)

Calibration statistics are summarized as second moments (cov = X^T X plus
the token mean), so the capture pass is O(d_model^2) memory per layer --
no activations are retained (see cka.head_cka_from_cov).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as _calibrate
from repro.core import cka as _cka
from repro.core import fisher as _fisher
from repro.core import fusion as _fusion
from repro.core import reorder as _reorder
from repro.core import svd as _svd


@dataclasses.dataclass(frozen=True)
class ReCalKVConfig:
    """Knobs for the compression pipeline.

    ``keep_ratio`` is the *kept* fraction of KV-cache bytes; the paper's
    "50% compression ratio" is ``keep_ratio=0.5``.
    """

    keep_ratio: float = 0.5
    group_size: int = 4
    use_hsr: bool = True            # CKA reordering for key groups
    use_calibration: bool = True    # ALS refinement of value factors
    use_whitening: bool = True      # SVD-LLM whitening before truncation
    use_fisher: bool = True         # per-layer rank allocation
    calib_iters: int = 8
    rank_multiple: int = 8
    min_rank: int = 8
    alpha: float = 0.5
    rho_min: float = 0.0625
    rho_max: float = 1.0

    def effective_group_size(self, num_kv_heads: int) -> int:
        return max(1, min(self.group_size, num_kv_heads))

    def rank_for_width(self, width: int) -> int:
        """Uniform rank for a ``width``-column group honoring the full rank
        policy (keep ratio, tiling multiple, floor)."""
        return _svd.effective_rank_for_ratio(
            width, self.keep_ratio, self.rank_multiple, self.min_rank)


@dataclasses.dataclass(frozen=True)
class AttnWeights:
    """Dense attention weights for one layer (row-vector convention)."""

    W_q: jax.Array   # (d_model, H_q * d_h)
    W_k: jax.Array   # (d_model, H_kv * d_h)
    W_v: jax.Array   # (d_model, H_kv * d_h)
    W_o: jax.Array   # (H_q * d_h, d_model)
    num_q_heads: int
    num_kv_heads: int

    @property
    def d_head(self) -> int:
        return self.W_k.shape[1] // self.num_kv_heads


@dataclasses.dataclass(frozen=True)
class CalibStats:
    """Second-moment summary of one layer's attention input activations."""

    cov: jax.Array     # (d_model, d_model) = X^T X (uncentered)
    mean: jax.Array    # (d_model,)
    count: int         # number of tokens accumulated

    def centered_cov(self) -> jax.Array:
        mu = self.mean.astype(jnp.float32)
        return self.cov.astype(jnp.float32) - self.count * jnp.outer(mu, mu)

    @classmethod
    def identity(cls, d_model: int) -> "CalibStats":
        return cls(cov=jnp.eye(d_model, dtype=jnp.float32),
                   mean=jnp.zeros((d_model,), jnp.float32), count=1)


def collect_stats(activations: jax.Array) -> CalibStats:
    """Summarize a (N, d_model) activation matrix."""
    X = activations.reshape(-1, activations.shape[-1]).astype(jnp.float32)
    return CalibStats(cov=X.T @ X, mean=X.mean(axis=0), count=X.shape[0])


def merge_stats(a: CalibStats, b: CalibStats) -> CalibStats:
    n = a.count + b.count
    return CalibStats(
        cov=a.cov + b.cov,
        mean=(a.mean * a.count + b.mean * b.count) / n,
        count=n,
    )


@dataclasses.dataclass(frozen=True)
class CompressedAttention:
    """Artifacts replacing one layer's dense K/V path.

    The kv-head permutation is already folded into every weight here;
    runtime code never permutes activations.
    """

    W_q: jax.Array        # (d_model, H_q * d_h)   permuted query projection
    L_k: jax.Array        # (G, d_model, r_k)      key latent down-projection
    R_k: jax.Array        # (G, r_k, s * d_h)      key reconstruction factor
    L_v: jax.Array        # (G, d_model, r_v)      value latent down-projection
    W_o_fused: jax.Array  # (H_q, r_v, d_model)    R_v folded into W_o
    perm: tuple[int, ...]  # kv-head permutation that was folded in
    rank_k: int
    rank_v: int
    num_q_heads: int
    num_kv_heads: int
    group_size: int

    @property
    def num_groups(self) -> int:
        return self.L_k.shape[0]

    def cache_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        return self.num_groups * (self.rank_k + self.rank_v) * dtype_bytes

    def dense_cache_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        d_h = self.W_q.shape[1] // self.num_q_heads
        return 2 * self.num_kv_heads * d_h * dtype_bytes


def compress_attention_layer(
    w: AttnWeights,
    stats: CalibStats,
    cfg: ReCalKVConfig,
    rank_k: int,
    rank_v: int,
) -> CompressedAttention:
    """Run HSR + OCMF on a single attention layer."""
    s = cfg.effective_group_size(w.num_kv_heads)
    H_kv = w.num_kv_heads
    if H_kv % s:
        raise ValueError(f"kv heads {H_kv} not divisible by group size {s}")
    cov = stats.cov.astype(jnp.float32)

    # --- Keys: HSR grouping -------------------------------------------------
    if cfg.use_hsr and s > 1:
        sim = np.asarray(_cka.head_cka_from_cov(w.W_k, stats.centered_cov(), H_kv))
        groups = _reorder.greedy_group_heads(sim, s)
    else:
        groups = _reorder.identity_groups(H_kv, s)
    perm = _reorder.groups_to_permutation(groups)

    # Fold the permutation into the weights; groups are contiguous afterwards.
    W_q, W_k, W_v, W_o = _fusion.fold_head_permutation(
        w.W_q, w.W_k, w.W_v, w.W_o, perm, w.num_q_heads, w.num_kv_heads
    )
    contiguous = _reorder.identity_groups(H_kv, s)

    # --- Keys: grouped (whitened) SVD ---------------------------------------
    k_factors = _svd.grouped_svd(
        W_k, contiguous, [rank_k] * len(contiguous), H_kv,
        cov=cov if cfg.use_whitening else None,
    )
    L_k, R_k = _svd.stack_group_factors(k_factors)

    # --- Values: grouped SVD + offline calibration --------------------------
    v_factors = _svd.grouped_svd(
        W_v, contiguous, [rank_v] * len(contiguous), H_kv,
        cov=cov if cfg.use_whitening else None,
    )
    if cfg.use_calibration:
        per_head = _svd.head_columns(W_v, H_kv)
        calibrated = []
        for g, f in zip(contiguous, v_factors, strict=True):
            Wg = jnp.concatenate([per_head[h] for h in g], axis=1)
            res = _calibrate.calibrate_factors(
                Wg, cov, f, num_iters=cfg.calib_iters
            )
            calibrated.append(res.factors)
        v_factors = calibrated
    L_v, R_v = _svd.stack_group_factors(v_factors)

    # --- Values: fuse R_v into the output projection ------------------------
    W_o_fused = _fusion.fuse_output_projection(
        R_v, W_o, w.num_q_heads, w.num_kv_heads
    )

    return CompressedAttention(
        W_q=W_q, L_k=L_k, R_k=R_k, L_v=L_v, W_o_fused=W_o_fused,
        perm=tuple(int(p) for p in perm),
        rank_k=int(rank_k), rank_v=int(rank_v),
        num_q_heads=w.num_q_heads, num_kv_heads=w.num_kv_heads, group_size=s,
    )


def allocate_layer_ranks(
    cfg: ReCalKVConfig,
    num_layers: int,
    group_width: int,
    fisher_k: Sequence[float] | None = None,
    fisher_v: Sequence[float] | None = None,
) -> tuple[list[int], list[int]]:
    """Fisher-guided per-layer rank allocation for K and V (Algorithm 1 l.4-5)."""
    if not cfg.use_fisher or fisher_k is None or fisher_v is None:
        r = cfg.rank_for_width(group_width)
        return [r] * num_layers, [r] * num_layers
    kw = dict(alpha=cfg.alpha, rho_min=cfg.rho_min, rho_max=cfg.rho_max,
              multiple=cfg.rank_multiple, min_rank=cfg.min_rank)
    alloc_k = _fisher.allocate(fisher_k, cfg.keep_ratio, group_width, **kw)
    alloc_v = _fisher.allocate(fisher_v, cfg.keep_ratio, group_width, **kw)
    return list(alloc_k.ranks), list(alloc_v.ranks)


def compress_model_layers(
    layers: Sequence[AttnWeights],
    stats: Sequence[CalibStats],
    cfg: ReCalKVConfig,
    fisher_k: Sequence[float] | None = None,
    fisher_v: Sequence[float] | None = None,
) -> list[CompressedAttention]:
    """Algorithm 1 over all attention layers of a model."""
    if len(layers) != len(stats):
        raise ValueError("one CalibStats required per layer")
    if not layers:
        return []
    w0 = layers[0]
    s = cfg.effective_group_size(w0.num_kv_heads)
    group_width = s * w0.d_head
    ranks_k, ranks_v = allocate_layer_ranks(
        cfg, len(layers), group_width, fisher_k, fisher_v
    )
    return [
        compress_attention_layer(w, st, cfg, rk, rv)
        for w, st, rk, rv in zip(layers, stats, ranks_k, ranks_v, strict=True)
    ]
