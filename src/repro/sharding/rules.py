"""Rule-based PartitionSpec assignment for every pytree in the system.

Philosophy: *best-effort preference lists* per leaf name.  Each rule is an
ordered list of (mesh_axis, dim) assignments; an assignment is applied only
if the dim is divisible by the axis size (and, for the FSDP axis, only if
the leaf is big enough to be worth gathering).  Whatever doesn't fit stays
replicated — so the same rules drive every architecture, including the
awkward ones (36-head MHA, 1500-frame cross caches), without special cases.

Key choices (see DESIGN.md §2/§7):
  * TP ("model") shards attention heads / FFN width / MoE experts / vocab.
  * FSDP ("data") shards a second dim of large parameters; XLA inserts the
    per-layer all-gather / reduce-scatter pairs.
  * Decode caches shard the SEQUENCE axis over "model" — kv-head counts are
    never divisible by 16, but S always is.  Under pjit this yields
    sequence-parallel flash decoding automatically: the softmax reduction
    over the sharded S axis becomes the (max, sum) psum pair (the LSE
    merge), and the latent A @ z_v contraction psums a tiny (B, H, r_v).
    This also makes batch=1 long_500k decode 16-way parallel.
  * Multi-pod: "pod" joins the batch axes (pure DP across pods); params
    stay pod-replicated unless enormous (the 671B case is reported in
    EXPERIMENTS.md with pod-sharded optimizer state).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (axis, dim) preference lists.  dim indexes the *logical* tensor; leaves
# under the scanned "blocks" subtree carry a leading n_periods dim that the
# resolver skips automatically.
_OUT = (("model", -1), ("data", 0))      # y = x @ W: shard W's output dim
_IN = (("model", 0), ("data", -1))       # shard W's input (contraction) dim

PARAM_RULES: dict[str, tuple] = {
    # embeddings / head
    "embed": (("model", 0), ("data", 1)),
    "lm_head": (("model", 1), ("data", 0)),
    # attention
    "wq": _OUT, "wk": _OUT, "wv": _OUT,
    "wo": _IN,
    # MLA
    "wq_a": _OUT, "wq_b": _OUT, "wkv_a": _OUT, "wkv_b": _OUT,
    # ReCalKV latent factors: small; replicated (the cache shards on S)
    "l_k": (), "r_k": (), "l_v": (),
    "wo_fused": (("model", 0), ("data", 2)),
    # dense FFN
    "wi": (("model", -1), ("data", 0)),
    "wg": (("model", -1), ("data", 0)),
    # mamba
    "in_proj": _OUT, "x_proj": _IN, "dt_proj": _OUT, "out_proj": _IN,
    "conv_w": (("model", -1),),
    "A_log": (("model", 0),),
    # rglru
    "in_main": _OUT, "in_gate": _OUT, "w_a": _IN, "w_x": _IN,
    # router: tiny, and its output feeds a global top-k -> replicate
    "router": (),
}

# 3D MoE expert weights: experts over model (EP), fsdp over dim1.
MOE_RULES = {
    "wi": (("model", 0), ("data", 1)),
    "wg": (("model", 0), ("data", 1)),
    "wo": (("model", 0), ("data", 1)),
}

# Name-based cache rules, layout-agnostic by construction: ring leaves
# are (slots, max_len, ...) and paged pool leaves are (n_pages,
# page_size, ...), so the same (dim0 over batch, dim1 over model) specs
# shard the ring slot x sequence and the pool page-major x page-offset.
# The paged page table itself is a carry leaf (see ``carry_specs``:
# slot dim 0 over batch, page indices replicated — they address pages
# whose shards every device can gather locally along its model slice).
CACHE_RULES: dict[str, tuple] = {
    "k": (("batch", 0), ("model", 1)),
    "v": (("batch", 0), ("model", 1)),
    "zk": (("batch", 0), ("model", 1)),
    "zv": (("batch", 0), ("model", 1)),
    "pos": (("batch", 0), ("model", 1)),
    "ckv": (("batch", 0), ("model", 1)),
    "krope": (("batch", 0), ("model", 1)),
    "h": (("batch", 0), ("model", 1)),
    "conv": (("batch", 0), ("model", 2)),
}

FSDP_THRESHOLD_BYTES = 1 << 24          # 16 MiB (post-TP) triggers FSDP
ZERO3_THRESHOLD_BYTES = 1 << 28         # 256 MiB: FSDP spans pods too
                                        # (671B-class experts; ZeRO-3 across
                                        # the slower inter-pod links is the
                                        # only way optimizer state fits)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# ---------------------------------------------------------------------------
# Manual-axes (shard_map) specs for the Pallas decode kernels
# ---------------------------------------------------------------------------
#
# Under pjit, CACHE_RULES shards the ring slot x sequence and XLA derives
# the einsum readers' collectives automatically — but a pallas_call is
# opaque to the SPMD partitioner, so the kernel path runs it per-shard
# under a FULL-manual ``shard_map`` and merges the partial softmaxes by
# hand (pmax/psum LSE merge over "model").  These helpers produce the
# in/out PartitionSpecs for that call so they cannot drift from
# CACHE_RULES: ring leaves (B, L, ...) split exactly like the resident
# cache (slot over the batch axes, sequence over "model"), slot-major
# carry leaves (B, ...) split on the slot dim only, and paged pool
# leaves (n_pages, page_size, ...) keep the page dim whole on every
# shard (the page table holds global page ids) while the in-page offset
# splits over "model".


def kernel_seq_shards(mesh: Mesh | None) -> int:
    """How many ways the kernel ring's sequence axis shards ("model")."""
    if mesh is None or "model" not in mesh.shape:
        return 1
    return int(mesh.shape["model"])


def kernel_batch_axes(mesh: Mesh, n: int):
    """Batch-dim axes for a manual-axes kernel call: the ("pod", "data")
    product when it divides ``n``, else None (replicated batch)."""
    names = batch_axes(mesh)
    total = math.prod(mesh.shape[a] for a in names)
    if not names or total <= 1 or n % total:
        return None
    return names if len(names) > 1 else names[0]


def kernel_slot_spec(leaf, batch) -> P:
    """(B, ...) slot-major operand: slot dim over ``batch``, rest whole."""
    return P(batch, *([None] * (leaf.ndim - 1)))


def kernel_ring_spec(leaf, batch) -> P:
    """(B, L, ...) ring leaf: slot over ``batch``, sequence over "model"."""
    return P(batch, "model", *([None] * (leaf.ndim - 2)))


def kernel_pool_spec(leaf) -> P:
    """(n_pages, page_size, ...) pool leaf: pages whole per shard (global
    page ids stay valid everywhere), in-page offset over "model"."""
    return P(None, "model", *([None] * (leaf.ndim - 2)))


def kernel_repl_spec(leaf) -> P:
    """Fully replicated operand (factors, norms, scalars)."""
    return P(*([None] * leaf.ndim))


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def _leaf_bytes(shape, dtype) -> int:
    return math.prod(shape) * np.dtype(dtype).itemsize


def _resolve(prefs, shape, dtype, mesh: Mesh, offset: int):
    """Apply a preference list with divisibility + size checks."""
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    size = _leaf_bytes(shape, dtype)
    for axis, dim in prefs:
        # logical dim -> physical dim (skip the scan-stack leading axis)
        d = dim + offset if dim >= 0 else ndim + dim
        if not (offset <= d < ndim) or spec[d] is not None:
            continue
        if axis == "batch":
            names = batch_axes(mesh)
            n = math.prod(mesh.shape[a] for a in names)
            if names and shape[d] % n == 0:
                spec[d] = names if len(names) > 1 else names[0]
                size //= n
            continue
        if axis not in mesh.shape:
            continue
        n = mesh.shape[axis]
        if axis == "data":
            if size < FSDP_THRESHOLD_BYTES:
                continue  # FSDP only pays off for big leaves
            if size >= ZERO3_THRESHOLD_BYTES and "pod" in mesh.shape:
                np_ = n * mesh.shape["pod"]
                if shape[d] % np_ == 0:
                    spec[d] = ("data", "pod")
                    size //= np_
                    continue
        if shape[d] % n != 0:
            continue
        spec[d] = axis
        size //= n
    return P(*spec)


def head_grains(cfg) -> dict[str, int]:
    """Model-axis sharding grain per attention projection, from a
    (duck-typed) ModelConfig.

    These projections' outputs are reshaped per head (or sliced into
    latent + rope parts) and fed through qk-norm / RoPE: a "model" tile
    narrower than the grain splits a head's rotation pairs across devices
    — useless for tensor parallelism (every score matmul contracts over
    the head dim) and a resharding hazard inside fused decode loops.
    For MLA, wkv_a's whole output (latent ‖ rope slice) is one grain: it
    is rmsnorm'd and rope'd as a unit, so TP never splits it."""
    mla = getattr(cfg, "mla", None)
    if mla is not None:
        return {"wq_b": mla.qk_nope_dim + mla.qk_rope_dim,
                "wkv_a": mla.kv_lora_rank + mla.qk_rope_dim,
                "wkv_b": mla.qk_nope_dim + mla.v_head_dim}
    return dict.fromkeys(("wq", "wk", "wv"), cfg.d_head)


def _spec_for(path, leaf, mesh: Mesh, rules, default=(), grains=None):
    names = _path_names(path)
    name = names[-1]
    # scanned stack: params/caches under top-level "blocks" carry (n_periods,)
    offset = 1 if (names and names[0] == "blocks") else 0
    shape, dtype = leaf.shape, leaf.dtype
    if rules is CACHE_RULES:
        prefs = rules.get(name, (("batch", 0),))
    else:
        if name in MOE_RULES and len(shape) - offset == 3 and "mlp" in names:
            prefs = MOE_RULES[name]
        else:
            prefs = rules.get(name, default)
    if len(shape) - offset < 1 or not prefs:
        return P()
    grain = grains.get(name) if grains else None
    if (grain and "model" in mesh.shape
            and shape[-1] % (mesh.shape["model"] * grain)):
        prefs = tuple(p for p in prefs if p != ("model", -1))
    return _resolve(prefs, shape, dtype, mesh, offset)


def param_specs(params, mesh: Mesh, grains: dict[str, int] | None = None):
    """PartitionSpec tree for a parameter pytree (shapes or arrays).

    ``grains`` (see :func:`head_grains`) enforces head-grain TP on
    attention projections when the ModelConfig is known — e.g. the
    serving engine passes ``head_grains(cfg)``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, PARAM_RULES,
                                     grains=grains), params)


def cache_specs(caches, mesh: Mesh):
    """PartitionSpec tree for decode caches (sequence-sharded rings)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, CACHE_RULES), caches)


def opt_specs(opt_state, params_spec, mesh: Mesh):
    """Optimizer state mirrors parameter sharding; scalars replicate."""
    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("mu", "nu", "residual"):
            sub = jax.tree_util.tree_map_with_path(
                lambda p, l: _spec_for(p, l, mesh, PARAM_RULES), leaf)
            return sub
        return P()
    out = {}
    for k, v in opt_state.items():
        if k in ("mu", "nu", "residual"):
            out[k] = jax.tree_util.tree_map_with_path(
                lambda p, l: _spec_for(p, l, mesh, PARAM_RULES), v)
        else:
            out[k] = P()
    return out


def carry_specs(carry, mesh: Mesh):
    """Specs for the serving engine's device carry (last-token, cur,
    active flags, per-slot PRNG keys, sampler knobs, ingest buffer, and
    the paged layout's slot -> physical-page table): dim 0 of every leaf
    is the SLOT axis, sharded over the batch axes when divisible; all
    other dims replicated.  Together with CACHE_RULES (slot over batch,
    sequence/page-offset over model) this keeps admission, harvest,
    sampling and chunked-prefill ingest transfer-free on a mesh — each
    addressable shard owns whole slots."""
    def one(path, leaf):
        if len(getattr(leaf, "shape", ())) < 1:
            return P()
        return _resolve((("batch", 0),), leaf.shape, leaf.dtype, mesh, 0)
    return jax.tree_util.tree_map_with_path(one, carry)


def slot_stacked_spec(n_slots: int, mesh: Mesh, lead_dims: int = 1) -> P:
    """Spec for per-window stacked outputs like toks/emits (steps, B):
    ``lead_dims`` replicated axes, then the slot axis over the batch
    axes."""
    names = batch_axes(mesh)
    n = math.prod(mesh.shape[a] for a in names)
    if not names or n_slots % n:
        return P()
    dp = names if len(names) > 1 else names[0]
    return P(*([None] * lead_dims), dp)


def stage_shardings(mesh: Mesh, carry, stage_cache=None):
    """NamedSharding tree for the continuous-batching staging queue
    ``{"seq", "rows"[, "cache"]}``: the per-row carry states shard like
    the carry itself (slot dim 0 over batch axes — stage row q feeds
    slot rows, so keeping both on the same layout makes the in-scan
    install a gather/scatter XLA already knows how to move), the tiny
    seq keys replicate, and a ring-layout stage cache follows
    CACHE_RULES exactly like the resident cache it is copied into."""
    repl = NamedSharding(mesh, P())
    sh = {"seq": repl, "rows": to_named(carry_specs(carry, mesh), mesh)}
    if stage_cache is not None:
        sh["cache"] = to_named(cache_specs(stage_cache, mesh), mesh)
    return sh


def window_shardings(mesh: Mesh, params, cache, carry,
                     grains: dict[str, int] | None = None, *,
                     param_shardings=None, cache_shardings=None,
                     draft_params=None, draft_cache=None,
                     draft_param_shardings=None,
                     draft_cache_shardings=None, spec_outputs=False,
                     stage=None):
    """(in_shardings, out_shardings) for the serving engine's fused decode
    window ``window(params, cache, carry) -> (cache, carry, toks, emits,
    n_active)``.

    Arguments may be arrays, numpy arrays, or ShapeDtypeStructs — only
    shape/dtype are read.  Params follow PARAM_RULES (TP heads / FSDP,
    head-grained via ``grains``), cache rings follow CACHE_RULES (slot x
    sequence), carry leaves follow carry_specs (slot axis — the
    speculative accept mask, key chain and fed-token history are ordinary
    slot-sharded leaves here); the stacked (steps, B[, S]) token/emit
    outputs shard their slot dim and the per-iteration active-slot count
    replicates.  Callers that already derived the param/cache
    NamedSharding trees (the engine does, for device_put) pass them via
    ``param_shardings``/``cache_shardings`` so the jit's in_shardings
    cannot diverge from actual placement.

    Speculative windows reuse the same rules: ``spec_outputs`` appends
    the stacked accepted/proposed counters, and a layer-fraction draft
    (``draft_params``/``draft_cache``) threads a second param/cache pair
    through — window(params, draft_params, cache, draft_cache, carry) ->
    (cache, draft_cache, carry, toks, emits, accepted, proposed,
    n_active).  No new collective patterns: the draft trees follow
    PARAM_RULES/CACHE_RULES verbatim.

    ``stage`` (the continuous-batching staging tree, see
    :func:`stage_shardings`) appends a 4th input and splices the carried
    swap bookkeeping — window(..., carry, stage) -> (cache, carry, seq,
    swap_slot, swap_iter, toks, emits, [acc, prop,] n_active)."""
    ps = (param_shardings if param_shardings is not None
          else to_named(param_specs(params, mesh, grains=grains), mesh))
    cs = (cache_shardings if cache_shardings is not None
          else to_named(cache_specs(cache, mesh), mesh))
    ss = to_named(carry_specs(carry, mesh), mesh)
    n_slots = jax.tree.leaves(carry)[0].shape[0]
    ts = NamedSharding(mesh, slot_stacked_spec(n_slots, mesh))
    repl = NamedSharding(mesh, P())
    if draft_cache is not None:
        dps = (draft_param_shardings if draft_param_shardings is not None
               else to_named(param_specs(draft_params, mesh, grains=grains),
                             mesh))
        dcs = (draft_cache_shardings if draft_cache_shardings is not None
               else to_named(cache_specs(draft_cache, mesh), mesh))
        if stage is not None:
            raise ValueError(
                "continuous batching does not support the layer-fraction "
                "draft (its ring has no staged twin)")
        return ((ps, dps, cs, dcs, ss),
                (cs, dcs, ss, ts, ts, ts, ts, repl))
    outs = (ts, ts, ts, ts) if spec_outputs else (ts, ts)
    if stage is not None:
        stage_sh = stage_shardings(
            mesh, carry, stage_cache=stage.get("cache"))
        return ((ps, cs, ss, stage_sh),
                (cs, ss, repl, repl, repl) + outs + (repl,))
    return (ps, cs, ss), (cs, ss) + outs + (repl,)


def batch_specs(batch, mesh: Mesh):
    """Inputs: leading dim over (pod, data)."""
    names = batch_axes(mesh)
    dp = names if len(names) > 1 else (names[0] if names else None)

    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        n = math.prod(mesh.shape[a] for a in batch_axes(mesh))
        if leaf.shape[0] % n == 0 and dp is not None:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, batch)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
