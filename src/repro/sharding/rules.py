"""Rule-based PartitionSpec assignment for every pytree in the system.

Philosophy: *best-effort preference lists* per leaf name.  Each rule is an
ordered list of (mesh_axis, dim) assignments; an assignment is applied only
if the dim is divisible by the axis size (and, for the FSDP axis, only if
the leaf is big enough to be worth gathering).  Whatever doesn't fit stays
replicated — so the same rules drive every architecture, including the
awkward ones (36-head MHA, 1500-frame cross caches), without special cases.

Key choices (see DESIGN.md §2/§7):
  * TP ("model") shards attention heads / FFN width / MoE experts / vocab.
  * FSDP ("data") shards a second dim of large parameters; XLA inserts the
    per-layer all-gather / reduce-scatter pairs.
  * Decode caches shard the SEQUENCE axis over "model" — kv-head counts are
    never divisible by 16, but S always is.  Under pjit this yields
    sequence-parallel flash decoding automatically: the softmax reduction
    over the sharded S axis becomes the (max, sum) psum pair (the LSE
    merge), and the latent A @ z_v contraction psums a tiny (B, H, r_v).
    This also makes batch=1 long_500k decode 16-way parallel.
  * Multi-pod: "pod" joins the batch axes (pure DP across pods); params
    stay pod-replicated unless enormous (the 671B case is reported in
    EXPERIMENTS.md with pod-sharded optimizer state).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (axis, dim) preference lists.  dim indexes the *logical* tensor; leaves
# under the scanned "blocks" subtree carry a leading n_periods dim that the
# resolver skips automatically.
_OUT = (("model", -1), ("data", 0))      # y = x @ W: shard W's output dim
_IN = (("model", 0), ("data", -1))       # shard W's input (contraction) dim

PARAM_RULES: dict[str, tuple] = {
    # embeddings / head
    "embed": (("model", 0), ("data", 1)),
    "lm_head": (("model", 1), ("data", 0)),
    # attention
    "wq": _OUT, "wk": _OUT, "wv": _OUT,
    "wo": _IN,
    # MLA
    "wq_a": _OUT, "wq_b": _OUT, "wkv_a": _OUT, "wkv_b": _OUT,
    # ReCalKV latent factors: small; replicated (the cache shards on S)
    "l_k": (), "r_k": (), "l_v": (),
    "wo_fused": (("model", 0), ("data", 2)),
    # dense FFN
    "wi": (("model", -1), ("data", 0)),
    "wg": (("model", -1), ("data", 0)),
    # mamba
    "in_proj": _OUT, "x_proj": _IN, "dt_proj": _OUT, "out_proj": _IN,
    "conv_w": (("model", -1),),
    "A_log": (("model", 0),),
    # rglru
    "in_main": _OUT, "in_gate": _OUT, "w_a": _IN, "w_x": _IN,
    # router: tiny, and its output feeds a global top-k -> replicate
    "router": (),
}

# 3D MoE expert weights: experts over model (EP), fsdp over dim1.
MOE_RULES = {
    "wi": (("model", 0), ("data", 1)),
    "wg": (("model", 0), ("data", 1)),
    "wo": (("model", 0), ("data", 1)),
}

CACHE_RULES: dict[str, tuple] = {
    "k": (("batch", 0), ("model", 1)),
    "v": (("batch", 0), ("model", 1)),
    "zk": (("batch", 0), ("model", 1)),
    "zv": (("batch", 0), ("model", 1)),
    "pos": (("batch", 0), ("model", 1)),
    "ckv": (("batch", 0), ("model", 1)),
    "krope": (("batch", 0), ("model", 1)),
    "h": (("batch", 0), ("model", 1)),
    "conv": (("batch", 0), ("model", 2)),
}

FSDP_THRESHOLD_BYTES = 1 << 24          # 16 MiB (post-TP) triggers FSDP
ZERO3_THRESHOLD_BYTES = 1 << 28         # 256 MiB: FSDP spans pods too
                                        # (671B-class experts; ZeRO-3 across
                                        # the slower inter-pod links is the
                                        # only way optimizer state fits)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def _leaf_bytes(shape, dtype) -> int:
    return math.prod(shape) * np.dtype(dtype).itemsize


def _resolve(prefs, shape, dtype, mesh: Mesh, offset: int):
    """Apply a preference list with divisibility + size checks."""
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    size = _leaf_bytes(shape, dtype)
    for axis, dim in prefs:
        # logical dim -> physical dim (skip the scan-stack leading axis)
        d = dim + offset if dim >= 0 else ndim + dim
        if not (offset <= d < ndim) or spec[d] is not None:
            continue
        if axis == "batch":
            names = batch_axes(mesh)
            n = math.prod(mesh.shape[a] for a in names)
            if names and shape[d] % n == 0:
                spec[d] = names if len(names) > 1 else names[0]
                size //= n
            continue
        if axis not in mesh.shape:
            continue
        n = mesh.shape[axis]
        if axis == "data":
            if size < FSDP_THRESHOLD_BYTES:
                continue  # FSDP only pays off for big leaves
            if size >= ZERO3_THRESHOLD_BYTES and "pod" in mesh.shape:
                np_ = n * mesh.shape["pod"]
                if shape[d] % np_ == 0:
                    spec[d] = ("data", "pod")
                    size //= np_
                    continue
        if shape[d] % n != 0:
            continue
        spec[d] = axis
        size //= n
    return P(*spec)


def _spec_for(path, leaf, mesh: Mesh, rules, default=()):
    names = _path_names(path)
    name = names[-1]
    # scanned stack: params/caches under top-level "blocks" carry (n_periods,)
    offset = 1 if (names and names[0] == "blocks") else 0
    shape, dtype = leaf.shape, leaf.dtype
    if rules is CACHE_RULES:
        prefs = rules.get(name, (("batch", 0),))
    else:
        if name in MOE_RULES and len(shape) - offset == 3 and "mlp" in names:
            prefs = MOE_RULES[name]
        else:
            prefs = rules.get(name, default)
    if len(shape) - offset < 1 or not prefs:
        return P()
    return _resolve(prefs, shape, dtype, mesh, offset)


def param_specs(params, mesh: Mesh):
    """PartitionSpec tree for a parameter pytree (shapes or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, PARAM_RULES), params)


def cache_specs(caches, mesh: Mesh):
    """PartitionSpec tree for decode caches (sequence-sharded rings)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, mesh, CACHE_RULES), caches)


def opt_specs(opt_state, params_spec, mesh: Mesh):
    """Optimizer state mirrors parameter sharding; scalars replicate."""
    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("mu", "nu", "residual"):
            sub = jax.tree_util.tree_map_with_path(
                lambda p, l: _spec_for(p, l, mesh, PARAM_RULES), leaf)
            return sub
        return P()
    out = {}
    for k, v in opt_state.items():
        if k in ("mu", "nu", "residual"):
            out[k] = jax.tree_util.tree_map_with_path(
                lambda p, l: _spec_for(p, l, mesh, PARAM_RULES), v)
        else:
            out[k] = P()
    return out


def batch_specs(batch, mesh: Mesh):
    """Inputs: leading dim over (pod, data)."""
    names = batch_axes(mesh)
    dp = names if len(names) > 1 else (names[0] if names else None)

    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        n = math.prod(mesh.shape[a] for a in batch_axes(mesh))
        if leaf.shape[0] % n == 0 and dp is not None:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, batch)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
