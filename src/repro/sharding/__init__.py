from repro.sharding.rules import (
    batch_axes,
    batch_specs,
    cache_specs,
    carry_specs,
    opt_specs,
    param_specs,
    to_named,
    window_shardings,
)

__all__ = ["batch_axes", "batch_specs", "cache_specs", "carry_specs",
           "opt_specs", "param_specs", "to_named", "window_shardings"]
