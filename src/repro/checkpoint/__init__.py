from repro.checkpoint.ckpt import (
    latest_step,
    load_flat,
    read_meta,
    restore,
    save,
    tuple_paths,
    unflatten,
)

__all__ = ["latest_step", "load_flat", "read_meta", "restore", "save",
           "tuple_paths", "unflatten"]
