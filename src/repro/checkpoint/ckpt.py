"""Atomic, async, reshard-on-restore checkpointing.

Layout:   <dir>/step_<n>/arrays.npz  +  meta.json     (tmp-dir + os.replace
gives atomicity; a crashed writer never corrupts the latest checkpoint).

* save() can run in a background thread (async): the arrays are snapshotted
  to host first, so training mutates device buffers freely while I/O runs.
* restore() device_puts every leaf with a *caller-supplied sharding tree* —
  restoring onto a different mesh (elastic up/down-scaling) is therefore
  just restore(new_shardings); no resharding pass is needed.
* keep_last trims old steps after each successful save.

In a true multi-host deployment each host writes its addressable shards
(same layout, per-process subdirectories); this container is single-process
so the consolidated path is exercised end-to-end.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree, *, keep_last: int = 3,
         async_: bool = False,
         extra_meta: dict | None = None) -> threading.Thread | None:
    """Write ``tree`` under <directory>/step_<step>.  Returns the writer
    thread when async (join it to guarantee durability).  ``extra_meta``
    is merged into meta.json (artifact provenance, model config, ...) and
    rides inside the same atomic os.replace."""
    os.makedirs(directory, exist_ok=True)
    host = _flatten(jax.tree.map(lambda x: np.asarray(x), tree))

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + f".tmp.{os.getpid()}.{time.time_ns()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({**(extra_meta or {}), "step": step,
                       "keys": sorted(host)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _trim(directory, keep_last)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _trim(directory: str, keep_last: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "meta.json"))]
    return max(steps) if steps else None


def read_meta(directory: str, step: int) -> dict:
    """Load a checkpoint's meta.json (step, keys, and any extra_meta)."""
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


def load_flat(directory: str, step: int) -> dict[str, np.ndarray]:
    """Load the raw 'path/to/leaf' -> array mapping of one checkpoint."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def tuple_paths(tree) -> list[str]:
    """'/'-joined paths of every sequence container in ``tree`` — stored in
    meta so unflatten() can rebuild containers exactly (a dict keyed by
    digit strings is otherwise indistinguishable from a tuple on disk)."""
    out: list[str] = []

    def walk(node, prefix):
        if isinstance(node, (tuple, list)):
            out.append("/".join(prefix))
            for i, v in enumerate(node):
                walk(v, prefix + (str(i),))
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, prefix + (str(k),))

    walk(tree, ())
    return out


def unflatten(flat: dict[str, np.ndarray], seq_paths: list[str] | None = None):
    """Rebuild a pytree from the '/'-joined keys save() writes — without a
    target tree, so a reader process needs no model code to know shapes.

    ``seq_paths`` (from :func:`tuple_paths` at save time) says exactly which
    containers are tuples; without it, containers whose keys are exactly
    0..n-1 become tuples (matching the tuple-of-blocks param layout) and
    everything else becomes a dict.
    """
    root: dict = {}
    for key, arr in flat.items():
        node = root
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    seq_set = None if seq_paths is None else set(seq_paths)

    def _finalize(node, prefix):
        if not isinstance(node, dict):
            return node
        out = {k: _finalize(v, prefix + (k,)) for k, v in node.items()}
        if seq_set is not None:
            if "/".join(prefix) not in seq_set:
                return out
        elif not (out and all(k.isdigit() for k in out)):
            return out
        idx = sorted(out, key=int)
        if [int(k) for k in idx] == list(range(len(idx))):
            return tuple(out[k] for k in idx)
        return out

    return _finalize(root, ())


def restore(directory: str, step: int, target_tree,
            sharding_for: Callable[[str, Any], Any] | None = None):
    """Rebuild ``target_tree``'s structure from disk.

    ``sharding_for(path, host_array)`` may return a jax.sharding.Sharding
    to place each leaf — pass the *new* mesh's shardings to reshard on
    restore (elastic scaling)."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    new_leaves = []
    for kpath, ref_leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kpath)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref_leaf.shape}")
        if sharding_for is not None:
            sh = sharding_for(key, arr)
            new_leaves.append(jax.device_put(arr.astype(ref_leaf.dtype), sh)
                              if sh is not None else
                              jax.numpy.asarray(arr, ref_leaf.dtype))
        else:
            new_leaves.append(jax.numpy.asarray(arr, ref_leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
