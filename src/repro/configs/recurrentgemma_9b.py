"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention in a (recurrent, recurrent, attn)
pattern.  [arXiv:2402.19427; unverified]

38 layers = 12 periods of (rglru, rglru, local) + 2 trailing rglru blocks
(handled by the unrolled suffix).  kv=1 (MQA) makes HSR grouping degenerate
(one head -> one group of 1): those layers get plain truncated+calibrated
SVD; OCMF fully applies (DESIGN.md §Arch-applicability).  RG-LRU layers are
attention-free.  Qualifies for long_500k (bounded 2048-window cache).
"""

from repro.models.config import ModelConfig, RGLRUConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    layer_pattern=("rglru", "rglru", "local"),
    sliding_window=16,
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
    embed_scale=True,
    attn_chunk=16,
)
