"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, Mamba-1 architecture.  [arXiv:2410.05355; unverified]

ReCalKV is INAPPLICABLE (DESIGN.md §Arch-applicability): there is no KV
cache; the recurrent state (B, d_inner, d_state) is already O(1) in
sequence length.  Implemented natively with the chunked selective scan.
head/d_ff fields are placeholders (no attention / no separate FFN).
"""

from repro.models.config import MambaConfig, ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab_size=65024,
    layer_pattern=("mamba",),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_head=16,
    d_ff=0,
    vocab_size=257,
    layer_pattern=("mamba",),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
    attn_chunk=16,
)
