"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(dense)=18432
vocab=129280, MoE 256 experts top-8 + 1 shared (d_expert=2048), MLA
(kv_lora=512, rope=64), first 3 layers dense.  [arXiv:2412.19437; hf]

ReCalKV is REDUNDANT here (DESIGN.md §Arch-applicability): MLA *is* the
trained-from-scratch latent-KV design the paper positions itself against.
The decode path uses absorbed MLA (kv_cache.decode_attn_mla) — the exact
latent-consumption pattern OCMF recovers post-hoc for GQA/MHA models.
MTP (multi-token prediction) is not modeled (training objective detail,
orthogonal to the serving/memory system).
"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_head=128,
    d_ff=18432,                       # dense-FFN width (first 3 layers)
    vocab_size=129280,
    prefix_pattern=("attn_dense",) * 3,
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  first_k_dense=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_rope_dim=64,
                  qk_nope_dim=128, v_head_dim=128),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    prefix_pattern=("attn_dense",),
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                  first_k_dense=1),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
                  qk_nope_dim=8, v_head_dim=16),
    tie_embeddings=False,
    attn_chunk=16,
)
