"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA kv=36) d_ff=5760
vocab=122753, WSD schedule (arch = llama-like MHA).  [arXiv:2404.06395; hf]

MHA is the paper's own main setting (LLaMA-2): 36 kv heads -> 9 HSR groups
of 4.  The WSD learning-rate schedule lives in repro.optim.schedule.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10000.0,
    attn_seq_shard=True,   # 36 heads % 16 != 0: sequence-parallel K/V
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    num_layers=3,
    d_model=72,
    num_heads=4,
    num_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    attn_chunk=16,
)
