"""Architecture registry: the 10 assigned configs + smoke variants + shapes.

``get_config(arch)`` returns the exact published configuration;
``get_config(arch, smoke=True)`` returns a reduced same-family config for
CPU tests; ``get_config(arch, recalkv_ratio=0.5)`` attaches a uniform-rank
ReCalKV latent cache at the given *kept* fraction (None where the technique
is inapplicable — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.core.svd import effective_rank_for_ratio
from repro.models.config import ModelConfig, ReCalKVRuntime

ARCHS: tuple[str, ...] = (
    "llama-3.2-vision-11b",
    "h2o-danube-1.8b",
    "qwen3-4b",
    "gemma3-12b",
    "minicpm-2b",
    "whisper-small",
    "falcon-mamba-7b",
    "deepseek-v3-671b",
    "qwen3-moe-235b-a22b",
    "recurrentgemma-9b",
)

_MODULES = {
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "qwen3-4b": "qwen3_4b",
    "gemma3-12b": "gemma3_12b",
    "minicpm-2b": "minicpm_2b",
    "whisper-small": "whisper_small",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

# ReCalKV applies to archs with cached RoPE'd attention; Mamba has no
# attention, DeepSeek's MLA is already a (trained) latent cache.
RECALKV_APPLICABLE = {
    "llama-3.2-vision-11b": True,
    "h2o-danube-1.8b": True,
    "qwen3-4b": True,
    "gemma3-12b": True,
    "minicpm-2b": True,
    "whisper-small": True,
    "falcon-mamba-7b": False,
    "deepseek-v3-671b": False,
    "qwen3-moe-235b-a22b": True,
    "recurrentgemma-9b": True,
}

# (seq_len, global_batch, kind); kind: "train" lowers train_step,
# "decode" lowers serve_step (one token against a seq_len cache).
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k requires a sub-quadratic cache: SSM / hybrid / windowed archs
# run it; pure full-attention archs (and the 448-position whisper decoder)
# skip it, per the assignment rule (DESIGN.md §4).
LONG_CONTEXT_ARCHS = {
    "falcon-mamba-7b", "recurrentgemma-9b", "gemma3-12b", "h2o-danube-1.8b",
}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 500k decode is quadratic-cost"
    return True, ""


def get_config(arch: str, *, smoke: bool = False,
               recalkv_ratio: float | None = None) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.SMOKE if smoke else mod.FULL
    if recalkv_ratio is not None:
        if not RECALKV_APPLICABLE[arch]:
            raise ValueError(
                f"ReCalKV inapplicable to {arch} (see DESIGN.md §Arch-applicability)")
        s = max(1, min(4, cfg.num_kv_heads))
        width = s * cfg.d_head
        rank = effective_rank_for_ratio(width, recalkv_ratio)
        cfg = dataclasses.replace(
            cfg, recalkv=ReCalKVRuntime(rank_k=rank, rank_v=rank, group_size=s))
    return cfg


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCHS}
