"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8, qk-norm.  [hf:Qwen/Qwen3-30B-A3B; hf]

GQA kv=4 -> a single HSR group of 4 per layer; ReCalKV fully applies (the
MoE change is FFN-only).
"""

from repro.models.config import MoEConfig, ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab_size=151936,
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_head=16,
    d_ff=64,
    vocab_size=257,
    layer_pattern=("attn",),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=64),
    qk_norm=True,
    tie_embeddings=False,
    attn_chunk=16,
)
