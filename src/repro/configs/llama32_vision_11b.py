"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers every 5th block.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a STUB: ``input_specs`` supplies precomputed patch
embeddings (B, 1600, d_model); cross blocks attend to them.  ReCalKV applies
to both self-attention (RoPE'd, reconstructed keys) and cross-attention
(no RoPE -> absorbed keys, DESIGN.md §2).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=("attn", "attn", "attn", "cross", "attn"),  # 8 cross / 40
    cross_source_len=1600,
    rope_theta=500000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    layer_pattern=("attn", "attn", "attn", "cross", "attn"),
    cross_source_len=16,
    rope_theta=500000.0,
    tie_embeddings=False,
    attn_chunk=16,
)
