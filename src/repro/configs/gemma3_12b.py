"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Local layers slide over a 1024 window (theta 10k); every 6th layer is
global (theta 1M).  Qualifies for long_500k: only the 8 global layers hold
an unbounded cache.  Per-layer Fisher allocation naturally compresses the
global layers hardest (their caches dominate bytes).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=1024,
    rope_theta=10000.0,
    rope_theta_global=1000000.0,
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke",
    family="dense",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=16,
    rope_theta=10000.0,
    rope_theta_global=1000000.0,
    qk_norm=True,
    embed_scale=True,
    attn_chunk=16,
)
