"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

Every layer slides (window 4096), so the KV ring is bounded and the arch
qualifies for long_500k.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_head=80,
    d_ff=6912,
    vocab_size=32000,
    layer_pattern=("local",),
    sliding_window=4096,
    rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    layer_pattern=("local",),
    sliding_window=16,
    tie_embeddings=False,
    attn_chunk=16,
)
