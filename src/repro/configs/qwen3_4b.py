"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk-norm, explicit head_dim=128.  [hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_head=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    qk_norm=True,
    rope_theta=1000000.0,
    attn_chunk=16,
)
