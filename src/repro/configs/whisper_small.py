"""whisper-small [audio] — 12L enc + 12L dec, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865, enc-dec with conv frontend (STUB).
[arXiv:2212.04356; unverified]

The conv/mel frontend is a stub: ``input_specs`` provides precomputed frame
embeddings (B, 1500, d_model).  Decoder blocks are self-attn + cross-attn;
ReCalKV compresses both (cross-attn KV dominates bytes at batch >> 1 and
has no RoPE -> absorbed keys).  Deviations from the original (SwiGLU for
GELU-MLP, RoPE for learned positions) are noted in DESIGN.md §6 — the
assignment specifies the transformer *backbone*; decode shapes are lowered
mechanically at the assigned seq_len even though the original model caps
decoding at 448 positions.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern=("attn_cross",),
    attn_seq_shard=True,   # 12 heads % 16 != 0: sequence-parallel K/V
    encoder_decoder=True,
    num_encoder_layers=12,
    cross_source_len=1500,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=257,
    layer_pattern=("attn_cross",),
    encoder_decoder=True,
    num_encoder_layers=2,
    cross_source_len=16,
    attn_chunk=16,
)
