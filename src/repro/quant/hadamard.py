"""Randomized Hadamard transform for latent pre-conditioning (Table 4).

Per-token quantization suffers from outlier channels; rotating by a
(randomized) Hadamard matrix flattens the distribution (Palu §quant, QuIP,
etc.).  For dim = 2^k * m we apply H_{2^k} (x) I_m — the fast Walsh-
Hadamard transform over the largest power-of-two factor — after a fixed
+-1 diagonal (seeded, so the inverse is reproducible everywhere).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _pow2_factor(n: int) -> int:
    p = 1
    while n % (2 * p) == 0:
        p *= 2
    return p


def rademacher_diag(dim: int, seed: int = 7) -> np.ndarray:
    g = np.random.Generator(np.random.Philox(key=[seed, dim]))
    return (g.integers(0, 2, size=dim) * 2 - 1).astype(np.float32)


def fwht(x: jax.Array) -> jax.Array:
    """Fast Walsh-Hadamard over the last axis (power-of-two blocks)."""
    n = x.shape[-1]
    p = _pow2_factor(n)
    m = n // p
    y = x.astype(jnp.float32).reshape(x.shape[:-1] + (m, p))
    h = 1
    while h < p:
        y = y.reshape(x.shape[:-1] + (m, p // (2 * h), 2, h))
        a = y[..., 0, :]
        b = y[..., 1, :]
        y = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    y = y.reshape(x.shape[:-1] + (m, p)) / jnp.sqrt(jnp.float32(p))
    return y.reshape(x.shape).astype(x.dtype)


def hadamard_transform(x: jax.Array, seed: int = 7) -> jax.Array:
    """Randomized orthogonal transform: diag(+-1) then FWHT."""
    d = jnp.asarray(rademacher_diag(x.shape[-1], seed), x.dtype)
    return fwht(x * d)


def hadamard_inverse(y: jax.Array, seed: int = 7) -> jax.Array:
    """FWHT is an involution (orthonormal); undo the diagonal after."""
    d = jnp.asarray(rademacher_diag(y.shape[-1], seed), y.dtype)
    return fwht(y) * d
