"""Per-token symmetric integer quantization of cache latents (Table 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, bits: int = 8):
    """Symmetric per-token (last-axis) quantization.

    Returns (q int8, scale f32 broadcastable).  4-bit values live in
    [-7, 7] inside int8 storage (packing is a serving-layer detail)."""
    if bits not in (3, 4, 8):
        raise ValueError(bits)
    qmax = {8: 127, 4: 7, 3: 3}[bits]
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-30)
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    """Quantize-dequantize round trip (quality evaluation path)."""
    q, s = quantize(x, bits)
    return dequantize(q, s, x.dtype)
