from repro.quant.hadamard import fwht, hadamard_inverse, hadamard_transform
from repro.quant.int_quant import dequantize, fake_quant, quantize

__all__ = ["dequantize", "fake_quant", "fwht", "hadamard_inverse",
           "hadamard_transform", "quantize"]
