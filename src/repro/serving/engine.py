"""Executor: continuous batching over the latent KV cache with a fused,
device-resident multi-token decode loop.

The serving subsystem is split three ways:

  scheduler.py  admission policy, slot lifecycle, chunked prefill (host)
  sampler.py    on-device temperature / top-k / top-p / greedy sampling
  engine.py     this file — the executor.  One ``jax.lax.scan`` window
                runs ``sync_every`` decode steps entirely on device
                (feed -> decode_step -> sample -> append -> termination),
                carrying last-token, cur, active-mask, PRNG keys, ingest
                buffers and done-flags as device state.  The host is
                touched once per window: harvest emitted tokens, retire
                finished slots, refill prompt-ingest buffers, and run
                admission (batched, shape-bucketed wave prefill).

The engine is MESH-NATIVE: ``Engine(mesh=...)`` device-puts params via
``sharding.rules.param_specs`` and jits the window with explicit
``in_shardings``/``out_shardings`` — cache rings sharded slot x sequence
per ``CACHE_RULES`` (the softmax over the sharded S axis becomes a psum
LSE merge; the latent ``A @ z_v`` contraction psums only a tiny
``(B, H, r_v)``, the low-rank win compounding with tensor parallelism),
and the rest of the device carry (last-token, cur, active, per-slot PRNG
keys, ingest buffer) sharded on the slot axis per ``carry_specs``.
Without a mesh the engine runs on a degenerate (1, 1) mesh — the sharded
window IS the single-device path, not a branch.

Chunked prefill rides the same loop: a long prompt's first
``prefill_chunk`` tokens go through the wave prefill; the remainder sits
in a per-slot device buffer and is *fed* through decode steps (cache
writes at the token's true position, sampled outputs discarded until the
final prompt token), so decode-phase slots keep emitting between chunks.

With ReCalKV enabled the resident cache is the *latent* ring — at 50%
compression the same HBM holds 2x the slots (the paper's serving win).
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import single_device_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import sampler as S
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Request, Scheduler
from repro.sharding import rules as R

__all__ = ["Engine", "Request", "SamplingParams"]


def _merge_slot(pool_cache, new_cache, slots: jax.Array):
    """Copy ``new_cache``'s leading batch rows into ``pool_cache`` at
    ``slots`` (the prefill wave may be padded past ``len(slots)`` rows for
    shape bucketing — the pad rows are dropped here).

    Batch is dim 0 for prefix/suffix caches but dim 1 under the scanned
    "blocks" subtree (leading dim = pattern periods)."""
    n = slots.shape[0]
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        if key0 == "blocks":
            return pool.at[:, slots].set(new[:, :n].astype(pool.dtype))
        return pool.at[slots].set(new[:n].astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two, capped: the (wave, prompt-len) shapes a
    long-running engine sees collapse to O(log) values instead of one jit
    retrace per distinct admission wave."""
    return min(max(1, 1 << (n - 1).bit_length()), max(cap, n))


class Engine:
    """Slot-based continuous-batching executor.

    ``sync_every`` sets the decode window: tokens decoded per
    host round-trip.  Large windows amortize dispatch and host syncs
    (throughput); small windows tighten admission latency for queued
    requests and finished-slot turnaround (latency).
    ``prefill_chunk`` bounds how much prompt one admission wave prefills
    at once; the remainder streams through the decode loop.
    ``mesh`` is a ("data", "model") jax Mesh (see ``launch.mesh``); the
    slot axis shards over "data", the cache ring's sequence axis over
    "model".  Default: a (1, 1) single-device mesh.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, source: jax.Array | None = None,
                 backend: str | None = None,
                 sampling: SamplingParams | None = None,
                 sync_every: int = 8, prefill_chunk: int | None = None,
                 mesh: jax.sharding.Mesh | None = None):
        if backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=backend)
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.cfg = cfg
        self.B, self.max_len = max_slots, max_len
        self.source = source
        self.sampling = sampling or S.GREEDY
        self.sync_every = sync_every
        self.mesh = mesh if mesh is not None else single_device_mesh()
        # slots-per-shard admission locality: only meaningful when the
        # slot axis actually shards (divisible); else one logical shard
        n_slot_shards = math.prod(
            self.mesh.shape[a] for a in R.batch_axes(self.mesh))
        if n_slot_shards < 1 or max_slots % n_slot_shards:
            n_slot_shards = 1
        self.scheduler = Scheduler(max_slots, max_len,
                                   prefill_chunk=prefill_chunk,
                                   slot_shards=n_slot_shards)
        # Mesh-native placement: params by PARAM_RULES (TP heads / FSDP),
        # the pooled cache rings by CACHE_RULES (slot x sequence).
        param_shardings = R.to_named(
            R.param_specs(params, self.mesh, grains=R.head_grains(cfg)),
            self.mesh)
        self.params = jax.device_put(params, param_shardings)
        cache = T.init_decode_cache(cfg, max_slots, max_len)
        self._cache_shardings = R.to_named(
            R.cache_specs(cache, self.mesh), self.mesh)
        self.cache = jax.device_put(cache, self._cache_shardings)
        self.finished: list[Request] = []
        # per-slot host mirror of the device loop state (synced once per
        # window); the cache itself never leaves the device
        W = prefill_chunk or 1
        self._st: dict[str, np.ndarray] = {
            "tok": np.zeros(max_slots, np.int32),
            "cur": np.zeros(max_slots, np.int32),
            "act": np.zeros(max_slots, bool),
            "keys": np.zeros((max_slots, 2), np.uint32),
            "temp": np.zeros(max_slots, np.float32),
            "top_k": np.zeros(max_slots, np.int32),
            "top_p": np.ones(max_slots, np.float32),
            "eos": np.full(max_slots, -1, np.int32),
            "left": np.zeros(max_slots, np.int32),
            "buf": np.zeros((max_slots, W), np.int32),
            "avail": np.zeros(max_slots, np.int32),
            "bpos": np.zeros(max_slots, np.int32),
            "more": np.zeros(max_slots, bool),
        }
        # metrics (sums and `windows` advance atomically at each window
        # boundary in _harvest, so metrics() mid-stream is consistent)
        self.host_syncs = 0          # device->host harvest points
        self.admission_syncs = 0     # host_syncs spent on wave prefills
        self.windows = 0
        self.tokens_emitted = 0      # emitted by decode windows
        self._admit_tokens = 0       # first tokens emitted at admission
        self._occupancy_sum = 0
        self._queue_depth_sum = 0
        self._run_seconds = 0.0

        self._prefill = jax.jit(
            lambda p, t, l: T.prefill(cfg, p, t, l, max_len=max_len,
                                      source=None if source is None
                                      else source[: t.shape[0]]),
            static_argnames=())
        # Donate the cache buffer into the window: self.cache is rebound
        # to the output, so XLA can update the ring in place instead of
        # holding two full caches live — the cache IS the HBM footprint
        # the paper halves.  (CPU ignores donation and would warn, so
        # only donate where it takes effect.)
        donate = (1,) if jax.default_backend() != "cpu" else ()
        in_sh, out_sh = R.window_shardings(
            self.mesh, self.params, self.cache, self._st,
            param_shardings=param_shardings,
            cache_shardings=self._cache_shardings)
        logits_spec = jax.sharding.NamedSharding(
            self.mesh, R.slot_stacked_spec(max_slots, self.mesh,
                                           lead_dims=0))
        self._window = jax.jit(
            self._make_window(cfg, max_len, sync_every,
                              cache_shardings=self._cache_shardings,
                              logits_spec=logits_spec),
            donate_argnums=donate, in_shardings=in_sh, out_shardings=out_sh)

    # -- fused decode window -------------------------------------------------

    @staticmethod
    def _make_window(cfg: ModelConfig, max_len: int, steps: int, *,
                     cache_shardings=None, logits_spec=None):
        """Build the jitted window fn: ``steps`` fused decode iterations.

        Per iteration, per slot: pick the fed token (ingest buffer while
        prompt remains, else last sampled), run one batched decode_step
        (inactive/stalled rows masked from cache writes), sample, then
        update emit/termination flags — all under one lax.scan, so the
        only host sync is the caller harvesting the stacked outputs.

        ``cache_shardings``/``logits_spec`` pin the scan carry's ring
        layout and the sampler's slot-sharded logits so the loop body
        never reshards mid-scan (the mesh must not smuggle per-step
        transfers back in)."""

        def window(params, cache, st):
            def body(carry, _):
                cache, st = carry
                feeding = st["bpos"] < st["avail"]
                buf_tok = jnp.take_along_axis(
                    st["buf"],
                    jnp.minimum(st["bpos"], st["buf"].shape[1] - 1)[:, None],
                    axis=1)[:, 0]
                tok_in = jnp.where(feeding, buf_tok, st["tok"])
                # a slot whose ingest buffer drained but has prompt left on
                # the host stalls (no step) until the next refill
                stalled = st["more"] & ~feeding
                stepping = st["act"] & ~stalled
                logits, cache = T.decode_step(
                    cfg, params, cache, tok_in, st["cur"], stepping,
                    cache_shardings=cache_shardings)
                ks = jax.vmap(lambda k: jax.random.split(k, 2))(st["keys"])
                sampled = S.sample_tokens(logits, st["temp"], st["top_k"],
                                          st["top_p"], ks[:, 1],
                                          spec=logits_spec)
                last_prompt = (feeding & ~st["more"]
                               & (st["bpos"] + 1 >= st["avail"]))
                emit = stepping & (~feeding | last_prompt)
                cur2 = st["cur"] + stepping.astype(st["cur"].dtype)
                left2 = st["left"] - emit.astype(st["left"].dtype)
                # ring-cap stop: cur2 == max_len means this step wrote the
                # last ring position — the NEXT write would wrap and evict
                # position 0.  (Not max_len - 1: that fired one step early
                # on the ingest path, costing cap-length chunked prompts
                # their final token vs unchunked admission.)
                done = (emit & ((sampled == st["eos"]) | (left2 <= 0))
                        | (stepping & (cur2 >= max_len)))
                st2 = {**st,
                       "tok": jnp.where(emit, sampled, st["tok"]),
                       "cur": cur2,
                       "act": st["act"] & ~done,
                       "keys": jnp.where(emit[:, None], ks[:, 0], st["keys"]),
                       "bpos": st["bpos"] + feeding.astype(st["bpos"].dtype),
                       "left": left2}
                return (cache, st2), (sampled, emit)

            (cache, st), (toks, emits) = jax.lax.scan(
                body, (cache, st), None, length=steps)
            return cache, st, toks, emits

        return window

    @classmethod
    def from_artifact(cls, path: str, *, max_slots: int, max_len: int,
                      source: jax.Array | None = None,
                      backend: str | None = None,
                      sampling: SamplingParams | None = None,
                      sync_every: int = 8,
                      prefill_chunk: int | None = None,
                      mesh: jax.sharding.Mesh | None = None) -> "Engine":
        """Boot an engine straight from a saved compression artifact —
        the compress-offline / serve-forever workflow across processes."""
        from repro.api import load_artifact  # local: api imports models too

        art = load_artifact(path)
        return cls(art.cfg, art.params, max_slots=max_slots, max_len=max_len,
                   source=source, backend=backend, sampling=sampling,
                   sync_every=sync_every, prefill_chunk=prefill_chunk,
                   mesh=mesh)

    # -- back-compat conveniences -------------------------------------------

    @property
    def slot_req(self) -> list[Request | None]:
        return self.scheduler.slot_req

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def unfinished(self) -> dict[str, int]:
        """Requests not yet finished: queued vs admitted-but-mid-flight."""
        return {"queued": self.scheduler.queue_depth,
                "in_flight": self.scheduler.occupancy}

    @property
    def mesh_str(self) -> str:
        """Mesh shape joined over ALL axes in mesh order (e.g. "1x1",
        "2x4", "2x16x16" for a multi-pod mesh)."""
        return "x".join(str(self.mesh.shape[a]) for a in self.mesh.axis_names)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> Request:
        return self.scheduler.submit(req)

    def _finish(self, slot: int):
        self.finished.append(self.scheduler.slot_req[slot])
        self.scheduler.release(slot)
        st = self._st
        st["act"][slot] = False
        st["avail"][slot] = 0
        st["bpos"][slot] = 0
        st["more"][slot] = False
        st["left"][slot] = 0

    def _admit(self):
        wave = self.scheduler.take_wave()
        if not wave:
            return
        first_lens = [self.scheduler.first_chunk_len(r) for _, r in wave]
        # Bucket the wave to power-of-two (rows, prompt-len) shapes so a
        # stream of ragged admissions reuses O(log) jit traces.  The row
        # cap is the slot count; the length cap is max_len (padding past
        # the ring would silently drop a fittable prompt prefix).
        W = _bucket(len(wave), self.B)
        P = _bucket(max(first_lens), self.max_len)
        toks = np.zeros((W, P), np.int32)
        lens = np.zeros((W,), np.int32)
        for i, (_, r) in enumerate(wave):
            toks[i, : first_lens[i]] = r.prompt[: first_lens[i]]
            lens[i] = first_lens[i]
        logits, new_cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        slots = jnp.asarray([s for s, _ in wave])
        self.cache = _merge_slot(self.cache, new_cache, slots)
        # Sample each wave row's first token with the SAME policy + key
        # split the decode window would use — a request's stream is then
        # identical whether its first token comes from the wave prefill
        # (whole prompt consumed) or from the loop's last ingest step
        # (chunked).  At temperature=0 this is exact argmax, matching the
        # seed engine.
        specs = [r.sampling or self.sampling for _, r in wave]
        keys0 = np.stack([sp.slot_key(r.uid)
                          for sp, (_, r) in zip(specs, wave)])
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(jnp.asarray(keys0))
        n = len(wave)
        first = np.asarray(S.sample_tokens(
            logits[:n],
            jnp.asarray([sp.temperature for sp in specs], jnp.float32),
            jnp.asarray([sp.top_k for sp in specs], jnp.int32),
            jnp.asarray([sp.top_p for sp in specs], jnp.float32),
            ks[:, 1]))
        ks = np.asarray(ks)
        self.host_syncs += 1
        self.admission_syncs += 1
        st = self._st
        for i, (slot, r) in enumerate(wave):
            sp = specs[i]
            st["cur"][slot] = first_lens[i]
            st["keys"][slot] = keys0[i]
            st["temp"][slot] = sp.temperature
            st["top_k"][slot] = sp.top_k
            st["top_p"][slot] = sp.top_p
            st["eos"][slot] = -1 if r.eos_id is None else r.eos_id
            st["bpos"][slot] = 0
            st["act"][slot] = True
            rest = r.prompt[first_lens[i]:]
            if rest.size == 0:
                # whole prompt prefilled: emit the first generated token
                # right away (as the seed engine did) and advance the key
                st["keys"][slot] = ks[i, 0]
                r.out_tokens.append(int(first[i]))
                self._admit_tokens += 1
                st["tok"][slot] = first[i]
                st["left"][slot] = r.max_new_tokens - 1
                st["avail"][slot] = 0
                st["more"][slot] = False
                if r.done:
                    self._finish(slot)
            else:
                # chunked prefill: stream the remainder through the
                # decode loop's ingest buffer
                self.scheduler.set_pending(slot, rest)
                self._load_chunk(slot)
                st["tok"][slot] = 0
                st["left"][slot] = r.max_new_tokens

    def _load_chunk(self, slot: int):
        chunk = self.scheduler.next_chunk(slot)
        st = self._st
        w = chunk.shape[0]
        st["buf"][slot, :w] = chunk
        st["avail"][slot] = w
        st["bpos"][slot] = 0
        st["more"][slot] = self.scheduler.pending_len(slot) > 0

    def _refill(self):
        st = self._st
        for slot, r in enumerate(self.scheduler.slot_req):
            if (r is not None and st["act"][slot]
                    and st["bpos"][slot] >= st["avail"][slot]
                    and self.scheduler.pending_len(slot) > 0):
                self._load_chunk(slot)

    # -- one engine step (= one decode window) -------------------------------

    def step(self):
        """Admit + refill, then run one ``sync_every``-token fused decode
        window and harvest it (the single host sync of the step)."""
        self._admit()
        self._refill()
        st = self._st
        if not st["act"].any():
            return
        # window-boundary snapshot: the load THIS window runs with —
        # folded into the means in _harvest, atomically with `windows`
        occ, qd = self.scheduler.occupancy, self.scheduler.queue_depth
        state = {k: jnp.asarray(v) for k, v in st.items()}
        self.cache, state, toks, emits = self._window(
            self.params, self.cache, state)
        self._harvest(state, toks, emits, occ, qd)

    def _harvest(self, state, toks, emits, occ: int, qd: int):
        toks = np.asarray(toks)                 # (K, B)
        emits = np.asarray(emits)               # (K, B)
        self._st = {k: np.array(v) for k, v in state.items()}
        # every window-scoped counter advances together, here and only
        # here — a mid-stream metrics() call never sees sums from one
        # window paired with counts from another
        self.host_syncs += 1
        self.windows += 1
        self.tokens_emitted += int(emits.sum())
        self._occupancy_sum += occ
        self._queue_depth_sum += qd
        slot_req = self.scheduler.slot_req
        for k in range(toks.shape[0]):
            for i in np.nonzero(emits[k])[0]:
                slot_req[i].out_tokens.append(int(toks[k, i]))
        for slot, r in enumerate(slot_req):
            if r is not None and not self._st["act"][slot]:
                self._finish(slot)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive until drained or ``max_steps`` windows.  On timeout the
        engine warns and leaves the backlog inspectable via
        ``engine.unfinished`` (callers distinguish drain from timeout)."""
        t0 = time.perf_counter()
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1
        self._run_seconds += time.perf_counter() - t0
        if self.scheduler.has_work:
            u = self.unfinished
            warnings.warn(
                f"Engine.run stopped at max_steps={max_steps} with "
                f"{u['queued']} queued and {u['in_flight']} in-flight "
                f"requests unfinished (not a drain)", RuntimeWarning,
                stacklevel=2)
        return self.finished

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict[str, Any]:
        """Serving counters since construction (host_syncs counts one per
        decode-window harvest plus one per admission wave).

        Safe to call mid-stream: window-scoped sums and ``windows``
        advance atomically at each harvest, and the instantaneous
        ``occupancy``/``queue_depth`` read the scheduler — the host-side
        truth at every window boundary — never the device mirror's
        active flags (which are stale between harvests)."""
        tokens = self.tokens_emitted + self._admit_tokens
        w = max(self.windows, 1)
        return {
            "tokens": tokens,
            "windows": self.windows,
            "sync_every": self.sync_every,
            "mesh": self.mesh_str,
            "host_syncs": self.host_syncs,
            "admission_syncs": self.admission_syncs,
            "host_syncs_per_token": self.host_syncs / max(tokens, 1),
            "decode_syncs_per_token": self.windows / max(self.tokens_emitted, 1),
            "occupancy": self.scheduler.occupancy,
            "queue_depth": self.scheduler.queue_depth,
            "occupancy_mean": self._occupancy_sum / w,
            "queue_depth_mean": self._queue_depth_sum / w,
            "run_seconds": self._run_seconds,
            "tokens_per_s": tokens / self._run_seconds
                            if self._run_seconds else 0.0,
        }
