"""Slot-based continuous-batching serving engine over the latent KV cache.

A fixed pool of B slots holds independent sequences at arbitrary positions
(per-slot ``cur``); each engine step runs ONE batched decode_step across
all active slots, samples, appends, admits queued requests into freed
slots, and returns finished sequences.  Prefill runs aligned/right-padded
per admission wave and scatters the new latents into the slot's rows of the
shared cache.

With ReCalKV enabled the resident cache is the *latent* ring — at 50%
compression the same HBM holds 2x the slots (the paper's serving win).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new_tokens:
            return True
        return bool(self.out_tokens) and self.out_tokens[-1] == self.eos_id


def _merge_slot(pool_cache, new_cache, slots: jax.Array):
    """Copy ``new_cache``'s leading batch rows into ``pool_cache`` at
    ``slots`` (the prefill wave may be padded past ``len(slots)`` rows for
    shape bucketing — the pad rows are dropped here).

    Batch is dim 0 for prefix/suffix caches but dim 1 under the scanned
    "blocks" subtree (leading dim = pattern periods)."""
    n = slots.shape[0]
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        if key0 == "blocks":
            return pool.at[:, slots].set(new[:, :n].astype(pool.dtype))
        return pool.at[slots].set(new[:n].astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two, capped: the (wave, prompt-len) shapes a
    long-running engine sees collapse to O(log) values instead of one jit
    retrace per distinct admission wave."""
    return min(max(1, 1 << (n - 1).bit_length()), max(cap, n))


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, source: jax.Array | None = None,
                 backend: str | None = None):
        if backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=backend)
        self.cfg, self.params = cfg, params
        self.B, self.max_len = max_slots, max_len
        self.source = source
        self.cache = T.init_decode_cache(cfg, max_slots, max_len)
        self.cur = np.zeros(max_slots, np.int64)          # next position
        self.slot_req: list[Request | None] = [None] * max_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, cur, act: T.decode_step(cfg, p, c, t, cur, act))
        self._prefill = jax.jit(
            lambda p, t, l: T.prefill(cfg, p, t, l, max_len=max_len,
                                      source=None if source is None
                                      else source[: t.shape[0]]),
            static_argnames=())

    @classmethod
    def from_artifact(cls, path: str, *, max_slots: int, max_len: int,
                      source: jax.Array | None = None,
                      backend: str | None = None) -> "Engine":
        """Boot an engine straight from a saved compression artifact —
        the compress-offline / serve-forever workflow across processes."""
        from repro.api import load_artifact  # local: api imports models too

        art = load_artifact(path)
        return cls(art.cfg, art.params, max_slots=max_slots, max_len=max_len,
                   source=source, backend=backend)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        free = self._free_slots()
        wave = []
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            wave.append((slot, req))
        if not wave:
            return
        # Bucket the wave to power-of-two (rows, prompt-len) shapes so a
        # stream of ragged admissions reuses O(log) jit traces.  The row
        # cap is the slot count; the length cap is max_len (padding past
        # the ring would silently drop a fittable prompt prefix).
        P_real = max(len(r.prompt) for _, r in wave)
        W = _bucket(len(wave), self.B)
        P = _bucket(P_real, self.max_len)
        toks = np.zeros((W, P), np.int32)
        lens = np.zeros((W,), np.int32)
        for i, (_, r) in enumerate(wave):
            toks[i, : len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        logits, new_cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        slots = jnp.asarray([s for s, _ in wave])
        self.cache = _merge_slot(self.cache, new_cache, slots)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for i, (slot, r) in enumerate(wave):
            r.out_tokens.append(int(first[i]))
            self.cur[slot] = lens[i]

    # -- one engine step ----------------------------------------------------

    def step(self):
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros(self.B, np.int32)
        act = np.zeros(self.B, bool)
        for i in active:
            toks[i] = self.slot_req[i].out_tokens[-1]
            act[i] = True
        # Inactive slots still ride through the batched step (their logits
        # are discarded) but the active mask freezes their cache rows — a
        # freed slot stays inert instead of ring-writing garbage at its
        # stale cur every step.
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.cur, jnp.int32), jnp.asarray(act))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            r = self.slot_req[i]
            self.cur[i] += 1
            r.out_tokens.append(int(nxt[i]))
            if r.done or self.cur[i] >= self.max_len - 1:
                self.finished.append(r)
                self.slot_req[i] = None

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
