"""Executor: continuous batching over the latent KV cache with a fused,
device-resident multi-token decode loop.

The serving subsystem is split three ways:

  scheduler.py  admission policy, slot lifecycle, chunked prefill (host)
  sampler.py    on-device temperature / top-k / top-p / greedy sampling
  pipeline.py   overlapped-serving plumbing: in-flight window records +
                the backlog worker thread that drains token handling
  engine.py     this file — the executor.  One ``jax.lax.scan`` window
                runs ``sync_every`` decode steps entirely on device
                (feed -> decode_step -> sample -> append -> termination),
                carrying last-token, cur, active-mask, PRNG keys, ingest
                buffers and done-flags as device state.  The host is
                touched once per window: harvest emitted tokens, retire
                finished slots, refill prompt-ingest buffers, and run
                admission (batched, shape-bucketed wave prefill).  With
                ``overlap=True`` that boundary work pipelines against the
                NEXT window already running on device (double buffering),
                and with ``aot=True`` every executable is compiled at
                construction.

The engine is MESH-NATIVE: ``Engine(mesh=...)`` device-puts params via
``sharding.rules.param_specs`` and jits the window with explicit
``in_shardings``/``out_shardings`` — cache rings sharded slot x sequence
per ``CACHE_RULES`` (the softmax over the sharded S axis becomes a psum
LSE merge; the latent ``A @ z_v`` contraction psums only a tiny
``(B, H, r_v)``, the low-rank win compounding with tensor parallelism),
and the rest of the device carry (last-token, cur, active, per-slot PRNG
keys, ingest buffer) sharded on the slot axis per ``carry_specs``.
Without a mesh the engine runs on a degenerate (1, 1) mesh — the sharded
window IS the single-device path, not a branch.

Chunked prefill rides the same loop: a long prompt's first
``prefill_chunk`` tokens go through the wave prefill; the remainder sits
in a per-slot device buffer and is *fed* through decode steps (cache
writes at the token's true position, sampled outputs discarded until the
final prompt token), so decode-phase slots keep emitting between chunks.

Speculative decoding (``spec_depth > 0``) upgrades each window iteration
from one token to up to ``spec_depth + 1``: a draft (prompt-lookup
n-gram, or the target's own first K layers — see ``serving.draft``)
proposes ``spec_depth`` tokens, and ONE multi-token ``T.verify_step``
scores all proposals against target logits.  Acceptance is the
deterministic specialization of accept/reject-with-residual-resampling:
the per-slot sampler (policy + key stream) is a deterministic function,
so a proposal is accepted iff it equals the token the target would have
emitted, and the first rejection emits the target's own draw (the
residual collapses onto it).  Keys still advance once per *emitted*
token and rejected proposals never touch any ring, so token streams are
invariant to speculation depth — the draft buys step-count, never
changes output.  The accept mask and fed-token history ride the same
slot-sharded device carry (``rules.carry_specs``); no new collectives.

With ReCalKV enabled the resident cache is the *latent* ring — at 50%
compression the same HBM holds 2x the slots (the paper's serving win).
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
import warnings
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import single_device_mesh
from repro.models import kv_cache as KC
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import draft as D
from repro.serving import sampler as S
from repro.serving.draft import DraftSpec
from repro.serving.pages import PagePool, PrefixRegistry, prefix_key
from repro.serving.pipeline import (AdmissionWorker, InflightWindow,
                                    PreemptedRecord, StagedEntry, StagedWave,
                                    TokenBacklog)
from repro.serving.policy import AdmissionPolicy, get_policy
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Request, Scheduler
from repro.sharding import rules as R

__all__ = ["Engine", "Request", "SamplingParams", "DraftSpec"]

# Sentinel for an empty device-side staging row: the in-scan install
# picks argmin(seq), so the max int32 sorts every real (monotonically
# assigned) staging sequence number ahead of every free row.
STAGE_FREE = np.iinfo(np.int32).max


def _array_ready(x) -> bool:
    """True when a device array's computation has already completed (the
    dispatch-side probe behind the ``window_overlap`` metric)."""
    try:
        return bool(x.is_ready())
    except AttributeError:          # older jax: no probe, call it ready
        return True


def _merge_slot(pool_cache, new_cache, slots: jax.Array, rows=None):
    """Copy ``new_cache`` batch rows into ``pool_cache`` at ``slots``.
    Without ``rows`` the leading ``len(slots)`` source rows are taken
    (the prefill wave may be padded past that for shape bucketing — the
    pad rows are dropped here); with ``rows`` (same length as ``slots``)
    an arbitrary subset of wave rows merges, which is how a staged wave
    larger than the free slots merges across several boundaries.

    Batch is dim 0 for prefix/suffix caches but dim 1 under the scanned
    "blocks" subtree (leading dim = pattern periods)."""
    n = slots.shape[0]
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        if key0 == "blocks":
            src = new[:, rows] if rows is not None else new[:, :n]
            return pool.at[:, slots].set(src.astype(pool.dtype))
        src = new[rows] if rows is not None else new[:n]
        return pool.at[slots].set(src.astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _merge_slot_paged(pool_cache, new_cache, rows: jax.Array,
                      cols: jax.Array, phys: jax.Array, page_size: int):
    """Scatter prefill rows into the PAGED pool: ``new_cache`` is
    slot-major (W, Lr, ...); tile (rows[t], cols[t]) — slot row, logical
    page index — lands in physical page ``phys[t]`` of the page-major
    pool (n_pages, page_size, ...).  Shared prefix pages are simply
    absent from (rows, cols, phys): their content is already resident,
    so admission never rewrites them (copy-on-write by omission)."""
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        ps = page_size
        if key0 == "blocks":
            n_per, W = new.shape[0], new.shape[1]
            tiles = new.reshape((n_per, W, new.shape[2] // ps, ps)
                                + new.shape[3:])[:, rows, cols]
            return pool.at[:, phys].set(tiles.astype(pool.dtype))
        W = new.shape[0]
        tiles = new.reshape((W, new.shape[1] // ps, ps)
                            + new.shape[2:])[rows, cols]
        return pool.at[phys].set(tiles.astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two, capped: the (wave, prompt-len) shapes a
    long-running engine sees collapse to O(log) values instead of one jit
    retrace per distinct admission wave."""
    return min(max(1, 1 << (n - 1).bit_length()), max(cap, n))


class Engine:
    """Slot-based continuous-batching executor.

    ``sync_every`` sets the decode window: tokens decoded per
    host round-trip.  Large windows amortize dispatch and host syncs
    (throughput); small windows tighten admission latency for queued
    requests and finished-slot turnaround (latency).
    ``prefill_chunk`` bounds how much prompt one admission wave prefills
    at once; the remainder streams through the decode loop.
    ``mesh`` is a ("data", "model") jax Mesh (see ``launch.mesh``); the
    slot axis shards over "data", the cache ring's sequence axis over
    "model".  Default: a (1, 1) single-device mesh.
    ``spec_depth`` turns on speculative decoding: up to that many draft
    tokens verified per window iteration (0 disables).  ``draft`` picks
    the proposer — "ngram" (default) or "layers:K" (self-draft from the
    target's first K layers); token streams are invariant to both knobs.
    ``overlap`` switches the step loop to the double-buffered pipeline:
    two windows in flight, the host blocking only on the *trailing*
    window's packed status, token handling on a backlog worker thread,
    and admission prefill dispatched concurrently with in-flight decode.
    Token streams are invariant to ``overlap`` (the async↔sync parity
    contract).  ``aot`` lowers + compiles the fused window and every
    reachable power-of-two (wave, prompt-len) prefill bucket at
    construction, so the first request pays load time, not trace time.
    ``pipeline_depth`` generalizes the double buffer to N windows in
    flight; ``continuous`` adds the device-side staging queue + in-scan
    slot swap; ``admission_thread`` moves wave prefill staging onto a
    worker thread (default: on whenever overlap is); ``adaptive_spec``
    degrades cold-draft slots to plain decode at window boundaries;
    ``pin_prefixes`` pins the K hottest registered prefix pages against
    pool recycling; ``profile`` records a host-boundary stage timeline.
    """

    # adaptive speculation: degrade a slot once it has proposed at least
    # MIN_PROPOSED draft tokens with an accept rate below ACCEPT_FLOOR
    ADAPTIVE_MIN_PROPOSED = 8
    ADAPTIVE_ACCEPT_FLOOR = 0.25

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, source: jax.Array | None = None,
                 backend: str | None = None,
                 sampling: SamplingParams | None = None,
                 sync_every: int = 8, prefill_chunk: int | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 spec_depth: int = 0,
                 draft: str | DraftSpec | None = None,
                 cache_layout: str = "ring",
                 page_size: int | None = None,
                 n_pages: int | None = None,
                 overlap: bool = False,
                 aot: bool = False,
                 pipeline_depth: int = 2,
                 continuous: bool = False,
                 admission_thread: bool | None = None,
                 pin_prefixes: int = 0,
                 adaptive_spec: bool = False,
                 profile: bool = False,
                 policy: str | AdmissionPolicy | None = None,
                 lazy_pages: bool = False,
                 staging_depth: int | None = None):
        if backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=backend)
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if cache_layout not in ("ring", "paged"):
            raise ValueError(f"cache_layout={cache_layout!r}: expected "
                             f"'ring' or 'paged'")
        if cache_layout == "ring" and (page_size is not None
                                       or n_pages is not None):
            raise ValueError(
                "page_size/n_pages only apply to cache_layout='paged'")
        self.cache_layout = cache_layout
        self.page_size = self.n_pages = None
        self._pages: PagePool | None = None
        if cache_layout == "paged":
            kinds = set(cfg.expanded_layers())
            bad = sorted(k for k in kinds
                         if k in ("mamba", "rglru", "cross", "attn_cross"))
            if bad:
                raise ValueError(
                    f"cache_layout='paged' needs position-addressed "
                    f"self-attention rings; {cfg.name} has {bad} blocks")
            short = sorted(k for k in kinds
                           if cfg.cache_len(k, max_len) != max_len)
            if short:
                raise ValueError(
                    f"cache_layout='paged' needs full-length rings; "
                    f"{short} blocks keep ring length < max_len={max_len}")
            if page_size is None:
                page_size = next(p for p in (16, 8, 4, 2, 1)
                                 if max_len % p == 0)
            if page_size < 1 or max_len % page_size:
                raise ValueError(f"page_size={page_size} must be >= 1 and "
                                 f"divide max_len={max_len}")
            n_sp = max_len // page_size
            if n_pages is None:
                # ring-equivalent capacity plus the reserved null page;
                # smaller pools trade concurrency headroom for memory
                n_pages = max_slots * n_sp + 1
            if n_pages < n_sp + 1:
                raise ValueError(
                    f"n_pages={n_pages} cannot hold one full-length "
                    f"request ({n_sp} pages + the reserved null page)")
            self.page_size, self.n_pages = page_size, n_pages
            self._pages = PagePool(n_pages)
            self._prefixes = PrefixRegistry()
            self._slot_pages: list[list[int]] = [[] for _ in
                                                 range(max_slots)]
            # The pallas decode path tiles the ring at attn_block; pin it
            # to page_size so the paged kernel's page-per-tile walk is
            # bitwise-identical to the ring kernel's tile sequence (the
            # paged <-> ring parity contract).
            cfg = dataclasses.replace(cfg, attn_block=page_size)
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if pipeline_depth != 2 and not overlap:
            raise ValueError("pipeline_depth is the overlapped engine's "
                             "in-flight window budget; set overlap=True")
        if continuous and not overlap:
            raise ValueError("continuous batching (in-window slot swap) "
                             "requires overlap=True")
        if admission_thread and not overlap:
            raise ValueError("admission_thread requires overlap=True (the "
                             "sync engine admits inline by definition)")
        if continuous:
            parsed = DraftSpec.parse(draft)
            if parsed is not None and parsed.kind == "layers":
                raise ValueError(
                    "continuous batching is incompatible with the layer-"
                    "fraction draft: its slot-major ring has no staged "
                    "twin for the in-scan install")
        if adaptive_spec and spec_depth == 0:
            raise ValueError("adaptive_spec degrades speculative depth "
                             "per slot; it needs spec_depth > 0")
        if pin_prefixes < 0:
            raise ValueError("pin_prefixes must be >= 0")
        if pin_prefixes > 0 and cache_layout != "paged":
            raise ValueError("pin_prefixes pins page-pool prefixes; it "
                             "needs cache_layout='paged'")
        resolved_policy = get_policy(policy)
        if resolved_policy.groups_by_prefix and cache_layout != "paged":
            raise ValueError(
                f"policy={resolved_policy.name!r} groups admissions by "
                f"shared prompt prefix; it needs cache_layout='paged' "
                f"(the prefix registry lives in the page pool)")
        if lazy_pages:
            if cache_layout != "paged":
                raise ValueError("lazy_pages defers page reservation; it "
                                 "needs cache_layout='paged'")
            if continuous:
                raise ValueError(
                    "lazy_pages is incompatible with continuous batching: "
                    "the in-scan installer hands slots over mid-window, so "
                    "boundary-granular page top-up/preemption cannot tell "
                    "whose reach it is covering")
            parsed = DraftSpec.parse(draft)
            if parsed is not None and parsed.kind == "layers":
                raise ValueError(
                    "lazy_pages is incompatible with the layer-fraction "
                    "draft: the draft's slot-major ring is not paged, so "
                    "a preempted slot's draft state cannot be rebuilt")
        if staging_depth is not None and staging_depth < 1:
            raise ValueError("staging_depth must be >= 1")
        if spec_depth < 0:
            raise ValueError("spec_depth must be >= 0")
        if spec_depth > 0:
            bad = [k for k in cfg.expanded_layers() if k in ("mamba",
                                                             "rglru")]
            if bad:
                raise ValueError(
                    f"spec_depth > 0 needs position-addressed caches; "
                    f"{cfg.name} has recurrent {sorted(set(bad))} blocks "
                    f"whose state cannot roll back a rejected token")
        self.cfg = cfg
        self.B, self.max_len = max_slots, max_len
        self.source = source
        self.sampling = sampling or S.GREEDY
        self.sync_every = sync_every
        self.spec_depth = spec_depth
        parsed_draft = DraftSpec.parse(draft)
        if parsed_draft is not None and spec_depth == 0:
            raise ValueError(
                f"draft={draft!r} requires spec_depth > 0 — a draft with "
                f"no speculation depth would be silently ignored")
        self.draft = (parsed_draft or DraftSpec("ngram")
                      if spec_depth > 0 else None)
        self.mesh = mesh if mesh is not None else single_device_mesh()
        # Backend telemetry + loud fallback: a requested pallas backend
        # still routes some layer kinds through einsum (absorbed-MLA
        # attention, cross-attention halves) — warn once so
        # backend="pallas" is never silently a no-op, and record what
        # the decode/verify steps will actually run for metrics().
        if cfg.attn_backend == "pallas":
            fallback = KC.pallas_fallback_kinds(cfg)
            if fallback:
                warnings.warn(
                    f"backend='pallas': layer kinds {fallback} have no "
                    f"pallas decode kernel and fall back to einsum",
                    RuntimeWarning, stacklevel=2)
        n_seq_shards = R.kernel_seq_shards(self.mesh)
        seq_cols = page_size if page_size is not None else max_len
        self._decode_kernel_sharded = bool(
            cfg.attn_backend == "pallas" and cfg.mla is None
            and n_seq_shards > 1 and seq_cols % n_seq_shards == 0)
        self._verify_backend = (
            None if spec_depth == 0
            else "pallas" if (cfg.attn_backend == "pallas"
                              and cfg.mla is None)
            else "einsum")
        # slots-per-shard admission locality: only meaningful when the
        # slot axis actually shards (divisible); else one logical shard
        n_slot_shards = math.prod(
            self.mesh.shape[a] for a in R.batch_axes(self.mesh))
        if n_slot_shards < 1 or max_slots % n_slot_shards:
            n_slot_shards = 1
        self.scheduler = Scheduler(max_slots, max_len,
                                   prefill_chunk=prefill_chunk,
                                   slot_shards=n_slot_shards,
                                   policy=resolved_policy)
        self.policy = self.scheduler.policy
        self.policy.configure(
            page_size=self.page_size,
            registry=self._prefixes if self._pages is not None else None)
        self.lazy_pages = bool(lazy_pages)
        # staging look-ahead: how many requests may sit prefilled-but-
        # unmerged ahead of free slots (satellite: decoupled from B)
        self.staging_depth = (int(staging_depth) if staging_depth is not None
                              else 2 * max_slots)
        # Mesh-native placement: params by PARAM_RULES (TP heads / FSDP),
        # the pooled cache rings by CACHE_RULES (slot x sequence).
        param_shardings = R.to_named(
            R.param_specs(params, self.mesh, grains=R.head_grains(cfg)),
            self.mesh)
        self.params = jax.device_put(params, param_shardings)
        cache = T.init_decode_cache(
            cfg, max_slots, max_len,
            pages=None if self._pages is None
            else (self.n_pages, self.page_size))
        self._cache_shardings = R.to_named(
            R.cache_specs(cache, self.mesh), self.mesh)
        self.cache = jax.device_put(cache, self._cache_shardings)
        # Layer-fraction draft: a VIEW over the target's first K layers
        # (no new weights) with its own — much smaller — ring cache,
        # sharded by the same rules and carried through the window.
        self.draft_params = self.draft_cache = None
        self._draft_cfg = self._draft_cache_shardings = None
        draft_param_shardings = None
        if self.draft is not None and self.draft.kind == "layers":
            dcfg, dparams = D.make_layer_draft(cfg, self.params,
                                               self.draft.layers)
            self._draft_cfg = dcfg
            draft_param_shardings = R.to_named(
                R.param_specs(dparams, self.mesh,
                              grains=R.head_grains(dcfg)), self.mesh)
            self.draft_params = jax.device_put(dparams,
                                               draft_param_shardings)
            dcache = T.init_decode_cache(dcfg, max_slots, max_len)
            self._draft_cache_shardings = R.to_named(
                R.cache_specs(dcache, self.mesh), self.mesh)
            self.draft_cache = jax.device_put(
                dcache, self._draft_cache_shardings)
        self.finished: list[Request] = []
        # per-slot host mirror of the device loop state (synced once per
        # window); the cache itself never leaves the device
        W = prefill_chunk or 1
        self._st: dict[str, np.ndarray] = {
            "tok": np.zeros(max_slots, np.int32),
            "cur": np.zeros(max_slots, np.int32),
            "act": np.zeros(max_slots, bool),
            "keys": np.zeros((max_slots, 2), np.uint32),
            "temp": np.zeros(max_slots, np.float32),
            "top_k": np.zeros(max_slots, np.int32),
            "top_p": np.ones(max_slots, np.float32),
            "eos": np.full(max_slots, -1, np.int32),
            "left": np.zeros(max_slots, np.int32),
            "buf": np.zeros((max_slots, W), np.int32),
            "avail": np.zeros(max_slots, np.int32),
            "bpos": np.zeros(max_slots, np.int32),
            "more": np.zeros(max_slots, bool),
        }
        if spec_depth > 0:
            # fed-token history: the n-gram draft's corpus, rebuilt from
            # the prompt at admission and extended on-device as tokens
            # are fed (a (B, max_len) carry leaf under carry_specs)
            self._st["hist"] = np.zeros((max_slots, max_len), np.int32)
        if adaptive_spec:
            # per-slot speculation gate: the window skips proposing for
            # slots degraded to plain decode (a cold draft's proposals
            # cost a wider verify for nothing).  Streams are invariant
            # to any spec_on schedule (deterministic accept/residual).
            self._st["spec_on"] = np.ones(max_slots, bool)
        if continuous:
            # per-slot generation counter, bumped by every in-scan
            # install: host scatters onto the live carry (refills,
            # degrades) are gen-guarded, so a scatter aimed at a slot
            # the device already handed to a NEW request drops instead
            # of clobbering it.
            self._st["gen"] = np.zeros(max_slots, np.int32)
        if self._pages is not None:
            # slot -> physical-page table: the device-side indirection the
            # paged readers/writers resolve through.  Unmapped logical
            # pages point at the reserved null page 0 (pos -1 there keeps
            # the bias masking them out); rides carry_specs on slot dim 0.
            self._st["ptab"] = np.zeros(
                (max_slots, max_len // self.page_size), np.int32)
        # metrics (sums and `windows` advance atomically at each window
        # boundary in _harvest, so metrics() mid-stream is consistent;
        # under overlap the backlog worker holds _mlock for its share)
        self.host_syncs = 0          # device->host harvest points
        self.admission_syncs = 0     # host_syncs spent on wave prefills
        self.windows = 0             # completed (harvested) windows
        self.windows_idle = 0        # harvested windows that emitted 0
        self.tokens_emitted = 0      # emitted by decode windows
        self._admit_tokens = 0       # first tokens emitted at admission
        self._occupancy_sum = 0
        self._queue_depth_sum = 0
        self._run_seconds = 0.0
        self.draft_proposed = 0      # draft tokens fed to verification
        self.draft_accepted = 0      # ... accepted (free extra tokens)
        self._mlock = threading.Lock()
        self._ttft_sum = 0.0         # summed submit -> first-token latency
        self._ttft_n = 0
        self.preemptions = 0         # slots evicted by lazy reservation
        self.prefill_calls = 0       # admission wave-prefill dispatches
        self.prefill_calls_saved = 0  # admissions served without a prefill
        self._preempted: deque[PreemptedRecord] = deque()

        # -- overlapped-pipeline state (inert when overlap=False) --------
        self.overlap = bool(overlap)
        self.aot = bool(aot)
        self.pipeline_depth = pipeline_depth
        self.continuous = bool(continuous)
        self.adaptive_spec = bool(adaptive_spec)
        self.admission_thread = (bool(overlap) if admission_thread is None
                                 else bool(admission_thread))
        self.pin_prefixes = pin_prefixes
        self.profile = bool(profile)
        self._inflight: deque[InflightWindow] = deque()
        self._st_dev: dict | None = None     # device-resident carry
        self._dispatch_index = 0             # windows dispatched so far
        self._overlapped_windows = 0         # dispatched before prior done
        # per-slot dispatch-index watermarks: a harvested window's status
        # is STALE for any slot (re)admitted or refilled at a later
        # boundary — without these, a fresh request would be "finished" by
        # its predecessor's death, and a refilled buffer re-refilled.
        self._slot_epoch = np.zeros(max_slots, np.int64)
        self._buf_epoch = np.zeros(max_slots, np.int64)
        self._backlog = TokenBacklog() if self.overlap else None
        self._repl = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
        # threaded-admission + continuous-batching state.  _sched_lock
        # guards the queue <-> staged handoff (the only scheduler surface
        # the admission worker touches); everything else scheduler-side
        # stays main-thread.
        self._sched_lock = threading.Lock()
        self._staged_waves: deque[StagedWave] = deque()
        self._stage_tab: list[StagedEntry | None] = [None] * max_slots
        self._stage_by_seq: dict[int, tuple[int, StagedEntry]] = {}
        self._stage_seq_next = 0
        self._stage_dev: dict | None = None
        self.slot_swaps = 0            # in-scan installs confirmed
        self._act_iters = 0            # sum of per-iteration stepping slots
        self.spec_degraded = 0         # slots degraded to plain decode
        self._spec_acc = np.zeros(max_slots, np.int64)
        self._spec_prop = np.zeros(max_slots, np.int64)
        self._prefix_hits: dict[int, int] = {}   # page -> registry hits
        # host-boundary profiler: per-stage wall-clock sums (always on —
        # the counters are cheap); profile=True additionally records a
        # bounded event timeline for serving_bench --profile.
        self._prof = {k: 0.0 for k in
                      ("dispatch", "harvest", "bookkeep", "admission_stage",
                       "backlog_drain")}
        self._prof_events: list[dict] = []
        self._prof_t0 = time.perf_counter()
        self._admission: AdmissionWorker | None = None
        if self.overlap and self.admission_thread:
            self._admission = AdmissionWorker(self._take_staged_locked,
                                              self._prepare_wave)

        # trace-count hooks: the counters bump inside the traced python
        # functions, so they advance exactly once per (re)trace — the AOT
        # smoke check asserts they stay flat while serving.
        self.trace_counts = {"window": 0, "prefill": 0, "draft_prefill": 0}

        def _prefill_fn(p, t, l):
            self.trace_counts["prefill"] += 1
            return T.prefill(cfg, p, t, l, max_len=max_len,
                             source=None if source is None
                             else source[: t.shape[0]])

        self._prefill_jit = jax.jit(_prefill_fn)
        self._prefill = self._prefill_jit
        self._prefill_exec: dict[tuple, Any] = {}
        if self.draft_cache is not None:
            dcfg = self._draft_cfg

            def _draft_prefill_fn(p, t, l):
                self.trace_counts["draft_prefill"] += 1
                return T.prefill(dcfg, p, t, l, max_len=max_len,
                                 source=None if source is None
                                 else source[: t.shape[0]])

            self._draft_prefill_jit = jax.jit(_draft_prefill_fn)
            self._draft_prefill = self._draft_prefill_jit
            self._draft_prefill_exec: dict[tuple, Any] = {}
        # Donate the cache buffer(s) into the window: self.cache is
        # rebound to the output, so XLA can update the ring in place
        # instead of holding two full caches live — the cache IS the HBM
        # footprint the paper halves.  (CPU ignores donation and would
        # warn, so only donate where it takes effect.)
        # continuous batching: a B-row device staging area — seq keys
        # (STAGE_FREE = empty), one carry row per staged request, and
        # (ring only) a stage cache tree the in-scan install copies a
        # slot row out of.  Paged mode needs no stage cache: a staged
        # request's pages are scattered straight into the shared pool at
        # stage time (they are freshly allocated, so no live reader can
        # see them until its ptab row installs).
        stage_tpl = None
        if self.continuous:
            stage_tpl = {
                "seq": np.full(max_slots, STAGE_FREE, np.int32),
                "rows": {k: np.zeros((max_slots,) + v.shape[1:], v.dtype)
                         for k, v in self._st.items()},
            }
            if self._pages is None:
                stage_tpl["cache"] = T.init_decode_cache(cfg, max_slots,
                                                         max_len)
        in_sh, out_sh = R.window_shardings(
            self.mesh, self.params, self.cache, self._st,
            param_shardings=param_shardings,
            cache_shardings=self._cache_shardings,
            draft_params=self.draft_params, draft_cache=self.draft_cache,
            draft_param_shardings=draft_param_shardings,
            draft_cache_shardings=self._draft_cache_shardings,
            spec_outputs=spec_depth > 0, stage=stage_tpl)
        logits_spec = jax.sharding.NamedSharding(
            self.mesh, R.slot_stacked_spec(max_slots, self.mesh,
                                           lead_dims=0))
        if spec_depth == 0:
            window_fn = self._make_window(
                cfg, max_len, sync_every,
                cache_shardings=self._cache_shardings,
                logits_spec=logits_spec, page_size=self.page_size,
                mesh=self.mesh, continuous=self.continuous)
            donate = (1,)
        else:
            window_fn = self._make_spec_window(
                cfg, max_len, sync_every, spec_depth, draft=self.draft,
                draft_cfg=self._draft_cfg,
                cache_shardings=self._cache_shardings,
                draft_cache_shardings=self._draft_cache_shardings,
                logits_spec=logits_spec, page_size=self.page_size,
                mesh=self.mesh, continuous=self.continuous,
                adaptive=self.adaptive_spec)
            donate = (2, 3) if self.draft_cache is not None else (1,)
        if jax.default_backend() == "cpu":
            donate = ()
        if self.draft_cache is not None:
            def counted_fn(params, dparams, cache, dcache, st):
                self.trace_counts["window"] += 1
                return window_fn(params, dparams, cache, dcache, st)
        elif self.continuous:
            def counted_fn(params, cache, st, stage):
                self.trace_counts["window"] += 1
                return window_fn(params, cache, st, stage)
        else:
            def counted_fn(params, cache, st):
                self.trace_counts["window"] += 1
                return window_fn(params, cache, st)
        self._window = jax.jit(counted_fn, donate_argnums=donate,
                               in_shardings=in_sh, out_shardings=out_sh)
        # the carry subtree of in_shardings, for committed state placement
        # (the overlapped pipeline and AOT executables both need inputs
        # that already sit where the compiled window expects them)
        if self.continuous:
            self._carry_sh = in_sh[-2]
            self._stage_sh = in_sh[-1]
            self._stage_dev = jax.device_put(stage_tpl, self._stage_sh)
        else:
            self._carry_sh = in_sh[-1]
        if self.aot:
            self._aot_compile()

    # -- AOT warmup ----------------------------------------------------------

    def _aot_compile(self):
        """Lower + compile the window and every reachable prefill bucket
        now, so serving never traces: the (wave, prompt-len) shapes
        ``_bucket`` can produce form a small closed set, and the window's
        shapes are fixed at construction."""
        st = {k: jax.device_put(v, self._carry_sh[k])
              for k, v in self._st.items()}
        if self.draft_cache is not None:
            args = (self.params, self.draft_params, self.cache,
                    self.draft_cache, st)
        elif self.continuous:
            args = (self.params, self.cache, st, self._stage_dev)
        else:
            args = (self.params, self.cache, st)
        self._window = self._window.lower(*args).compile()
        cap = self.max_len - 1                  # submit() prompt cap
        if self.scheduler.prefill_chunk is not None:
            cap = min(cap, self.scheduler.prefill_chunk)
        wcap = max(self.B, self.staging_depth)
        waves = sorted({_bucket(n, wcap) for n in range(1, wcap + 1)})
        plens = sorted({_bucket(n, self.max_len) for n in range(1, cap + 1)})
        for w in waves:
            for p in plens:
                t = jax.ShapeDtypeStruct((w, p), jnp.int32,
                                         sharding=self._repl)
                ln = jax.ShapeDtypeStruct((w,), jnp.int32,
                                          sharding=self._repl)
                self._prefill_exec[(w, p)] = self._prefill_jit.lower(
                    self.params, t, ln).compile()
                if self.draft_cache is not None:
                    self._draft_prefill_exec[(w, p)] = \
                        self._draft_prefill_jit.lower(
                            self.draft_params, t, ln).compile()
        self._prefill = self._make_prefill_dispatch(
            self._prefill_jit, self._prefill_exec)
        if self.draft_cache is not None:
            self._draft_prefill = self._make_prefill_dispatch(
                self._draft_prefill_jit, self._draft_prefill_exec)

    @staticmethod
    def _make_prefill_dispatch(jit_fn, executables):
        def dispatch(p, t, l):
            exe = executables.get(tuple(t.shape))
            return (jit_fn if exe is None else exe)(p, t, l)
        return dispatch

    def _prefill_args(self, toks: np.ndarray, lens: np.ndarray):
        """Device placement for wave-prefill inputs.  AOT executables
        require committed arrays matching the lowered shardings; the
        plain jit path keeps the cheaper uncommitted upload."""
        if self.aot:
            return (jax.device_put(toks, self._repl),
                    jax.device_put(lens, self._repl))
        return jnp.asarray(toks), jnp.asarray(lens)

    # -- fused decode window -------------------------------------------------

    @staticmethod
    def _stage_install(st, cache, seq, stage, max_len=None):
        """The device half of continuous batching: install (at most) the
        FIFO-head staged request into the lowest free slot.  ``seq`` is a
        scan CARRY — clearing the installed entry there makes the install
        exactly-once across any pipeline depth (later windows chain on
        this window's seq output).  Rows/cache are read-only inputs.  A
        full batch (or an empty stage) degenerates to an out-of-range
        scatter index, which ``mode="drop"`` turns into a no-op — no
        branch, so the window stays one trace."""
        B = st["act"].shape[0]
        q = jnp.argmin(seq).astype(jnp.int32)
        have = seq[q] != STAGE_FREE
        slot = jnp.argmax(~st["act"]).astype(jnp.int32)
        do = have & ~st["act"][slot]
        tgt = jnp.where(do, slot, B)
        st2 = {}
        for k, v in st.items():
            if k == "gen":
                # generation bump, NOT a copy: the host's gen-guarded
                # scatters key off this to drop writes aimed at the
                # slot's previous occupant
                st2[k] = v.at[tgt].add(1, mode="drop")
            else:
                st2[k] = v.at[tgt].set(
                    jnp.take(stage["rows"][k], q, axis=0), mode="drop")
        if "cache" in stage:
            cache = T.swap_cache_slot(cache, stage["cache"], tgt, q)
        seq2 = seq.at[jnp.where(do, q, B)].set(STAGE_FREE, mode="drop")
        sw_seq = jnp.where(do, seq[q], -1).astype(jnp.int32)
        sw_slot = jnp.where(do, slot, -1).astype(jnp.int32)
        return st2, cache, seq2, sw_seq, sw_slot

    @staticmethod
    def _make_window(cfg: ModelConfig, max_len: int, steps: int, *,
                     cache_shardings=None, logits_spec=None,
                     page_size: int | None = None, mesh=None,
                     continuous: bool = False):
        """Build the jitted window fn: ``steps`` fused decode iterations.

        Per iteration, per slot: pick the fed token (ingest buffer while
        prompt remains, else last sampled), run one batched decode_step
        (inactive/stalled rows masked from cache writes), sample, then
        update emit/termination flags — all under one lax.scan, so the
        only host sync is the caller harvesting the stacked outputs.

        ``continuous`` threads the device staging queue through the scan
        (see ``_stage_install``): each iteration may refill one freed
        slot from staged state before stepping, so a mid-window death
        costs idle iterations only until the next staged head, not until
        the boundary.

        ``cache_shardings``/``logits_spec`` pin the scan carry's ring
        layout and the sampler's slot-sharded logits so the loop body
        never reshards mid-scan (the mesh must not smuggle per-step
        transfers back in)."""

        def window(params, cache, st, stage=None):
            def body(carry, _):
                if continuous:
                    cache, st, seq = carry
                    st, cache, seq, sw_seq, sw_slot = Engine._stage_install(
                        st, cache, seq, stage)
                else:
                    cache, st = carry
                feeding = st["bpos"] < st["avail"]
                buf_tok = jnp.take_along_axis(
                    st["buf"],
                    jnp.minimum(st["bpos"], st["buf"].shape[1] - 1)[:, None],
                    axis=1)[:, 0]
                tok_in = jnp.where(feeding, buf_tok, st["tok"])
                # a slot whose ingest buffer drained but has prompt left on
                # the host stalls (no step) until the next refill
                stalled = st["more"] & ~feeding
                stepping = st["act"] & ~stalled
                pages = ((st["ptab"], page_size)
                         if page_size is not None else None)
                logits, cache = T.decode_step(
                    cfg, params, cache, tok_in, st["cur"], stepping,
                    cache_shardings=cache_shardings, pages=pages,
                    mesh=mesh)
                ks = S.split_keys(st["keys"])
                sampled = S.sample_tokens(logits, st["temp"], st["top_k"],
                                          st["top_p"], ks[:, 1],
                                          spec=logits_spec)
                last_prompt = (feeding & ~st["more"]
                               & (st["bpos"] + 1 >= st["avail"]))
                emit = stepping & (~feeding | last_prompt)
                cur2 = st["cur"] + stepping.astype(st["cur"].dtype)
                left2 = st["left"] - emit.astype(st["left"].dtype)
                # ring-cap stop: cur2 == max_len means this step wrote the
                # last ring position — the NEXT write would wrap and evict
                # position 0.  (Not max_len - 1: that fired one step early
                # on the ingest path, costing cap-length chunked prompts
                # their final token vs unchunked admission.)
                done = (emit & ((sampled == st["eos"]) | (left2 <= 0))
                        | (stepping & (cur2 >= max_len)))
                st2 = {**st,
                       "tok": jnp.where(emit, sampled, st["tok"]),
                       "cur": cur2,
                       "act": st["act"] & ~done,
                       "keys": jnp.where(emit[:, None], ks[:, 0], st["keys"]),
                       "bpos": st["bpos"] + feeding.astype(st["bpos"].dtype),
                       "left": left2}
                n_act = stepping.astype(jnp.int32).sum()
                if continuous:
                    return ((cache, st2, seq),
                            (sampled, emit, sw_seq, sw_slot, n_act))
                return (cache, st2), (sampled, emit, n_act)

            if continuous:
                (cache, st, seq), (toks, emits, sw_seq, sw_slot, n_act) = \
                    jax.lax.scan(body, (cache, st, stage["seq"]), None,
                                 length=steps)
                return (cache, st, seq, sw_seq, sw_slot, toks, emits,
                        n_act)
            (cache, st), (toks, emits, n_act) = jax.lax.scan(
                body, (cache, st), None, length=steps)
            return cache, st, toks, emits, n_act

        return window

    # -- speculative decode window -------------------------------------------

    @staticmethod
    def _make_spec_window(cfg: ModelConfig, max_len: int, steps: int,
                          depth: int, *, draft: DraftSpec, draft_cfg=None,
                          cache_shardings=None, draft_cache_shardings=None,
                          logits_spec=None, page_size: int | None = None,
                          mesh=None, continuous: bool = False,
                          adaptive: bool = False):
        """Build the jitted speculative window: ``steps`` iterations, each
        verifying up to ``depth`` draft tokens in ONE target pass.

        Per iteration, per slot: propose ``depth`` tokens (n-gram lookup
        over the fed-token history, or greedy steps of the layer draft),
        run one S = depth + 1 token ``T.verify_step``, then walk the S
        positions in order: position j's target draw (the slot's policy
        with its j-th key split) is the token sequential decoding would
        emit there, so a proposal is accepted iff it matches; the first
        mismatch emits the draw itself (deterministic residual) and stops
        the round.  Only the accepted prefix is committed to the ring and
        keys advance exactly once per emitted token — the sequential body
        is the S = 1 special case, so streams are depth-invariant.
        Ingesting (chunked-prefill) slots keep their one-token-per-
        iteration behavior: their columns >= 1 are never candidates."""
        S_pos = depth + 1
        has_draft_model = draft.kind == "layers"

        def round_body(params, dparams, cache, dcache, st, seq=None,
                       stage=None):
            sw = ()
            if continuous:
                st, cache, seq, sw_seq, sw_slot = Engine._stage_install(
                    st, cache, seq, stage)
                sw = (sw_seq, sw_slot)
            feeding = st["bpos"] < st["avail"]
            buf_tok = jnp.take_along_axis(
                st["buf"],
                jnp.minimum(st["bpos"], st["buf"].shape[1] - 1)[:, None],
                axis=1)[:, 0]
            tok_in = jnp.where(feeding, buf_tok, st["tok"])
            stalled = st["more"] & ~feeding
            stepping = st["act"] & ~stalled
            speculating = stepping & ~feeding
            if adaptive:
                # degraded slots propose nothing — they ride the window
                # as plain decode (column 0 only).  Any spec_on schedule
                # leaves streams bitwise identical (deterministic
                # accept/residual), so this is purely a cost knob.
                speculating = speculating & st["spec_on"]
            cur = st["cur"]
            js = jnp.arange(S_pos, dtype=cur.dtype)
            cap_ok = (cur[:, None] + js[None, :]) < max_len      # (B, S)

            # --- proposals (B, depth)
            if has_draft_model:
                props = []
                d_tok, d_cur = tok_in, cur
                # S_pos draft steps: feeds [tok_in, d1..d_depth], so the
                # draft ring also covers the last (bonus) position on
                # full acceptance; rejected columns are struck from its
                # position index below.
                for j in range(S_pos):
                    act_j = (stepping if j == 0
                             else speculating & cap_ok[:, j])
                    dlogits, dcache = T.decode_step(
                        draft_cfg, dparams, dcache, d_tok, d_cur, act_j,
                        cache_shardings=draft_cache_shardings, mesh=mesh)
                    d_cur = d_cur + act_j.astype(d_cur.dtype)
                    if j < depth:
                        d_tok = jnp.argmax(dlogits, -1).astype(jnp.int32)
                        props.append(d_tok)
                props = jnp.stack(props, axis=1)
            else:
                props = D.ngram_propose(st["hist"], cur, tok_in, depth)

            # --- one multi-token target pass over [tok_in | proposals]
            fed = jnp.concatenate([tok_in[:, None], props], axis=1)
            cand = jnp.concatenate(
                [stepping[:, None], speculating[:, None] & cap_ok[:, 1:]],
                axis=1)                                          # (B, S)
            # the draft ring (layer draft) stays slot-major even in paged
            # mode — only the target cache resolves through the page table
            pages = ((st["ptab"], page_size)
                     if page_size is not None else None)
            logits, updates = T.verify_step(cfg, params, cache, fed, cur,
                                            cand, pages=pages, mesh=mesh)
            last_prompt = (feeding & ~st["more"]
                           & (st["bpos"] + 1 >= st["avail"]))

            # --- in-order accept / residual walk (j == emission index)
            keys_state = st["keys"]
            tok2 = st["tok"]
            done_any = jnp.zeros_like(st["act"])
            nemit = jnp.zeros_like(cur)
            cols = []
            emit_prev = s_prev = None
            for j in range(S_pos):
                if j == 0:
                    valid_j = stepping
                    emit_j = stepping & (~feeding | last_prompt)
                else:
                    valid_j = (emit_prev & ~done_any & cand[:, j]
                               & (fed[:, j] == s_prev))
                    emit_j = valid_j
                ks = S.split_keys(keys_state)
                s_j = S.sample_tokens(logits[:, j], st["temp"],
                                      st["top_k"], st["top_p"], ks[:, 1],
                                      spec=logits_spec)
                nemit = nemit + emit_j.astype(cur.dtype)
                left_j = st["left"] - nemit
                done_j = (emit_j & ((s_j == st["eos"]) | (left_j <= 0))
                          | (valid_j & (cur + j + 1 >= max_len)))
                done_any = done_any | done_j
                keys_state = jnp.where(emit_j[:, None], ks[:, 0],
                                       keys_state)
                tok2 = jnp.where(emit_j, s_j, tok2)
                cols.append((valid_j, emit_j, s_j))
                emit_prev, s_prev = emit_j, s_j
            valid = jnp.stack([c[0] for c in cols], axis=1)      # (B, S)
            emits_r = jnp.stack([c[1] for c in cols], axis=1)
            toks_r = jnp.stack([c[2] for c in cols], axis=1)

            # --- commit the accepted prefix (rejected tokens never wrote)
            cache = T.commit_verify_writes(cache, updates, cur, valid,
                                           cache_shardings=cache_shardings,
                                           pages=pages)
            if has_draft_model:
                # the draft wrote as it proposed; strike rejected columns
                # from its position index so they can't shadow the slot
                for j in range(1, S_pos):
                    dcache = KC.invalidate_positions(
                        dcache, cur + j, cand[:, j] & ~valid[:, j])
            hist = st["hist"]
            iota = jnp.arange(hist.shape[1], dtype=cur.dtype)[None, :]
            for j in range(S_pos):
                hit = (iota == (cur + j)[:, None]) & valid[:, j][:, None]
                hist = jnp.where(hit, fed[:, j][:, None], hist)

            st2 = {**st,
                   "tok": tok2,
                   "cur": cur + valid.astype(cur.dtype).sum(axis=1),
                   "act": st["act"] & ~done_any,
                   "keys": keys_state,
                   "bpos": st["bpos"] + feeding.astype(st["bpos"].dtype),
                   "left": st["left"] - nemit,
                   "hist": hist}
            accepted = valid[:, 1:].astype(jnp.int32).sum(axis=1)
            # count only REAL proposals: the n-gram draft pads unknown
            # positions with -1 (guaranteed rejects), which would deflate
            # accept_rate below what the draft actually achieves on the
            # positions it dared to predict
            proposed = ((cand[:, 1:] & (fed[:, 1:] >= 0))
                        .astype(jnp.int32).sum(axis=1))
            n_act = stepping.astype(jnp.int32).sum()
            return cache, dcache, st2, seq, (toks_r, emits_r, accepted,
                                             proposed, n_act) + sw

        if has_draft_model:
            def window(params, dparams, cache, dcache, st):
                def body(carry, _):
                    cache, dcache, st = carry
                    cache, dcache, st2, _, ys = round_body(
                        params, dparams, cache, dcache, st)
                    return (cache, dcache, st2), ys
                (cache, dcache, st), (toks, emits, acc, prop, n_act) = \
                    jax.lax.scan(body, (cache, dcache, st), None,
                                 length=steps)
                return cache, dcache, st, toks, emits, acc, prop, n_act
        elif continuous:
            def window(params, cache, st, stage):
                def body(carry, _):
                    cache, st, seq = carry
                    cache, _, st2, seq, ys = round_body(
                        params, None, cache, None, st, seq, stage)
                    return (cache, st2, seq), ys
                ((cache, st, seq),
                 (toks, emits, acc, prop, n_act, sw_seq, sw_slot)) = \
                    jax.lax.scan(body, (cache, st, stage["seq"]), None,
                                 length=steps)
                return (cache, st, seq, sw_seq, sw_slot, toks, emits,
                        acc, prop, n_act)
        else:
            def window(params, cache, st):
                def body(carry, _):
                    cache, st = carry
                    cache, _, st2, _, ys = round_body(params, None, cache,
                                                      None, st)
                    return (cache, st2), ys
                (cache, st), (toks, emits, acc, prop, n_act) = jax.lax.scan(
                    body, (cache, st), None, length=steps)
                return cache, st, toks, emits, acc, prop, n_act

        return window

    @classmethod
    def from_artifact(cls, path: str, *, max_slots: int, max_len: int,
                      source: jax.Array | None = None,
                      backend: str | None = None,
                      sampling: SamplingParams | None = None,
                      sync_every: int = 8,
                      prefill_chunk: int | None = None,
                      mesh: jax.sharding.Mesh | None = None,
                      spec_depth: int = 0,
                      draft: str | DraftSpec | None = None,
                      cache_layout: str = "ring",
                      page_size: int | None = None,
                      n_pages: int | None = None,
                      overlap: bool = False,
                      aot: bool = False,
                      pipeline_depth: int = 2,
                      continuous: bool = False,
                      admission_thread: bool | None = None,
                      pin_prefixes: int = 0,
                      adaptive_spec: bool = False,
                      profile: bool = False,
                      policy: str | AdmissionPolicy | None = None,
                      lazy_pages: bool = False,
                      staging_depth: int | None = None) -> "Engine":
        """Boot an engine straight from a saved compression artifact —
        the compress-offline / serve-forever workflow across processes.
        ``overlap``/``aot``/``pipeline_depth``/``continuous`` select the
        pipelined engine exactly as on the constructor."""
        from repro.api import load_artifact  # local: api imports models too

        art = load_artifact(path)
        return cls(art.cfg, art.params, max_slots=max_slots, max_len=max_len,
                   source=source, backend=backend, sampling=sampling,
                   sync_every=sync_every, prefill_chunk=prefill_chunk,
                   mesh=mesh, spec_depth=spec_depth, draft=draft,
                   cache_layout=cache_layout, page_size=page_size,
                   n_pages=n_pages, overlap=overlap, aot=aot,
                   pipeline_depth=pipeline_depth, continuous=continuous,
                   admission_thread=admission_thread,
                   pin_prefixes=pin_prefixes, adaptive_spec=adaptive_spec,
                   profile=profile, policy=policy, lazy_pages=lazy_pages,
                   staging_depth=staging_depth)

    # -- back-compat conveniences -------------------------------------------

    @property
    def slot_req(self) -> list[Request | None]:
        return self.scheduler.slot_req

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def unfinished(self) -> dict[str, int]:
        """Requests not yet finished: queued vs admitted-but-mid-flight."""
        return {"queued": self.scheduler.queue_depth,
                "in_flight": self.scheduler.occupancy}

    @property
    def mesh_str(self) -> str:
        """Mesh shape joined over ALL axes in mesh order (e.g. "1x1",
        "2x4", "2x16x16" for a multi-pod mesh)."""
        return "x".join(str(self.mesh.shape[a]) for a in self.mesh.axis_names)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> Request:
        with self._sched_lock:
            self.scheduler.submit(req)
        if self._admission is not None:
            self._admission.kick(self._staging_capacity())
        return req

    def _staging_capacity(self) -> int:
        """How many MORE requests admission may pull off the queue right
        now: ``staging_depth`` bounds the prefilled-but-unmerged look-
        ahead (default 2x the slot count, decoupled from ``max_slots``).
        Pulling a deeper run per kick is what batches N staged prompts
        into ONE bucketed wave prefill instead of N separate calls; the
        stage-row / free-slot / page-budget bounds are enforced at the
        boundary merge, where prepared waves wait head-of-line."""
        with self._sched_lock:
            return max(0, self.staging_depth - len(self.scheduler.staged))

    def _take_staged_locked(self, max_n: int) -> list[Request]:
        with self._sched_lock:
            return self.scheduler.take_staged(max_n)

    def _count_prefill(self):
        """One admission wave-prefill dispatch (any thread)."""
        with self._mlock:
            self.prefill_calls += 1

    def _record_token(self, req: Request, tok: int):
        """Credit one emitted token to a request: append, stamp ttft on
        the first, fire the stream callback.  Runs on the main thread
        (sync engine) or the backlog worker (overlapped engine) — never
        both for the same engine, so out_tokens needs no lock; the ttft
        sums are shared with metrics() and do."""
        req.out_tokens.append(tok)
        if req.first_token_at is None:
            req.first_token_at = time.perf_counter()
            if req.submitted_at is not None:
                with self._mlock:
                    self._ttft_sum += req.first_token_at - req.submitted_at
                    self._ttft_n += 1
        if req.on_token is not None:
            req.on_token(req, tok)

    def _finish(self, slot: int):
        self.finished.append(self.scheduler.slot_req[slot])
        self.scheduler.release(slot)
        st = self._st
        st["act"][slot] = False
        st["avail"][slot] = 0
        st["bpos"][slot] = 0
        st["more"][slot] = False
        st["left"][slot] = 0
        if self._pages is not None:
            for pg in self._slot_pages[slot]:
                # refcount-0 pages keep their prefix key: the content is
                # resident until the LRU free list recycles the page, so
                # a recurring prompt can resurrect it (see _assign_pages)
                self._pages.free(pg)
            self._slot_pages[slot] = []
            st["ptab"][slot] = 0

    # -- paged admission helpers ---------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Worst-case page count for ``req``: its write reach is known at
        admission (prompt + generation budget, capped by the ring), so
        admission can reserve up front and the device loop never faults.
        Conservative — ignores prefix sharing, so a fitting wave always
        has real pages even if every registry lookup misses."""
        reach = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return -(-reach // self.page_size)

    @property
    def _headroom(self) -> int:
        """Tokens a slot can advance past its last boundary-visible
        ``cur`` before the next lazy top-up runs: every window that can
        be in flight when one is dispatched (the trailing harvest lags
        by up to ``pipeline_depth`` windows, plus the new one) times the
        worst per-iteration advance (one fed/sampled token plus up to
        ``spec_depth`` accepted draft tokens)."""
        per_window = self.sync_every * (self.spec_depth + 1)
        depth = (self.pipeline_depth + 1) if self.overlap else 1
        return per_window * depth

    def _admit_need(self, req: Request, first_len: int) -> int:
        """Pages admission must map up front.  Eager (default): the full
        worst-case reach, so the device loop can never fault.  Lazy:
        just the admitted coverage plus one top-up interval's headroom —
        ``_lazy_topup`` grows the mapping at window boundaries as
        ``cur`` approaches it, preempting a victim when the pool runs
        dry."""
        if not self.lazy_pages:
            return self._pages_needed(req)
        reach = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return -(-min(first_len + self._headroom, reach) // self.page_size)

    def _probe_prefix_len(self, req: Request) -> int:
        """READ-ONLY registry probe: how many leading prompt tokens are
        covered by resident pages right now, capped one page short of
        the whole prompt (at least one prompt token must flow through
        the decode loop so the first generated token has a step to be
        sampled in when the prefill is skipped).  Heuristic — worker-
        thread safe; the authoritative lookup+retain happens at the
        boundary merge (``_map_shared_pages``), which may see more or
        fewer resident pages and is correct either way."""
        ps = self.page_size
        hit = 0
        for j in range((len(req.prompt) - 1) // ps):
            if self._prefixes.lookup(prefix_key(req.prompt, j, ps)) is None:
                break
            hit += 1
        return hit * ps

    def _skip_prefill(self, req: Request) -> bool:
        """Prefill-skip gate (prefix-affinity): admit with NO prefill
        row when resident registry pages cover all but at most one
        page's worth of the prompt — the remainder streams through the
        ingest buffer, which is cheap only when it is short."""
        if self._pages is None or not self.policy.groups_by_prefix:
            return False
        hit = self._probe_prefix_len(req)
        return hit > 0 and len(req.prompt) - hit <= self.page_size

    def _map_shared_pages(self, req: Request):
        """Skip-path page mapping (no prefill row): the longest resident
        registry prefix is retained/resurrected; the remainder is
        freshly allocated and POS-WIPED on device, because no prefill
        scatter covers it and a recycled page's stale positions would
        otherwise read as valid cache (position masking is the paged
        reader's validity mechanism).  Content for the wiped pages
        arrives via the decode loop's ingest/generation writes, so they
        are never registered.  Returns (mapping, hit_len)."""
        ps = self.page_size
        shared: list[int] = []
        for j in range((len(req.prompt) - 1) // ps):
            pg = self._prefixes.lookup(prefix_key(req.prompt, j, ps))
            if pg is None:
                break
            shared.append(pg)
        for pg in shared:
            self._note_prefix_hit(pg)
            if self._pages.refcount(pg) == 0:
                self._pages.resurrect(pg)
            else:
                self._pages.retain(pg)
        hit_len = len(shared) * ps
        n_need = max(self._admit_need(req, hit_len), len(shared))
        own = self._pages.alloc(n_need - len(shared))
        for pg in own:
            self._prefixes.drop_page(pg)
            self._prefix_hits.pop(pg, None)
        if own:
            self.cache = T.wipe_pages(self.cache,
                                      jnp.asarray(own, jnp.int32))
        self._update_pins()
        return shared + own, hit_len

    def _assign_shared_pages(self, slot: int, req: Request) -> int:
        """_map_shared_pages plus the slot bindings (page list + ptab
        row); returns the shared coverage — the admitted ``cur``."""
        mapping, hit_len = self._map_shared_pages(req)
        self._slot_pages[slot] = list(mapping)
        row = self._st["ptab"][slot]
        row[:] = 0
        row[: len(mapping)] = mapping
        with self._mlock:
            self.prefill_calls_saved += 1
        return hit_len

    def _map_pages(self, req: Request, first_len: int):
        """Map ``req``'s logical pages to physical ones: longest
        registry-hit prefix is *retained* (refcount++, no copy), the rest
        freshly allocated.  Returns (mapping, scatter_cols): the full
        physical mapping for a ptab row, and which logical pages the
        wave prefill must scatter (the non-shared ones).  Main-thread
        only (mutates the pool/registry) — the staging path calls this
        at the boundary merge, never on the admission worker.

        Copy-on-write resolves at admission: only prefix pages FULLY
        covered by this wave's prefill are shareable, and the first
        logical page past the shared run is by definition divergent —
        its content comes from this request's own prefill scatter, so
        the "copy" is free.  Generation never touches shared pages
        (writes start at first_len >= shared run end)."""
        ps = self.page_size
        n_need = self._admit_need(req, first_len)
        shared: list[int] = []
        lim = min(n_need, first_len // ps)
        for j in range(lim):
            pg = self._prefixes.lookup(prefix_key(req.prompt, j, ps))
            if pg is None:
                break
            shared.append(pg)
        for pg in shared:
            self._note_prefix_hit(pg)
            if self._pages.refcount(pg) == 0:
                # every holder retired but the page was never recycled:
                # its latent content is still resident, so the recurring
                # prefix skips the prefill (registry keys outlive holders)
                self._pages.resurrect(pg)
            else:
                self._pages.retain(pg)
        if shared and n_need > len(shared):
            # first divergent page: a fork in COW terms, but the new
            # content arrives via this request's own prefill scatter —
            # no device copy needed, just a fresh page
            self._pages.cow_forks += 1
        own = self._pages.alloc(n_need - len(shared))
        for pg in own:
            # a recycled page's old prefix key (if any) is dead now —
            # the registry must never map a prefix to rewritten content
            self._prefixes.drop_page(pg)
            self._prefix_hits.pop(pg, None)
        mapping = shared + own
        for j in range(len(shared), n_need):
            # register pages whose content this wave's prefill fully
            # determines (complete, never-rewritten prompt prefixes)
            if (j + 1) * ps <= first_len:
                self._prefixes.register(prefix_key(req.prompt, j, ps),
                                        mapping[j])
        self._update_pins()
        return mapping, list(range(len(shared), n_need))

    def _assign_pages(self, slot: int, req: Request, first_len: int):
        """_map_pages plus the slot bindings (page list + ptab row)."""
        mapping, scat = self._map_pages(req, first_len)
        self._slot_pages[slot] = list(mapping)
        row = self._st["ptab"][slot]
        row[:] = 0
        row[: len(mapping)] = mapping
        return mapping, scat

    def _note_prefix_hit(self, page: int):
        if self.pin_prefixes:
            self._prefix_hits[page] = self._prefix_hits.get(page, 0) + 1

    def _update_pins(self):
        """Keep the ``pin_prefixes`` hottest still-registered prefix
        pages pinned (exempt from LRU recycling, parked at refcount 0).
        Hit counts die with their page's registry entry, so a recycled
        page can't haunt the ranking."""
        if not self.pin_prefixes:
            return
        registered = self._prefixes.pages()
        alive = {pg: h for pg, h in self._prefix_hits.items()
                 if pg in registered}
        want = set(sorted(alive, key=lambda p: (-alive[p], p))
                   [: self.pin_prefixes])
        for pg in range(1, self.n_pages):
            if pg in want:
                self._pages.pin(pg)
            elif self._pages.is_pinned(pg):
                self._pages.unpin(pg)

    def _page_fits(self):
        """Page-budget admission gate: reserve each request's admission
        need up front against a running budget (head-of-line under fifo;
        other policies document their own skipping).  Conservative —
        ignores prefix sharing, so a fitting wave always has real pages
        even if every registry lookup misses.  Under lazy reservation
        the need shrinks to coverage + headroom, but a request whose
        worst-case reach exceeds the whole pool never fits: the top-up
        path must be able to finish it once the pool is all hers."""
        budget = self._pages.free_count

        def fits(req: Request) -> bool:
            nonlocal budget
            if (self.lazy_pages
                    and self._pages_needed(req) > self.n_pages - 1):
                return False
            need = self._admit_need(req, self.scheduler.first_chunk_len(req))
            if need > budget:
                return False
            budget -= need
            return True

        return fits

    def _skip_rows(self, reqs) -> tuple[list[int], int]:
        """Prefill-row assignment for an admission run: request i rides
        prefill row rows[i], or -1 when its prefill is skipped (resident
        prefix pages).  Returns (rows, prefill-row count)."""
        rows, w = [], 0
        for r in reqs:
            if self._skip_prefill(r):
                rows.append(-1)
            else:
                rows.append(w)
                w += 1
        return rows, w

    def _bucket_prompts(self, reqs, first_lens, rows, w):
        """Pack the prefill members of an admission run into one
        power-of-two (rows, prompt-len) bucket so a stream of ragged
        admissions reuses O(log) jit traces.  The row cap is the staging
        look-ahead (staged runs batch past the slot count); the length
        cap is max_len (padding past the ring would silently drop a
        fittable prompt prefix)."""
        pf = [fl for fl, ri in zip(first_lens, rows) if ri >= 0]
        # row cap: staged runs may batch up to staging_depth prompts in
        # one wave — past the cap _bucket degenerates to the raw count,
        # which would mint a fresh shape (and an AOT retrace) per run
        W = _bucket(w, max(self.B, self.staging_depth))
        P = _bucket(max(pf), self.max_len)
        toks = np.zeros((W, P), np.int32)
        lens = np.zeros((W,), np.int32)
        for i, r in enumerate(reqs):
            if rows[i] < 0:
                continue
            toks[rows[i], : first_lens[i]] = r.prompt[: first_lens[i]]
            lens[rows[i]] = first_lens[i]
        return toks, lens

    def _admission_wave(self):
        """Host half of admission: take a wave off the queue (policy
        order) and build its shape-bucketed prefill inputs.  Shared by
        the sync and the overlapped paths — the scheduler bookkeeping
        must be identical for the parity contract to hold."""
        if self._pages is None:
            wave = self.scheduler.take_wave()
        else:
            wave = self.scheduler.take_wave(self._page_fits())
        if not wave:
            return None
        first_lens = [self.scheduler.first_chunk_len(r) for _, r in wave]
        rows, w = self._skip_rows([r for _, r in wave])
        if w == 0:
            return wave, first_lens, rows, None, None
        toks, lens = self._bucket_prompts([r for _, r in wave],
                                          first_lens, rows, w)
        return wave, first_lens, rows, toks, lens

    def _admit_prefill(self, wave, first_lens, rows, toks, lens):
        """Dispatch the wave prefill (when any row needs one) and chain
        the slot merges onto the current cache futures.  Skip members
        (rows[i] == -1) bind resident registry pages instead, mutating
        ``first_lens`` in place to their shared coverage.  Never blocks:
        the returned logits are a (W, V) device future, or None for an
        all-skip wave."""
        logits = new_cache = None
        if toks is not None:
            self._count_prefill()
            tj, lj = self._prefill_args(toks, lens)
            logits, new_cache = self._prefill(self.params, tj, lj)
        if self._pages is None:
            # ring layout never skips (the gate needs the page registry)
            slots = jnp.asarray([s for s, _ in wave])
            self.cache = _merge_slot(self.cache, new_cache, slots)
        else:
            rws, cols, phys = [], [], []
            for i, (slot, r) in enumerate(wave):
                if rows[i] < 0:
                    first_lens[i] = self._assign_shared_pages(slot, r)
                    continue
                mapping, scat = self._assign_pages(slot, r, first_lens[i])
                for j in scat:
                    rws.append(rows[i])
                    cols.append(j)
                    phys.append(mapping[j])
            if phys:
                # non-shared pages only: shared prefixes are already
                # resident and must not be rewritten (their tail slots in
                # new_cache hold pos=-1 filler, same as fresh pages get)
                self.cache = _merge_slot_paged(
                    self.cache, new_cache, jnp.asarray(rws),
                    jnp.asarray(cols), jnp.asarray(phys), self.page_size)
        if self.draft_cache is not None and toks is not None:
            # the layer draft consumes the same wave so its ring tracks
            # the target's (its logits here are irrelevant).  A skip
            # member's draft ring keeps stale content: its proposals are
            # garbage until overwritten, which costs acceptance rate but
            # never correctness (streams are invariant to proposals).
            _, dnew = self._draft_prefill(self.draft_params, tj, lj)
            dslots = [s for (s, _), ri in zip(wave, rows) if ri >= 0]
            drows = [ri for ri in rows if ri >= 0]
            self.draft_cache = _merge_slot(self.draft_cache, dnew,
                                           jnp.asarray(dslots),
                                           rows=jnp.asarray(drows))
        return logits

    def _admit_sample_first(self, reqs, first_lens, logits):
        """Sample every wave row's first token with the SAME policy + key
        split the decode window would use — a request's stream is then
        identical whether its first token comes from the wave prefill
        (whole prompt consumed) or from the loop's last ingest step
        (chunked).  At temperature=0 this is exact argmax, matching the
        seed engine.  Knobs are padded to the full (W,) bucket and the
        sampler is the shared jitted entry point, so the value is bitwise
        identical under sync and overlapped admission (sample_tokens is
        batch-invariant per row).  Returns device futures.  Thread-safe
        (pure numpy + jax dispatch), so the admission worker can run it
        off-thread."""
        W = logits.shape[0]
        specs = [r.sampling or self.sampling for r in reqs]
        keys0 = np.zeros((W, 2), np.uint32)
        temp = np.zeros(W, np.float32)
        top_k = np.zeros(W, np.int32)
        top_p = np.ones(W, np.float32)
        eos = np.full(W, -1, np.int32)
        full = np.zeros(W, bool)
        for i, (sp, r) in enumerate(zip(specs, reqs)):
            keys0[i] = sp.slot_key(r.uid)
            temp[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
            eos[i] = -1 if r.eos_id is None else r.eos_id
            full[i] = first_lens[i] == len(r.prompt)
        ks = S.split_keys(jnp.asarray(keys0))
        first = S.sample_tokens_jit(logits, jnp.asarray(temp),
                                    jnp.asarray(top_k), jnp.asarray(top_p),
                                    ks[:, 1])
        return specs, keys0, eos, full, ks, first

    def _admit_bookkeep(self, slot, r, sp, first_len, eos_id):
        """Mirror writes common to both admission paths (everything the
        host knows without touching the device)."""
        st = self._st
        st["cur"][slot] = first_len
        st["keys"][slot] = 0              # real keys land per-path
        st["temp"][slot] = sp.temperature
        st["top_k"][slot] = sp.top_k
        st["top_p"][slot] = sp.top_p
        st["eos"][slot] = eos_id
        st["bpos"][slot] = 0
        st["act"][slot] = True
        if "spec_on" in st:
            # adaptive degradation is per REQUEST: a fresh admission gets
            # the draft back, with clean accept/propose accumulators
            st["spec_on"][slot] = True
        self._spec_acc[slot] = 0
        self._spec_prop[slot] = 0
        if "hist" in st:
            # the WHOLE prompt is known at admission (even the not-
            # yet-ingested tail): seed the n-gram corpus up front
            st["hist"][slot] = 0
            st["hist"][slot, : len(r.prompt)] = r.prompt
        rest = r.prompt[first_len:]
        if rest.size == 0:
            st["tok"][slot] = 0           # real first token lands per-path
            st["left"][slot] = r.max_new_tokens - 1
            st["avail"][slot] = 0
            st["more"][slot] = False
        else:
            # chunked prefill: stream the remainder through the
            # decode loop's ingest buffer
            self.scheduler.set_pending(slot, rest)
            self._load_chunk(slot)
            st["tok"][slot] = 0
            st["left"][slot] = r.max_new_tokens

    def _admit(self):
        """Synchronous admission: wave prefill (skip members ride
        resident registry pages instead), first-token sample for the
        prefill rows, at most one host sync, mirror writes.  An all-skip
        wave admits with ZERO device syncs — its first tokens come from
        the decode loop's ingest steps."""
        taken = self._admission_wave()
        if taken is None:
            return
        wave, first_lens, rows, toks, lens = taken
        logits = self._admit_prefill(wave, first_lens, rows, toks, lens)
        full = ks = first = None
        if logits is not None:
            preqs = [r for (_, r), ri in zip(wave, rows) if ri >= 0]
            pflens = [fl for fl, ri in zip(first_lens, rows) if ri >= 0]
            _, _, _, full, ks, first_dev = self._admit_sample_first(
                preqs, pflens, logits)
            first = np.asarray(first_dev)
            ks = np.asarray(ks)
            self.host_syncs += 1
            self.admission_syncs += 1
        st = self._st
        for i, (slot, r) in enumerate(wave):
            sp = r.sampling or self.sampling
            eos_id = -1 if r.eos_id is None else r.eos_id
            self._admit_bookkeep(slot, r, sp, first_lens[i], eos_id)
            st["keys"][slot] = sp.slot_key(r.uid)
            ri = rows[i]
            if ri >= 0 and full[ri]:
                # whole prompt prefilled: emit the first generated token
                # right away (as the seed engine did) and advance the key
                st["keys"][slot] = ks[ri, 0]
                st["tok"][slot] = first[ri]
                self._admit_tokens += 1
                self._record_token(r, int(first[ri]))
                if r.done:
                    self._finish(slot)

    def _load_chunk(self, slot: int):
        chunk = self.scheduler.next_chunk(slot)
        st = self._st
        w = chunk.shape[0]
        st["buf"][slot, :w] = chunk
        st["avail"][slot] = w
        st["bpos"][slot] = 0
        st["more"][slot] = self.scheduler.pending_len(slot) > 0

    def _refill(self):
        st = self._st
        for slot, r in enumerate(self.scheduler.slot_req):
            if (r is not None and st["act"][slot]
                    and st["bpos"][slot] >= st["avail"][slot]
                    and self.scheduler.pending_len(slot) > 0):
                self._load_chunk(slot)

    # -- lazy page reservation + preemption -----------------------------------
    #
    # With lazy_pages=True admission maps only the admitted coverage plus
    # one top-up interval's headroom instead of the worst-case reach; at
    # every window boundary _lazy_topup extends each active slot's
    # mapping to stay ahead of ``cur``.  When the pool runs dry the
    # admission policy picks a running victim to PREEMPT: its carry row,
    # pages, and alloc stamps are snapshotted, its fully-written prompt
    # pages are registered (so sharers or its own resurrection can find
    # them), and its pages are freed.  Re-admission (_readmit_preempted,
    # boundary priority over fresh admissions) resurrects surviving
    # pages and rebuilds recycled ones by re-prefilling the fed history
    # over just the lost page columns — streams are token-for-token
    # identical to an un-preempted run because the carry row (keys, cur,
    # left, ingest buffer) is restored verbatim.

    def _lazy_topup(self):
        """Boundary half of lazy reservation: extend every active slot's
        page mapping to cover ``cur + headroom`` (capped at its reach),
        position-wiping the fresh pages on device — nothing prefills
        them, and a recycled page's stale positions would otherwise read
        as valid cache."""
        if not self.lazy_pages:
            return
        st = self._st
        ps = self.page_size
        H = self._headroom
        for slot, r in enumerate(list(self.scheduler.slot_req)):
            if r is None or not st["act"][slot]:
                continue
            reach = min(len(r.prompt) + r.max_new_tokens, self.max_len)
            tgt = -(-min(int(st["cur"][slot]) + H, reach) // ps)
            have = len(self._slot_pages[slot])
            if tgt <= have:
                continue
            own = self._alloc_with_preemption(slot, tgt - have)
            if own is None:
                continue              # the slot itself was parked
            for pg in own:
                self._prefixes.drop_page(pg)
                self._prefix_hits.pop(pg, None)
            self._slot_pages[slot].extend(own)
            row = st["ptab"][slot]
            row[have: have + len(own)] = own
            self.cache = T.wipe_pages(self.cache,
                                      jnp.asarray(own, jnp.int32))
            if self.overlap:
                self._ensure_dev_state()
                self._scatter_rows(np.array([slot], np.int32),
                                   {"ptab": row[None]}, {})
        self._update_pins()

    def _alloc_with_preemption(self, slot: int, need: int):
        """Allocate ``need`` pages for a running slot, preempting policy-
        chosen victims while the pool is short.  Returns the pages, or
        None when the slot itself had to be parked (no other victim
        could cover it — admission's solo-servability check guarantees
        it can be re-seated once the pool drains)."""
        tried = {slot}
        while not self._pages.can_alloc(need):
            cands = self._victim_candidates(tried)
            if not cands:
                self._preempt_slot(slot)
                return None
            victim = self.policy.pick_victim(cands)
            tried.add(victim)
            self._preempt_slot(victim)
        return self._pages.alloc(need)

    def _victim_candidates(self, exclude):
        """Active slots in admission order, oldest first — the universe
        ``policy.pick_victim`` chooses from (the default evicts the
        youngest, minimizing wasted work)."""
        pos = {}
        for i, uid in enumerate(self.scheduler.admitted_uids):
            pos[uid] = i
        cands = sorted(
            (pos.get(r.uid, -1), slot)
            for slot, r in enumerate(self.scheduler.slot_req)
            if (r is not None and slot not in exclude
                and self._st["act"][slot]))
        return [(slot, self.scheduler.slot_req[slot])
                for _, slot in cands]

    def _preempt_slot(self, slot: int) -> bool:
        """Evict a running slot: snapshot its carry row, register its
        fully-written prompt pages, free everything, park the request.
        Under overlap this is the pipeline's one deliberate full sync —
        reading the leading carry's row waits for every dispatched
        window, so the snapshot includes all their effects (their token
        emissions still reach the stream in dispatch order through the
        backlog).  Returns False when the slot turns out to have
        finished on device already (its retirement settles at harvest;
        its pages free there)."""
        if self.overlap:
            self._ensure_dev_state()
            row = {k: np.asarray(v[slot])
                   for k, v in self._st_dev.items()}
        else:
            row = {k: np.array(v[slot]) for k, v in self._st.items()}
        if not bool(row["act"]):
            return False
        if self.overlap:
            # deactivate on the leading carry so windows dispatched from
            # here on ignore the slot; epoch-gate in-flight statuses
            self._st_dev = T.preempt_slot(self._st_dev, slot)
            self._slot_epoch[slot] = self._dispatch_index
            self._buf_epoch[slot] = self._dispatch_index
        st = self._st
        st["act"][slot] = False
        st["avail"][slot] = 0
        st["bpos"][slot] = 0
        st["more"][slot] = False
        st["left"][slot] = 0
        with self._sched_lock:
            req, pending = self.scheduler.preempt(slot)
        ps = self.page_size
        cur = int(row["cur"])
        pages = list(self._slot_pages[slot])
        stamps = [self._pages.alloc_stamp(pg) for pg in pages]
        for j, pg in enumerate(pages):
            # fully-written prompt pages stay discoverable: a prefix
            # sharer — or this request's own resurrection — can pull
            # them back while they survive in the free list
            if (j + 1) * ps <= min(cur, len(req.prompt)):
                self._prefixes.register(prefix_key(req.prompt, j, ps), pg)
        for pg in pages:
            self._pages.free(pg)
        self._slot_pages[slot] = []
        st["ptab"][slot] = 0
        self._preempted.append(PreemptedRecord(
            req=req, host_row=row, pending=pending, pages=pages,
            stamps=stamps, cur=cur, keys0=np.array(row["keys"])))
        with self._mlock:
            self.preemptions += 1
        return True

    def _readmit_preempted(self):
        """Re-seat parked requests, oldest first, when a slot and pages
        are available (boundary priority over fresh admissions).  Pages
        whose alloc stamp is unchanged still hold the victim's content:
        resurrect at refcount 0, retain when a prefix sharer took them.
        Recycled pages are rebuilt — content pages by re-prefilling the
        fed history over just those columns, ahead-of-cur pages by a
        position wipe.  A zero-rebuild resurrection costs no prefill."""
        pool = self._pages
        while self._preempted:
            rec = self._preempted[0]
            with self._sched_lock:
                free = self.scheduler._wave_slot_order(1)
            if not free:
                return
            surv = [pool.alloc_stamp(pg) == stp
                    for pg, stp in zip(rec.pages, rec.stamps)]
            lost = [j for j, s in enumerate(surv) if not s]
            # surviving refcount-0 unpinned pages leave the free list on
            # resurrect, so the lost replacements must fit AFTER them
            surv_free = sum(
                1 for pg, s in zip(rec.pages, surv)
                if s and pool.refcount(pg) == 0 and not pool.is_pinned(pg))
            if pool.free_count - surv_free < len(lost):
                return
            slot = free[0]
            with self._sched_lock:
                self.scheduler.place(slot, rec.req)
            # claim every survivor FIRST (pulling it off the free list)
            # — allocating a lost page's replacement earlier could
            # recycle a survivor out from under its stale surv flag
            mapping = [None] * len(rec.pages)
            for j, (pg, ok_) in enumerate(zip(rec.pages, surv)):
                if ok_:
                    if pool.refcount(pg) == 0:
                        pool.resurrect(pg)
                    else:
                        pool.retain(pg)
                    mapping[j] = pg
            wipe, rebuild = [], []
            for j in lost:
                npg = pool.alloc(1)[0]
                self._prefixes.drop_page(npg)
                self._prefix_hits.pop(npg, None)
                mapping[j] = npg
                if j * self.page_size < rec.cur:
                    rebuild.append(j)
                else:
                    wipe.append(npg)
            if wipe:
                self.cache = T.wipe_pages(self.cache,
                                          jnp.asarray(wipe, jnp.int32))
            if rebuild:
                self._rebuild_pages(rec, mapping, rebuild)
            else:
                with self._mlock:
                    self.prefill_calls_saved += 1
            self._slot_pages[slot] = list(mapping)
            st = self._st
            for k, v in rec.host_row.items():
                st[k][slot] = v
            row = st["ptab"][slot]
            row[:] = 0
            row[: len(mapping)] = mapping
            with self._sched_lock:
                self.scheduler.set_pending(
                    slot, np.asarray(rec.pending, np.int32))
            if self.overlap:
                self._ensure_dev_state()
                rows_all = {k: np.asarray(st[k][slot])[None] for k in st}
                self._scatter_rows(np.array([slot], np.int32),
                                   rows_all, {})
                self._slot_epoch[slot] = self._dispatch_index
                self._buf_epoch[slot] = self._dispatch_index
            self._update_pins()
            self._preempted.popleft()

    def _rebuild_pages(self, rec: PreemptedRecord, mapping, rebuild):
        """Recompute recycled pages' cache content: prefill the tokens
        the victim had FED (cache content at position t is a pure
        function of the token fed at t) and scatter just the lost page
        columns.  Uses the spec hist leaf when present — it IS the fed
        history — else the prompt plus the settled out_tokens (the
        backlog is flushed first so the generated history is whole)."""
        cur = rec.cur
        if "hist" in rec.host_row:
            fed = np.asarray(rec.host_row["hist"][:cur], np.int32)
        else:
            if self._backlog is not None and self._backlog.started:
                self._backlog.flush()
            prompt = np.asarray(rec.req.prompt, np.int32)
            P = len(prompt)
            gen = (np.asarray(rec.req.out_tokens[: cur - P], np.int32)
                   if cur > P else np.zeros((0,), np.int32))
            fed = np.concatenate([prompt[: min(cur, P)], gen])
        toks = np.zeros((1, _bucket(cur, self.max_len)), np.int32)
        toks[0, :cur] = fed
        lens = np.array([cur], np.int32)
        self._count_prefill()
        tj, lj = self._prefill_args(toks, lens)
        _, new_cache = self._prefill(self.params, tj, lj)
        self.cache = _merge_slot_paged(
            self.cache, new_cache, jnp.asarray([0] * len(rebuild)),
            jnp.asarray(rebuild),
            jnp.asarray([mapping[j] for j in rebuild]), self.page_size)

    # -- overlapped pipeline --------------------------------------------------
    #
    # The double-buffered loop keeps the carry ON DEVICE (self._st_dev)
    # and up to two windows in flight.  At each boundary the host:
    #   1. blocks on the TRAILING window's packed (act, bpos) status —
    #      the pipeline's single device sync — retires finished slots,
    #      and hands its token futures to the backlog worker;
    #   2. applies its admission/refill decisions to the LEADING window's
    #      *output* futures via eager scatters (functional updates chain
    #      by dataflow, so no device round-trip is needed);
    #   3. dispatches the next window on the merged carry.
    # The numpy mirror self._st stays authoritative for host-owned leaves
    # and is refreshed for act/bpos at harvests, gated by per-slot epochs
    # (a harvested status is stale for slots touched at later boundaries).

    def _ensure_dev_state(self):
        if self._st_dev is None:
            self._st_dev = {k: jax.device_put(v, self._carry_sh[k])
                            for k, v in self._st.items()}

    def _scatter_rows(self, slots_pad: np.ndarray, host_rows: dict,
                      dev_rows: dict, guard_gen=None):
        """Scatter per-slot rows into the device carry.  ``slots_pad`` is
        bucket-padded with out-of-range index B; mode="drop" discards the
        pad rows, so bucketing never writes a real slot.

        ``guard_gen`` (continuous batching): the host's per-slot
        generation counters at decision time.  An in-scan install may
        have repopulated a slot since — the device compares its ``gen``
        leaf against the guard and redirects mismatched rows to the drop
        index, so a stale host decision can never clobber a freshly
        installed request."""
        sl = jnp.asarray(slots_pad)
        st = dict(self._st_dev)
        if guard_gen is not None:
            ok = st["gen"][sl] == jnp.asarray(guard_gen)
            sl = jnp.where(ok, sl, self.B)
        for k, rows in {**host_rows, **dev_rows}.items():
            st[k] = st[k].at[sl].set(
                jnp.asarray(rows).astype(st[k].dtype), mode="drop")
        self._st_dev = st

    def _prepare_wave(self, reqs) -> StagedWave:
        """Stage a wave OFF the admission path: bucket the prompts,
        dispatch the prefill into a FRESH per-wave cache, and sample each
        row's first token.  Pure device dispatch against immutable engine
        state — no scheduler, pool, or mirror mutation — so the admission
        worker thread can run it concurrently with boundary work.  All
        merging happens later, on the main thread, at a boundary."""
        first_lens = [self.scheduler.first_chunk_len(r) for r in reqs]
        # skip decision from a read-only registry probe (worker-thread
        # safe); the boundary merge re-resolves pages authoritatively,
        # and either direction of drift is correct (the remainder just
        # streams through ingest from wherever coverage actually ends)
        rows, w = self._skip_rows(reqs)
        specs = [r.sampling or self.sampling for r in reqs]
        keys0 = np.zeros((len(reqs), 2), np.uint32)
        eos = np.full(len(reqs), -1, np.int32)
        full = np.zeros(len(reqs), bool)
        for i, (sp, r) in enumerate(zip(specs, reqs)):
            keys0[i] = sp.slot_key(r.uid)
            if r.eos_id is not None:
                eos[i] = r.eos_id
            full[i] = rows[i] >= 0 and first_lens[i] == len(r.prompt)
        ks = first = new_cache = draft_new = None
        if w:
            toks, lens = self._bucket_prompts(reqs, first_lens, rows, w)
            self._count_prefill()
            tj, lj = self._prefill_args(toks, lens)
            logits, new_cache = self._prefill(self.params, tj, lj)
            if self.draft_cache is not None:
                _, draft_new = self._draft_prefill(self.draft_params,
                                                   tj, lj)
            preqs = [r for r, ri in zip(reqs, rows) if ri >= 0]
            pflens = [fl for fl, ri in zip(first_lens, rows) if ri >= 0]
            _, _, _, _, ks, first = self._admit_sample_first(
                preqs, pflens, logits)
        return StagedWave(reqs=list(reqs), first_lens=first_lens,
                          specs=specs, keys0=keys0, eos=eos, full=full,
                          ks=ks, first=first, new_cache=new_cache,
                          draft_new_cache=draft_new, rows=rows)

    def _admit_overlap(self):
        """Boundary admission for the overlapped engine: collect prepared
        waves (from the worker, or prepared inline), then merge them —
        straight into free slots, or into the device staging queue under
        continuous batching.  ``host_syncs``/``admission_syncs`` tick once
        per wave at its FIRST merge, however many boundaries the merge
        spans, preserving the host_syncs == windows + admission_syncs
        identity."""
        if self._admission is not None:
            self._staged_waves.extend(self._admission.poll())
        else:
            cap = self._staging_capacity()
            if cap > 0:
                reqs = self._take_staged_locked(cap)
                if reqs:
                    self._staged_waves.append(self._prepare_wave(reqs))
        if self.continuous:
            self._stage_from_waves()
        else:
            self._place_from_waves()
        if self._admission is not None:
            self._admission.kick(self._staging_capacity())

    def _place_from_waves(self):
        """Merge prepared waves into free slots (non-continuous overlap).
        Head-of-line FIFO like every admission path: a wave that doesn't
        fully fit (slots or pages) blocks the ones behind it and resumes
        at the next boundary."""
        while self._staged_waves:
            wv = self._staged_waves[0]
            todo = wv.reqs[wv.merged:]
            if not todo:
                self._staged_waves.popleft()
                continue
            with self._sched_lock:
                free = len(self.scheduler.free_slots())
            n = min(len(todo), free)
            if self._pages is not None:
                fits = self._page_fits()
                fit = 0
                for r in todo[:n]:
                    if not fits(r):
                        break
                    fit += 1
                n = fit
            if n == 0:
                return
            if wv.merged == 0:
                self.host_syncs += 1
                self.admission_syncs += 1
            with self._sched_lock:
                placed = self.scheduler.place_wave(todo[:n])
            idx = list(range(wv.merged, wv.merged + n))
            self._merge_wave_rows(wv, placed, idx)
            wv.merged += n
            if wv.merged < len(wv.reqs):
                return
            self._staged_waves.popleft()

    def _merge_wave_rows(self, wv: StagedWave, placed, idx):
        """Merge wave rows ``idx`` into their placed slots: cache
        scatter, mirror bookkeeping, carry-row scatter, and the deferred
        first-token emission — the device half of what _admit does
        synchronously, expressed as dataflow on the leading carry."""
        st = self._st
        prow = ((lambda i: i) if wv.rows is None
                else (lambda i: wv.rows[i]))
        if self._pages is None:
            # ring layout never skips: rows is the identity mapping
            slots = jnp.asarray([s for s, _ in placed])
            self.cache = _merge_slot(self.cache, wv.new_cache, slots,
                                     rows=jnp.asarray(idx))
        else:
            rws, cols, phys = [], [], []
            for i, (slot, r) in zip(idx, placed):
                if prow(i) < 0:
                    # authoritative skip-path binding; the probe's guess
                    # is replaced by the coverage actually resident now
                    wv.first_lens[i] = self._assign_shared_pages(slot, r)
                    continue
                mapping, scat = self._assign_pages(slot, r,
                                                   wv.first_lens[i])
                for j in scat:
                    rws.append(prow(i))
                    cols.append(j)
                    phys.append(mapping[j])
            if phys:
                self.cache = _merge_slot_paged(
                    self.cache, wv.new_cache, jnp.asarray(rws),
                    jnp.asarray(cols), jnp.asarray(phys), self.page_size)
        if wv.draft_new_cache is not None:
            dslots = [s for i, (s, _) in zip(idx, placed) if prow(i) >= 0]
            drows = [prow(i) for i in idx if prow(i) >= 0]
            if dslots:
                self.draft_cache = _merge_slot(
                    self.draft_cache, wv.draft_new_cache,
                    jnp.asarray(dslots), rows=jnp.asarray(drows))
        for i, (slot, r) in zip(idx, placed):
            self._admit_bookkeep(slot, r, wv.specs[i], wv.first_lens[i],
                                 wv.eos[i])
            st["keys"][slot] = wv.keys0[i]   # placeholder: device = truth
            if wv.full[i]:
                self._admit_tokens += 1
            self._slot_epoch[slot] = self._dispatch_index
            self._buf_epoch[slot] = self._dispatch_index
        # host-known carry rows from the mirror the bookkeeping just
        # wrote; tok/keys/act depend on the sampled first token and stay
        # on device.  Pad to a slot-count bucket (mode="drop" pads).
        n = len(placed)
        Wb = _bucket(n, self.B)
        slots_pad = np.full(Wb, self.B, np.int32)
        slots_pad[:n] = [s for s, _ in placed]
        host_rows = {}
        for k, arr in st.items():
            if k in ("tok", "keys", "act"):
                continue
            rows = np.zeros((Wb,) + arr.shape[1:], arr.dtype)
            for i, (slot, _) in enumerate(placed):
                rows[i] = arr[slot]
            host_rows[k] = rows
        pad_ix = np.zeros(Wb, np.int64)
        pad_ix[:n] = idx
        sel = jnp.asarray(pad_ix)
        if wv.first is None:
            # all-skip wave: no sampled first tokens; every row starts
            # active with its base key, feeding from the ingest buffer
            dev_rows = {
                "tok": jnp.zeros(Wb, jnp.int32),
                "act": jnp.ones(Wb, bool),
                "keys": jnp.asarray(wv.keys0)[sel],
            }
        else:
            prow_ix = np.zeros(Wb, np.int64)
            prow_ix[:n] = [max(prow(i), 0) for i in idx]
            rsel = jnp.asarray(prow_ix)      # per-prefill-row gathers
            full_d = jnp.asarray(wv.full)[sel]
            eos_d = jnp.asarray(wv.eos)[sel]
            first_sel = wv.first[rsel]
            left_d = jnp.asarray(np.array(
                [wv.reqs[i].max_new_tokens - 1 for i in idx]
                + [0] * (Wb - n), np.int32))
            dev_rows = {
                "tok": jnp.where(full_d, first_sel, 0),
                # a full-prompt row can die at its very first token (eos,
                # or an exhausted budget) — the checks the window applies
                "act": jnp.where(full_d, (first_sel != eos_d)
                                 & (left_d > 0), True),
                "keys": jnp.where(full_d[:, None], wv.ks[rsel][:, 0],
                                  jnp.asarray(wv.keys0)[sel]),
            }
        self._scatter_rows(slots_pad, host_rows, dev_rows)
        entries = [(r, prow(i)) for i, (_, r) in zip(idx, placed)
                   if wv.full[i]]
        if entries:
            self._backlog.put(self._timed(
                self._admit_item(wv.first, entries), "backlog_drain"))

    def _stage_bookkeep(self, r: Request, sp, first_len: int, eos_id):
        """Host-known carry ROW for a staged request — everything
        _admit_bookkeep writes to the mirror, built standalone so the
        install can land it on whichever slot the device picks.  Returns
        (row dict over every carry leaf, pending prompt tail)."""
        st = self._st
        row = {k: np.zeros(v.shape[1:], v.dtype) for k, v in st.items()}
        row["cur"][...] = first_len
        row["temp"][...] = sp.temperature
        row["top_k"][...] = sp.top_k
        row["top_p"][...] = sp.top_p
        row["eos"][...] = eos_id
        row["act"][...] = True
        if "hist" in row:
            row["hist"][: len(r.prompt)] = r.prompt
        if "spec_on" in row:
            row["spec_on"][...] = True
        rest = r.prompt[first_len:]
        if rest.size == 0:
            row["left"][...] = r.max_new_tokens - 1
            pending = np.zeros((0,), np.int32)
        else:
            # the ingest buffer row is W = prefill_chunk-or-1 wide; a
            # pending tail with no configured chunk (prefill-skip) must
            # stream one token per iteration like the sync path does
            width = self.scheduler.prefill_chunk or 1
            chunk, pending = rest[:width], rest[width:]
            row["buf"][: chunk.shape[0]] = chunk
            row["avail"][...] = chunk.shape[0]
            row["more"][...] = pending.size > 0
            row["left"][...] = r.max_new_tokens
        return row, pending

    def _stage_from_waves(self):
        """Continuous batching: move prepared wave rows into the device
        staging queue (carry rows + FIFO seq keys + cache content),
        bounded by free stage rows and — paged — the page budget.
        Head-of-line FIFO, like every admission path.  Requests stay in
        ``scheduler.staged`` until their install is confirmed at a
        harvest; the scan itself picks the slot."""
        free_rows = [q for q, e in enumerate(self._stage_tab) if e is None]
        while self._staged_waves and free_rows:
            wv = self._staged_waves[0]
            if wv.merged >= len(wv.reqs):
                self._staged_waves.popleft()
                continue
            i = wv.merged
            r = wv.reqs[i]
            if (self._pages is not None
                    and self._pages_needed(r) > self._pages.free_count):
                return
            if wv.merged == 0:
                self.host_syncs += 1
                self.admission_syncs += 1
            self._stage_one(wv, i, free_rows.pop(0))
            wv.merged += 1
        while (self._staged_waves
               and self._staged_waves[0].merged
                   >= len(self._staged_waves[0].reqs)):
            self._staged_waves.popleft()

    def _stage_one(self, wv: StagedWave, i: int, q: int):
        """Scatter wave row ``i`` into stage row ``q``: the host-known
        carry row, the device first-token pieces, the monotone seq key
        the scan's installer FIFOs on, and the prefilled cache content
        (stage cache row for ring, pool pages for paged)."""
        r = wv.reqs[i]
        ri = i if wv.rows is None else wv.rows[i]
        pages = mapping = None
        if self._pages is not None:
            if ri < 0:
                # prefill-skip: bind resident registry pages now (the
                # authoritative walk) and stage from their coverage
                mapping, hit_len = self._map_shared_pages(r)
                wv.first_lens[i] = hit_len
                with self._mlock:
                    self.prefill_calls_saved += 1
            else:
                mapping, scat = self._map_pages(r, wv.first_lens[i])
                rws = [ri] * len(scat)
                cols = list(scat)
                phys = [mapping[j] for j in scat]
                if phys:
                    # freshly-allocated (refcount-1) pages only, chained
                    # on the LATEST cache future: no in-flight window
                    # reads them, and the window that can see this seq
                    # key sees the pages
                    self.cache = _merge_slot_paged(
                        self.cache, wv.new_cache, jnp.asarray(rws),
                        jnp.asarray(cols), jnp.asarray(phys),
                        self.page_size)
            pages = list(mapping)
        else:
            self._stage_dev = {
                **self._stage_dev,
                "cache": _merge_slot(self._stage_dev["cache"],
                                     wv.new_cache, jnp.asarray([q]),
                                     rows=jnp.asarray([ri])),
            }
        row, pending = self._stage_bookkeep(r, wv.specs[i],
                                            wv.first_lens[i], wv.eos[i])
        if mapping is not None:
            row["ptab"][: len(mapping)] = mapping
        seq_val = self._stage_seq_next
        self._stage_seq_next += 1
        ent = StagedEntry(req=r, host_row=row, pending=pending,
                          pages=pages, seq=seq_val, keys0=wv.keys0[i],
                          full=bool(wv.full[i]))
        if ri < 0:
            # skip member: no sampled first token; it starts feeding
            # from the ingest buffer with its base key
            dev_row = {
                "tok": jnp.zeros((), jnp.int32),
                "act": jnp.asarray(True),
                "keys": jnp.asarray(ent.keys0),
            }
        else:
            full_d = jnp.asarray(bool(wv.full[i]))
            eos_d = jnp.int32(int(wv.eos[i]))
            left0 = jnp.int32(r.max_new_tokens - 1)
            first_i = wv.first[ri]
            dev_row = {
                "tok": jnp.where(full_d, first_i, 0),
                "act": jnp.where(full_d, (first_i != eos_d) & (left0 > 0),
                                 True),
                "keys": jnp.where(full_d, wv.ks[ri, 0],
                                  jnp.asarray(ent.keys0)),
            }
        rows_dev = dict(self._stage_dev["rows"])
        for k, v in row.items():
            if k in ("tok", "act", "keys"):
                continue
            rows_dev[k] = rows_dev[k].at[q].set(
                jnp.asarray(v).astype(rows_dev[k].dtype))
        for k, v in dev_row.items():
            rows_dev[k] = rows_dev[k].at[q].set(v.astype(rows_dev[k].dtype))
        self._stage_dev = {
            **self._stage_dev, "rows": rows_dev,
            "seq": self._stage_dev["seq"].at[q].set(seq_val),
        }
        self._stage_tab[q] = ent
        self._stage_by_seq[seq_val] = (q, ent)
        if ent.full:
            # first token was emitted at STAGE time (parity with direct
            # admission); it must reach the stream before any window item
            # carrying this request's later tokens — backlog FIFO does it
            self._admit_tokens += 1
            self._backlog.put(self._timed(
                self._admit_item(wv.first, [(r, ri)]), "backlog_drain"))

    def _admit_item(self, first, entries):
        def item():
            arr = np.asarray(first)
            for r, i in entries:
                self._record_token(r, int(arr[i]))
        return item

    def _refill_async(self):
        """Refill drained ingest buffers and scatter them into the
        leading carry.  The mirror's (bpos, avail) pair is epoch-gated at
        harvest, so a chunk loaded at boundary d cannot be double-loaded
        off a pre-d status."""
        st = self._st
        slots = [slot for slot, r in enumerate(self.scheduler.slot_req)
                 if (r is not None and st["act"][slot]
                     and st["bpos"][slot] >= st["avail"][slot]
                     and self.scheduler.pending_len(slot) > 0)]
        if not slots:
            return
        for slot in slots:
            self._load_chunk(slot)
            self._buf_epoch[slot] = self._dispatch_index
        n = len(slots)
        R_ = _bucket(n, self.B)
        slots_pad = np.full(R_, self.B, np.int32)
        slots_pad[:n] = slots
        host_rows = {}
        for k in ("buf", "avail", "bpos", "more"):
            arr = st[k]
            rows = np.zeros((R_,) + arr.shape[1:], arr.dtype)
            for i, slot in enumerate(slots):
                rows[i] = arr[slot]
            host_rows[k] = rows
        gg = None
        if self.continuous:
            gg = np.zeros(R_, np.int32)
            gg[:n] = st["gen"][slots]
        self._scatter_rows(slots_pad, host_rows, {}, guard_gen=gg)

    def _dispatch_window(self) -> bool:
        """One pipeline boundary's front half: launch the next window on
        the merged leading carry.  Returns False when nothing is active
        to decode AND (under continuous batching) nothing is staged for
        an in-scan install."""
        staged_pending = (self.continuous
                          and any(e is not None for e in self._stage_tab))
        if not (self._st["act"].any() or staged_pending):
            return False
        occ, qd = self.scheduler.occupancy, self.scheduler.queue_depth
        prior = self._inflight[-1] if self._inflight else None
        overlapped = prior is not None and not _array_ready(prior.status)
        acc = prop = sw_seq = sw_slot = None
        if self.draft_cache is not None:
            (self.cache, self.draft_cache, st2, toks, emits, acc,
             prop, n_act) = self._window(self.params, self.draft_params,
                                         self.cache, self.draft_cache,
                                         self._st_dev)
        elif self.continuous and self.spec_depth > 0:
            (self.cache, st2, seq, sw_seq, sw_slot, toks, emits, acc,
             prop, n_act) = self._window(self.params, self.cache,
                                         self._st_dev, self._stage_dev)
            self._stage_dev = {**self._stage_dev, "seq": seq}
        elif self.continuous:
            (self.cache, st2, seq, sw_seq, sw_slot, toks, emits,
             n_act) = self._window(self.params, self.cache,
                                   self._st_dev, self._stage_dev)
            self._stage_dev = {**self._stage_dev, "seq": seq}
        elif self.spec_depth > 0:
            self.cache, st2, toks, emits, acc, prop, n_act = self._window(
                self.params, self.cache, self._st_dev)
        else:
            self.cache, st2, toks, emits, n_act = self._window(
                self.params, self.cache, self._st_dev)
        self._st_dev = st2
        # pack the harvest-critical pieces into ONE 1-D array at dispatch
        # so the trailing-boundary block is a single small transfer; the
        # harvest parses it positionally by the same layout
        parts = [st2["act"].astype(jnp.int32), st2["bpos"].astype(jnp.int32),
                 st2["cur"].astype(jnp.int32)]
        if self.continuous:
            parts.append(st2["gen"])
        if self.adaptive_spec:
            parts.append(acc.sum(axis=0))
            parts.append(prop.sum(axis=0))
        if self.continuous:
            parts.append(sw_seq)
            parts.append(sw_slot)
        parts.append(n_act.sum().reshape(1))
        status = jnp.concatenate(parts)
        self._inflight.append(InflightWindow(
            index=self._dispatch_index, status=status, toks=toks,
            emits=emits, slot_reqs=list(self.scheduler.slot_req),
            occ=occ, qd=qd, overlapped=overlapped, acc=acc, prop=prop))
        self._dispatch_index += 1
        if overlapped:
            self._overlapped_windows += 1
        return True

    def _harvest_trailing(self):
        """Block on the trailing window's status (the pipeline's one
        device sync), process confirmed in-scan installs, refresh the
        epoch-eligible mirror slots, retire finished requests, and hand
        token work to the backlog."""
        w = self._inflight.popleft()
        t0 = time.perf_counter()
        status = np.asarray(w.status)
        t1 = time.perf_counter()
        self._prof_add("harvest", t0, t1 - t0)
        self.host_syncs += 1
        self.windows += 1
        self._occupancy_sum += w.occ
        self._queue_depth_sum += w.qd
        B = self.B
        act = status[:B].astype(bool)
        bpos = status[B: 2 * B]
        cur = status[2 * B: 3 * B]
        off = 3 * B
        accs = props = sw_seq = sw_slot = None
        if self.continuous:
            off += B                      # gen leaf: mirrored per install
        if self.adaptive_spec:
            accs = status[off: off + B]
            props = status[off + B: off + 2 * B]
            off += 2 * B
        if self.continuous:
            K = self.sync_every
            sw_seq = status[off: off + K]
            sw_slot = status[off + K: off + 2 * K]
            off += 2 * K
        self._act_iters += int(status[off])
        # snapshot the PRE-install slot->request map and the in-window
        # swap list BEFORE bookkeeping mutates them: the backlog item
        # credits each iteration's tokens to whoever held the slot then
        base = list(w.slot_reqs)
        installs, swaps = [], []
        if sw_seq is not None:
            for k in range(self.sync_every):
                sv = int(sw_seq[k])
                if sv < 0:
                    continue
                q, ent = self._stage_by_seq.pop(sv)
                installs.append((k, int(sw_slot[k]), q, ent))
                swaps.append((k, int(sw_slot[k]), ent.req))
        item = self._window_item(w, base, swaps)
        for k, s, q, ent in installs:
            self._install_entry(w, s, q, ent)
        ok = self._slot_epoch <= w.index
        if accs is not None:
            self._adaptive_update(ok, act, accs, props,
                                  {s for _, s, _, _ in installs})
        self._st["act"][ok] = act[ok]
        self._st["cur"][ok] = cur[ok]
        bok = ok & (self._buf_epoch <= w.index)
        self._st["bpos"][bok] = bpos[bok]
        self._backlog.put(self._timed(item, "backlog_drain"))
        for slot, r in enumerate(w.slot_reqs):
            if (r is not None and ok[slot] and not act[slot]
                    and self.scheduler.slot_req[slot] is r):
                self._finish(slot)
        self.slot_swaps += len(installs)
        self._prof_add("bookkeep", t1, time.perf_counter() - t1)

    def _install_entry(self, w: InflightWindow, s: int, q: int,
                       ent: StagedEntry):
        """Main-thread bookkeeping for a CONFIRMED in-scan install: the
        device already owns slot ``s``'s carry row (the scan wrote it at
        iteration time); scheduler, mirror, pages, and epochs catch up
        here, retroactively."""
        if self.scheduler.slot_req[s] is not None:
            # the previous occupant died inside this window before the
            # install; its final tokens ride this window's backlog item
            self._finish(s)
        with self._sched_lock:
            self.scheduler.place(s, ent.req)
        st = self._st
        for k, v in ent.host_row.items():
            if k == "gen":
                continue
            st[k][s] = v
        st["gen"][s] += 1                 # mirror the scan's install bump
        self.scheduler.set_pending(s, np.asarray(ent.pending, np.int32))
        if self._pages is not None:
            self._slot_pages[s] = list(ent.pages)
        self._slot_epoch[s] = w.index
        self._buf_epoch[s] = w.index
        self._spec_acc[s] = 0
        self._spec_prop[s] = 0
        # windows dispatched before this install was known snapshot the
        # OLD occupant; patch them so their items credit the new one
        # from their own iteration 0 (the install predates them all)
        w.slot_reqs[s] = ent.req
        for wf in self._inflight:
            wf.slot_reqs[s] = ent.req
        self._stage_tab[q] = None

    def _adaptive_update(self, ok, act, accs, props, installed):
        """Fold a window's per-slot accept/propose counts into the
        running accumulators and degrade cold-draft slots to plain
        decode.  Sticky per request: spec_on resets at the next
        admission, not mid-request."""
        st = self._st
        degrade = []
        for s in range(self.B):
            if s in installed or not ok[s]:
                continue
            self._spec_acc[s] += int(accs[s])
            self._spec_prop[s] += int(props[s])
            if (st["spec_on"][s] and act[s]
                    and self._spec_prop[s] >= self.ADAPTIVE_MIN_PROPOSED
                    and self._spec_acc[s]
                        < self.ADAPTIVE_ACCEPT_FLOOR * self._spec_prop[s]):
                st["spec_on"][s] = False
                degrade.append(s)
        if not degrade:
            return
        self.spec_degraded += len(degrade)
        if self._st_dev is None:
            return                        # sync engine: mirror uploads
        n = len(degrade)
        Rb = _bucket(n, self.B)
        slots_pad = np.full(Rb, self.B, np.int32)
        slots_pad[:n] = degrade
        gg = None
        if self.continuous:
            gg = np.zeros(Rb, np.int32)
            gg[:n] = st["gen"][degrade]
        self._scatter_rows(slots_pad, {"spec_on": np.zeros(Rb, bool)}, {},
                           guard_gen=gg)

    def _window_item(self, w: InflightWindow, base=None, swaps=None):
        slot_reqs = list(w.slot_reqs) if base is None else base
        swap_iter: dict[int, list] = {}
        for k, s, r in (swaps or ()):
            swap_iter.setdefault(k, []).append((s, r))

        def item():
            toks = np.asarray(w.toks)           # (K, B) or (K, B, S)
            emits = np.asarray(w.emits)
            if toks.ndim == 2:
                toks, emits = toks[:, :, None], emits[:, :, None]
            nemit = int(emits.sum())
            acc = 0 if w.acc is None else int(np.asarray(w.acc).sum())
            prop = 0 if w.prop is None else int(np.asarray(w.prop).sum())
            with self._mlock:
                self.tokens_emitted += nemit
                if nemit == 0:
                    # pipeline bubble: every host-believed-active slot
                    # died in the window in flight when this one launched
                    self.windows_idle += 1
                self.draft_accepted += acc
                self.draft_proposed += prop
            reqs = slot_reqs
            for k in range(toks.shape[0]):
                for s, r in swap_iter.get(k, ()):
                    reqs[s] = r
                for j in range(toks.shape[2]):
                    for i in np.nonzero(emits[k, :, j])[0]:
                        self._record_token(reqs[i], int(toks[k, i, j]))
        return item

    def _step_async(self):
        """One overlapped boundary: harvest the trailing window once the
        pipeline is full (``pipeline_depth`` windows in flight), merge
        staged admissions and refills into the leading carry, then
        dispatch the next window."""
        t0 = time.perf_counter()
        if len(self._inflight) >= self.pipeline_depth:
            self._harvest_trailing()
        self._ensure_dev_state()
        t1 = time.perf_counter()
        self._readmit_preempted()
        self._admit_overlap()
        self._refill_async()
        self._lazy_topup()
        t2 = time.perf_counter()
        self._prof_add("admission_stage", t1, t2 - t1)
        dispatched = self._dispatch_window()
        t3 = time.perf_counter()
        self._prof_add("dispatch", t2, t3 - t2)
        if not dispatched:
            if self._inflight:
                # nothing to decode by the host's (possibly stale) view:
                # drain a window — its harvest may retire slots and
                # unblock the queue for the next boundary
                self._harvest_trailing()
            elif (self._admission is not None
                  and (self._admission.busy
                       or self.scheduler.queue_depth > 0)):
                # nothing on device, but admission work is pending or
                # mid-prefill on the worker: block (bounded) for its
                # wave instead of spinning the idle guard down
                self._admission.wait(timeout=1.0)
        self._run_seconds += time.perf_counter() - t0

    def _prof_add(self, stage: str, t0: float, dur: float):
        with self._mlock:
            self._prof[stage] += dur
            if self.profile and len(self._prof_events) < 100_000:
                self._prof_events.append(
                    {"stage": stage, "t": t0 - self._prof_t0, "dur": dur})

    def _timed(self, fn, stage: str):
        """Wrap a backlog work item so its wall-clock accrues to the
        named profiler stage (on whichever thread runs it)."""
        def run():
            t0 = time.perf_counter()
            try:
                fn()
            finally:
                self._prof_add(stage, t0, time.perf_counter() - t0)
        return run

    def flush(self):
        """Drain the pipeline: harvest every in-flight window and block
        until the backlog worker has processed all queued token work.
        No-op on a sync engine."""
        t0 = time.perf_counter()
        while self._inflight:
            self._harvest_trailing()
        if self._backlog is not None and self._backlog.started:
            self._backlog.flush()
        self._run_seconds += time.perf_counter() - t0

    def close(self):
        """Flush and join the worker threads.  Idempotent; the engine
        remains usable for sync inspection (metrics, finished) after."""
        if self._admission is not None:
            self._admission.close()
        self.flush()
        if self._backlog is not None:
            self._backlog.close()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc):
        self.close()

    # -- one engine step (= one decode window) -------------------------------

    def step(self):
        """Admit + refill, then run one ``sync_every``-token fused decode
        window.  Sync mode harvests it immediately (the single host sync
        of the step); overlap mode harvests the *trailing* window and
        leaves this one in flight.

        Wall-clock accrues HERE (not in run()), so callers driving
        ``step()`` directly — benches, external event loops — still get a
        meaningful ``tokens_per_s`` out of :meth:`metrics`.  Idle no-op
        calls (nothing active, nothing admitted) accrue nothing: an
        event loop polling an empty engine must not dilute the rate."""
        if self.overlap:
            return self._step_async()
        t0 = time.perf_counter()
        self._readmit_preempted()
        self._admit()
        self._refill()
        self._lazy_topup()
        st = self._st
        if not st["act"].any():
            return
        # window-boundary snapshot: the load THIS window runs with —
        # folded into the means in _harvest, atomically with `windows`
        occ, qd = self.scheduler.occupancy, self.scheduler.queue_depth
        if self.aot:
            # AOT executables skip jit's implicit placement: the carry
            # must arrive committed to the lowered shardings
            state = {k: jax.device_put(v, self._carry_sh[k])
                     for k, v in st.items()}
        else:
            state = {k: jnp.asarray(v) for k, v in st.items()}
        acc = prop = None
        if self.draft_cache is not None:
            (self.cache, self.draft_cache, state, toks, emits, acc,
             prop, n_act) = self._window(self.params, self.draft_params,
                                         self.cache, self.draft_cache,
                                         state)
        elif self.spec_depth > 0:
            (self.cache, state, toks, emits, acc, prop,
             n_act) = self._window(self.params, self.cache, state)
        else:
            self.cache, state, toks, emits, n_act = self._window(
                self.params, self.cache, state)
        self._harvest(state, toks, emits, occ, qd, acc, prop, n_act)
        self._run_seconds += time.perf_counter() - t0

    def _harvest(self, state, toks, emits, occ: int, qd: int,
                 acc=None, prop=None, n_act=None):
        toks = np.asarray(toks)                 # (K, B) or (K, B, S)
        emits = np.asarray(emits)
        if toks.ndim == 2:                      # non-speculative window
            toks, emits = toks[:, :, None], emits[:, :, None]
        self._st = {k: np.array(v) for k, v in state.items()}
        # every window-scoped counter advances together, here and only
        # here — a mid-stream metrics() call never sees sums from one
        # window paired with counts from another
        self.host_syncs += 1
        self.windows += 1
        self.tokens_emitted += int(emits.sum())
        self._occupancy_sum += occ
        self._queue_depth_sum += qd
        if n_act is not None:
            self._act_iters += int(np.asarray(n_act).sum())
        if acc is not None:
            self.draft_accepted += int(np.asarray(acc).sum())
            self.draft_proposed += int(np.asarray(prop).sum())
            if self.adaptive_spec:
                self._adaptive_update(
                    np.ones(self.B, bool), self._st["act"],
                    np.asarray(acc).sum(axis=0).reshape(-1),
                    np.asarray(prop).sum(axis=0).reshape(-1), set())
        slot_req = self.scheduler.slot_req
        for k in range(toks.shape[0]):
            for j in range(toks.shape[2]):
                for i in np.nonzero(emits[k, :, j])[0]:
                    self._record_token(slot_req[i], int(toks[k, i, j]))
        for slot, r in enumerate(slot_req):
            if r is not None and not self._st["act"][slot]:
                self._finish(slot)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive until drained or ``max_steps`` COMPLETED windows, then
        flush the pipeline + backlog (so ``finished`` streams are whole
        and ``metrics()`` is settled even on timeout).  The bound counts
        harvested windows — under overlap, dispatched-but-unharvested
        windows don't tick it, so the timeout means what it says.  On
        timeout the engine warns and leaves the backlog inspectable via
        ``engine.unfinished`` (callers distinguish drain from timeout).
        A stuck load (a request that can never admit) exits via the idle
        guard instead of spinning to max_steps."""
        idle = 0
        while self.scheduler.has_work or self._inflight:
            if self.windows >= max_steps:
                break
            before = (self.windows, self.host_syncs, self._dispatch_index)
            self.step()
            made_progress = (self.windows, self.host_syncs,
                             self._dispatch_index) != before
            idle = 0 if made_progress else idle + 1
            if idle > self.B + 2:
                break
        self.flush()
        if self.scheduler.has_work:
            u = self.unfinished
            warnings.warn(
                f"Engine.run stopped after {self.windows} completed "
                f"windows (max_steps={max_steps}) with {u['queued']} "
                f"queued and {u['in_flight']} in-flight requests "
                f"unfinished (not a drain)", RuntimeWarning,
                stacklevel=2)
        return self.finished

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict[str, Any]:
        """Serving counters since construction (host_syncs counts one per
        decode-window harvest plus one per admission wave).

        Safe to call mid-stream: window-scoped sums and ``windows``
        advance atomically at each harvest, and the instantaneous
        ``occupancy``/``queue_depth`` read the scheduler — the host-side
        truth at every window boundary — never the device mirror's
        active flags (which are stale between harvests).  Under overlap,
        ``tokens_per_s`` is true pipeline wall-clock: ``_run_seconds``
        accrues across boundary work AND the final flush, while the
        token counts settle on the backlog worker (flush/close first for
        exact totals).  ``ttft_s`` averages submit -> first-token wall
        latency; ``window_overlap`` is the fraction of windows that were
        dispatched before the prior one had finished on device — the
        direct measure of how often the double buffer actually hid the
        host; ``windows_idle`` counts harvested windows that emitted
        nothing (pipeline bubbles after a drain)."""
        with self._mlock:
            tokens = self.tokens_emitted + self._admit_tokens
            windows_idle = self.windows_idle
            ttft = self._ttft_sum / self._ttft_n if self._ttft_n else 0.0
            draft_proposed = self.draft_proposed
            draft_accepted = self.draft_accepted
            preemptions = self.preemptions
            prefill_calls = self.prefill_calls
            prefill_calls_saved = self.prefill_calls_saved
        w = max(self.windows, 1)
        pool = self._pages
        with self._mlock:
            prof = dict(self._prof)
        if self._admission is not None:
            prof["admission_worker"] = self._admission.prepare_seconds
        ptotal = sum(prof.values())
        profile = {"seconds": prof,
                   "shares": {k: (v / ptotal if ptotal else 0.0)
                              for k, v in prof.items()}}
        return {
            "tokens": tokens,
            "windows": self.windows,
            "sync_every": self.sync_every,
            "cache_layout": self.cache_layout,
            "page_size": self.page_size or 0,
            "pages_total": 0 if pool is None else self.n_pages,
            # pages_free counts the allocatable free list only; pinned
            # pages at refcount 0 are PARKED (resident, not allocatable)
            # and reported separately so free + held + parked + null
            # partitions pages_total
            "pages_free": 0 if pool is None else pool.free_count,
            "pages_parked": 0 if pool is None else pool.parked,
            "pages_shared": 0 if pool is None else pool.share_events,
            "pages_peak": 0 if pool is None else pool.peak_used,
            "cow_forks": 0 if pool is None else pool.cow_forks,
            "mesh": self.mesh_str,
            "backend": self.cfg.attn_backend,
            "verify_backend": self._verify_backend,
            "decode_kernel_sharded": self._decode_kernel_sharded,
            "spec_depth": self.spec_depth,
            "draft": (None if self.draft is None else
                      (self.draft.kind if self.draft.kind == "ngram"
                       else f"layers:{self.draft.layers}")),
            "draft_proposed": draft_proposed,
            "draft_accepted": draft_accepted,
            "accept_rate": (draft_accepted / draft_proposed
                            if draft_proposed else 0.0),
            "host_syncs": self.host_syncs,
            "admission_syncs": self.admission_syncs,
            "host_syncs_per_token": self.host_syncs / max(tokens, 1),
            "decode_syncs_per_token":
                self.windows / max(tokens - self._admit_tokens, 1),
            "occupancy": self.scheduler.occupancy,
            "queue_depth": self.scheduler.queue_depth,
            "occupancy_mean": self._occupancy_sum / w,
            "queue_depth_mean": self._queue_depth_sum / w,
            "overlap": self.overlap,
            "aot": self.aot,
            "pipeline_depth": self.pipeline_depth if self.overlap else 0,
            "continuous": self.continuous,
            "admission_thread": self.admission_thread,
            "window_overlap": (self._overlapped_windows
                               / max(self._dispatch_index, 1)
                               if self.overlap else 0.0),
            "windows_idle": windows_idle,
            "slot_swaps": self.slot_swaps,
            "occupancy_device_mean":
                self._act_iters / (w * self.sync_every),
            "adaptive_spec": self.adaptive_spec,
            "spec_degraded": self.spec_degraded,
            "pin_prefixes": self.pin_prefixes,
            "pages_pinned": 0 if pool is None else pool.pinned,
            "policy": self.policy.name,
            "lazy_pages": self.lazy_pages,
            "staging_depth": self.staging_depth,
            "preemptions": preemptions,
            "prefill_calls": prefill_calls,
            "prefill_calls_saved": prefill_calls_saved,
            "profile": profile,
            "ttft_s": ttft,
            "prefix_resurrections": (0 if pool is None
                                     else pool.prefix_resurrections),
            "run_seconds": self._run_seconds,
            "tokens_per_s": tokens / self._run_seconds
                            if self._run_seconds else 0.0,
        }
