"""Executor: continuous batching over the latent KV cache with a fused,
device-resident multi-token decode loop.

The serving subsystem is split three ways:

  scheduler.py  admission policy, slot lifecycle, chunked prefill (host)
  sampler.py    on-device temperature / top-k / top-p / greedy sampling
  engine.py     this file — the executor.  One ``jax.lax.scan`` window
                runs ``sync_every`` decode steps entirely on device
                (feed -> decode_step -> sample -> append -> termination),
                carrying last-token, cur, active-mask, PRNG keys, ingest
                buffers and done-flags as device state.  The host is
                touched once per window: harvest emitted tokens, retire
                finished slots, refill prompt-ingest buffers, and run
                admission (batched, shape-bucketed wave prefill).

The engine is MESH-NATIVE: ``Engine(mesh=...)`` device-puts params via
``sharding.rules.param_specs`` and jits the window with explicit
``in_shardings``/``out_shardings`` — cache rings sharded slot x sequence
per ``CACHE_RULES`` (the softmax over the sharded S axis becomes a psum
LSE merge; the latent ``A @ z_v`` contraction psums only a tiny
``(B, H, r_v)``, the low-rank win compounding with tensor parallelism),
and the rest of the device carry (last-token, cur, active, per-slot PRNG
keys, ingest buffer) sharded on the slot axis per ``carry_specs``.
Without a mesh the engine runs on a degenerate (1, 1) mesh — the sharded
window IS the single-device path, not a branch.

Chunked prefill rides the same loop: a long prompt's first
``prefill_chunk`` tokens go through the wave prefill; the remainder sits
in a per-slot device buffer and is *fed* through decode steps (cache
writes at the token's true position, sampled outputs discarded until the
final prompt token), so decode-phase slots keep emitting between chunks.

Speculative decoding (``spec_depth > 0``) upgrades each window iteration
from one token to up to ``spec_depth + 1``: a draft (prompt-lookup
n-gram, or the target's own first K layers — see ``serving.draft``)
proposes ``spec_depth`` tokens, and ONE multi-token ``T.verify_step``
scores all proposals against target logits.  Acceptance is the
deterministic specialization of accept/reject-with-residual-resampling:
the per-slot sampler (policy + key stream) is a deterministic function,
so a proposal is accepted iff it equals the token the target would have
emitted, and the first rejection emits the target's own draw (the
residual collapses onto it).  Keys still advance once per *emitted*
token and rejected proposals never touch any ring, so token streams are
invariant to speculation depth — the draft buys step-count, never
changes output.  The accept mask and fed-token history ride the same
slot-sharded device carry (``rules.carry_specs``); no new collectives.

With ReCalKV enabled the resident cache is the *latent* ring — at 50%
compression the same HBM holds 2x the slots (the paper's serving win).
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import single_device_mesh
from repro.models import kv_cache as KC
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import draft as D
from repro.serving import sampler as S
from repro.serving.draft import DraftSpec
from repro.serving.pages import PagePool, PrefixRegistry, prefix_key
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Request, Scheduler
from repro.sharding import rules as R

__all__ = ["Engine", "Request", "SamplingParams", "DraftSpec"]


def _merge_slot(pool_cache, new_cache, slots: jax.Array):
    """Copy ``new_cache``'s leading batch rows into ``pool_cache`` at
    ``slots`` (the prefill wave may be padded past ``len(slots)`` rows for
    shape bucketing — the pad rows are dropped here).

    Batch is dim 0 for prefix/suffix caches but dim 1 under the scanned
    "blocks" subtree (leading dim = pattern periods)."""
    n = slots.shape[0]
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        if key0 == "blocks":
            return pool.at[:, slots].set(new[:, :n].astype(pool.dtype))
        return pool.at[slots].set(new[:n].astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _merge_slot_paged(pool_cache, new_cache, rows: jax.Array,
                      cols: jax.Array, phys: jax.Array, page_size: int):
    """Scatter prefill rows into the PAGED pool: ``new_cache`` is
    slot-major (W, Lr, ...); tile (rows[t], cols[t]) — slot row, logical
    page index — lands in physical page ``phys[t]`` of the page-major
    pool (n_pages, page_size, ...).  Shared prefix pages are simply
    absent from (rows, cols, phys): their content is already resident,
    so admission never rewrites them (copy-on-write by omission)."""
    def one(path, pool, new):
        key0 = getattr(path[0], "key", None)
        ps = page_size
        if key0 == "blocks":
            n_per, W = new.shape[0], new.shape[1]
            tiles = new.reshape((n_per, W, new.shape[2] // ps, ps)
                                + new.shape[3:])[:, rows, cols]
            return pool.at[:, phys].set(tiles.astype(pool.dtype))
        W = new.shape[0]
        tiles = new.reshape((W, new.shape[1] // ps, ps)
                            + new.shape[2:])[rows, cols]
        return pool.at[phys].set(tiles.astype(pool.dtype))
    return jax.tree_util.tree_map_with_path(one, pool_cache, new_cache)


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two, capped: the (wave, prompt-len) shapes a
    long-running engine sees collapse to O(log) values instead of one jit
    retrace per distinct admission wave."""
    return min(max(1, 1 << (n - 1).bit_length()), max(cap, n))


class Engine:
    """Slot-based continuous-batching executor.

    ``sync_every`` sets the decode window: tokens decoded per
    host round-trip.  Large windows amortize dispatch and host syncs
    (throughput); small windows tighten admission latency for queued
    requests and finished-slot turnaround (latency).
    ``prefill_chunk`` bounds how much prompt one admission wave prefills
    at once; the remainder streams through the decode loop.
    ``mesh`` is a ("data", "model") jax Mesh (see ``launch.mesh``); the
    slot axis shards over "data", the cache ring's sequence axis over
    "model".  Default: a (1, 1) single-device mesh.
    ``spec_depth`` turns on speculative decoding: up to that many draft
    tokens verified per window iteration (0 disables).  ``draft`` picks
    the proposer — "ngram" (default) or "layers:K" (self-draft from the
    target's first K layers); token streams are invariant to both knobs.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, source: jax.Array | None = None,
                 backend: str | None = None,
                 sampling: SamplingParams | None = None,
                 sync_every: int = 8, prefill_chunk: int | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 spec_depth: int = 0,
                 draft: str | DraftSpec | None = None,
                 cache_layout: str = "ring",
                 page_size: int | None = None,
                 n_pages: int | None = None):
        if backend is not None:
            cfg = dataclasses.replace(cfg, attn_backend=backend)
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        if cache_layout not in ("ring", "paged"):
            raise ValueError(f"cache_layout={cache_layout!r}: expected "
                             f"'ring' or 'paged'")
        if cache_layout == "ring" and (page_size is not None
                                       or n_pages is not None):
            raise ValueError(
                "page_size/n_pages only apply to cache_layout='paged'")
        self.cache_layout = cache_layout
        self.page_size = self.n_pages = None
        self._pages: PagePool | None = None
        if cache_layout == "paged":
            kinds = set(cfg.expanded_layers())
            bad = sorted(k for k in kinds
                         if k in ("mamba", "rglru", "cross", "attn_cross"))
            if bad:
                raise ValueError(
                    f"cache_layout='paged' needs position-addressed "
                    f"self-attention rings; {cfg.name} has {bad} blocks")
            short = sorted(k for k in kinds
                           if cfg.cache_len(k, max_len) != max_len)
            if short:
                raise ValueError(
                    f"cache_layout='paged' needs full-length rings; "
                    f"{short} blocks keep ring length < max_len={max_len}")
            if page_size is None:
                page_size = next(p for p in (16, 8, 4, 2, 1)
                                 if max_len % p == 0)
            if page_size < 1 or max_len % page_size:
                raise ValueError(f"page_size={page_size} must be >= 1 and "
                                 f"divide max_len={max_len}")
            n_sp = max_len // page_size
            if n_pages is None:
                # ring-equivalent capacity plus the reserved null page;
                # smaller pools trade concurrency headroom for memory
                n_pages = max_slots * n_sp + 1
            if n_pages < n_sp + 1:
                raise ValueError(
                    f"n_pages={n_pages} cannot hold one full-length "
                    f"request ({n_sp} pages + the reserved null page)")
            self.page_size, self.n_pages = page_size, n_pages
            self._pages = PagePool(n_pages)
            self._prefixes = PrefixRegistry()
            self._slot_pages: list[list[int]] = [[] for _ in
                                                 range(max_slots)]
            # The pallas decode path tiles the ring at attn_block; pin it
            # to page_size so the paged kernel's page-per-tile walk is
            # bitwise-identical to the ring kernel's tile sequence (the
            # paged <-> ring parity contract).
            cfg = dataclasses.replace(cfg, attn_block=page_size)
        if spec_depth < 0:
            raise ValueError("spec_depth must be >= 0")
        if spec_depth > 0:
            bad = [k for k in cfg.expanded_layers() if k in ("mamba",
                                                             "rglru")]
            if bad:
                raise ValueError(
                    f"spec_depth > 0 needs position-addressed caches; "
                    f"{cfg.name} has recurrent {sorted(set(bad))} blocks "
                    f"whose state cannot roll back a rejected token")
        self.cfg = cfg
        self.B, self.max_len = max_slots, max_len
        self.source = source
        self.sampling = sampling or S.GREEDY
        self.sync_every = sync_every
        self.spec_depth = spec_depth
        parsed_draft = DraftSpec.parse(draft)
        if parsed_draft is not None and spec_depth == 0:
            raise ValueError(
                f"draft={draft!r} requires spec_depth > 0 — a draft with "
                f"no speculation depth would be silently ignored")
        self.draft = (parsed_draft or DraftSpec("ngram")
                      if spec_depth > 0 else None)
        self.mesh = mesh if mesh is not None else single_device_mesh()
        # slots-per-shard admission locality: only meaningful when the
        # slot axis actually shards (divisible); else one logical shard
        n_slot_shards = math.prod(
            self.mesh.shape[a] for a in R.batch_axes(self.mesh))
        if n_slot_shards < 1 or max_slots % n_slot_shards:
            n_slot_shards = 1
        self.scheduler = Scheduler(max_slots, max_len,
                                   prefill_chunk=prefill_chunk,
                                   slot_shards=n_slot_shards)
        # Mesh-native placement: params by PARAM_RULES (TP heads / FSDP),
        # the pooled cache rings by CACHE_RULES (slot x sequence).
        param_shardings = R.to_named(
            R.param_specs(params, self.mesh, grains=R.head_grains(cfg)),
            self.mesh)
        self.params = jax.device_put(params, param_shardings)
        cache = T.init_decode_cache(
            cfg, max_slots, max_len,
            pages=None if self._pages is None
            else (self.n_pages, self.page_size))
        self._cache_shardings = R.to_named(
            R.cache_specs(cache, self.mesh), self.mesh)
        self.cache = jax.device_put(cache, self._cache_shardings)
        # Layer-fraction draft: a VIEW over the target's first K layers
        # (no new weights) with its own — much smaller — ring cache,
        # sharded by the same rules and carried through the window.
        self.draft_params = self.draft_cache = None
        self._draft_cfg = self._draft_cache_shardings = None
        draft_param_shardings = None
        if self.draft is not None and self.draft.kind == "layers":
            dcfg, dparams = D.make_layer_draft(cfg, self.params,
                                               self.draft.layers)
            self._draft_cfg = dcfg
            draft_param_shardings = R.to_named(
                R.param_specs(dparams, self.mesh,
                              grains=R.head_grains(dcfg)), self.mesh)
            self.draft_params = jax.device_put(dparams,
                                               draft_param_shardings)
            dcache = T.init_decode_cache(dcfg, max_slots, max_len)
            self._draft_cache_shardings = R.to_named(
                R.cache_specs(dcache, self.mesh), self.mesh)
            self.draft_cache = jax.device_put(
                dcache, self._draft_cache_shardings)
        self.finished: list[Request] = []
        # per-slot host mirror of the device loop state (synced once per
        # window); the cache itself never leaves the device
        W = prefill_chunk or 1
        self._st: dict[str, np.ndarray] = {
            "tok": np.zeros(max_slots, np.int32),
            "cur": np.zeros(max_slots, np.int32),
            "act": np.zeros(max_slots, bool),
            "keys": np.zeros((max_slots, 2), np.uint32),
            "temp": np.zeros(max_slots, np.float32),
            "top_k": np.zeros(max_slots, np.int32),
            "top_p": np.ones(max_slots, np.float32),
            "eos": np.full(max_slots, -1, np.int32),
            "left": np.zeros(max_slots, np.int32),
            "buf": np.zeros((max_slots, W), np.int32),
            "avail": np.zeros(max_slots, np.int32),
            "bpos": np.zeros(max_slots, np.int32),
            "more": np.zeros(max_slots, bool),
        }
        if spec_depth > 0:
            # fed-token history: the n-gram draft's corpus, rebuilt from
            # the prompt at admission and extended on-device as tokens
            # are fed (a (B, max_len) carry leaf under carry_specs)
            self._st["hist"] = np.zeros((max_slots, max_len), np.int32)
        if self._pages is not None:
            # slot -> physical-page table: the device-side indirection the
            # paged readers/writers resolve through.  Unmapped logical
            # pages point at the reserved null page 0 (pos -1 there keeps
            # the bias masking them out); rides carry_specs on slot dim 0.
            self._st["ptab"] = np.zeros(
                (max_slots, max_len // self.page_size), np.int32)
        # metrics (sums and `windows` advance atomically at each window
        # boundary in _harvest, so metrics() mid-stream is consistent)
        self.host_syncs = 0          # device->host harvest points
        self.admission_syncs = 0     # host_syncs spent on wave prefills
        self.windows = 0
        self.tokens_emitted = 0      # emitted by decode windows
        self._admit_tokens = 0       # first tokens emitted at admission
        self._occupancy_sum = 0
        self._queue_depth_sum = 0
        self._run_seconds = 0.0
        self.draft_proposed = 0      # draft tokens fed to verification
        self.draft_accepted = 0      # ... accepted (free extra tokens)

        self._prefill = jax.jit(
            lambda p, t, l: T.prefill(cfg, p, t, l, max_len=max_len,
                                      source=None if source is None
                                      else source[: t.shape[0]]),
            static_argnames=())
        if self.draft_cache is not None:
            dcfg = self._draft_cfg
            self._draft_prefill = jax.jit(
                lambda p, t, l: T.prefill(dcfg, p, t, l, max_len=max_len,
                                          source=None if source is None
                                          else source[: t.shape[0]]))
        # Donate the cache buffer(s) into the window: self.cache is
        # rebound to the output, so XLA can update the ring in place
        # instead of holding two full caches live — the cache IS the HBM
        # footprint the paper halves.  (CPU ignores donation and would
        # warn, so only donate where it takes effect.)
        in_sh, out_sh = R.window_shardings(
            self.mesh, self.params, self.cache, self._st,
            param_shardings=param_shardings,
            cache_shardings=self._cache_shardings,
            draft_params=self.draft_params, draft_cache=self.draft_cache,
            draft_param_shardings=draft_param_shardings,
            draft_cache_shardings=self._draft_cache_shardings,
            spec_outputs=spec_depth > 0)
        logits_spec = jax.sharding.NamedSharding(
            self.mesh, R.slot_stacked_spec(max_slots, self.mesh,
                                           lead_dims=0))
        if spec_depth == 0:
            window_fn = self._make_window(
                cfg, max_len, sync_every,
                cache_shardings=self._cache_shardings,
                logits_spec=logits_spec, page_size=self.page_size)
            donate = (1,)
        else:
            window_fn = self._make_spec_window(
                cfg, max_len, sync_every, spec_depth, draft=self.draft,
                draft_cfg=self._draft_cfg,
                cache_shardings=self._cache_shardings,
                draft_cache_shardings=self._draft_cache_shardings,
                logits_spec=logits_spec, page_size=self.page_size)
            donate = (2, 3) if self.draft_cache is not None else (1,)
        if jax.default_backend() == "cpu":
            donate = ()
        self._window = jax.jit(window_fn, donate_argnums=donate,
                               in_shardings=in_sh, out_shardings=out_sh)

    # -- fused decode window -------------------------------------------------

    @staticmethod
    def _make_window(cfg: ModelConfig, max_len: int, steps: int, *,
                     cache_shardings=None, logits_spec=None,
                     page_size: int | None = None):
        """Build the jitted window fn: ``steps`` fused decode iterations.

        Per iteration, per slot: pick the fed token (ingest buffer while
        prompt remains, else last sampled), run one batched decode_step
        (inactive/stalled rows masked from cache writes), sample, then
        update emit/termination flags — all under one lax.scan, so the
        only host sync is the caller harvesting the stacked outputs.

        ``cache_shardings``/``logits_spec`` pin the scan carry's ring
        layout and the sampler's slot-sharded logits so the loop body
        never reshards mid-scan (the mesh must not smuggle per-step
        transfers back in)."""

        def window(params, cache, st):
            def body(carry, _):
                cache, st = carry
                feeding = st["bpos"] < st["avail"]
                buf_tok = jnp.take_along_axis(
                    st["buf"],
                    jnp.minimum(st["bpos"], st["buf"].shape[1] - 1)[:, None],
                    axis=1)[:, 0]
                tok_in = jnp.where(feeding, buf_tok, st["tok"])
                # a slot whose ingest buffer drained but has prompt left on
                # the host stalls (no step) until the next refill
                stalled = st["more"] & ~feeding
                stepping = st["act"] & ~stalled
                pages = ((st["ptab"], page_size)
                         if page_size is not None else None)
                logits, cache = T.decode_step(
                    cfg, params, cache, tok_in, st["cur"], stepping,
                    cache_shardings=cache_shardings, pages=pages)
                ks = jax.vmap(lambda k: jax.random.split(k, 2))(st["keys"])
                sampled = S.sample_tokens(logits, st["temp"], st["top_k"],
                                          st["top_p"], ks[:, 1],
                                          spec=logits_spec)
                last_prompt = (feeding & ~st["more"]
                               & (st["bpos"] + 1 >= st["avail"]))
                emit = stepping & (~feeding | last_prompt)
                cur2 = st["cur"] + stepping.astype(st["cur"].dtype)
                left2 = st["left"] - emit.astype(st["left"].dtype)
                # ring-cap stop: cur2 == max_len means this step wrote the
                # last ring position — the NEXT write would wrap and evict
                # position 0.  (Not max_len - 1: that fired one step early
                # on the ingest path, costing cap-length chunked prompts
                # their final token vs unchunked admission.)
                done = (emit & ((sampled == st["eos"]) | (left2 <= 0))
                        | (stepping & (cur2 >= max_len)))
                st2 = {**st,
                       "tok": jnp.where(emit, sampled, st["tok"]),
                       "cur": cur2,
                       "act": st["act"] & ~done,
                       "keys": jnp.where(emit[:, None], ks[:, 0], st["keys"]),
                       "bpos": st["bpos"] + feeding.astype(st["bpos"].dtype),
                       "left": left2}
                return (cache, st2), (sampled, emit)

            (cache, st), (toks, emits) = jax.lax.scan(
                body, (cache, st), None, length=steps)
            return cache, st, toks, emits

        return window

    # -- speculative decode window -------------------------------------------

    @staticmethod
    def _make_spec_window(cfg: ModelConfig, max_len: int, steps: int,
                          depth: int, *, draft: DraftSpec, draft_cfg=None,
                          cache_shardings=None, draft_cache_shardings=None,
                          logits_spec=None, page_size: int | None = None):
        """Build the jitted speculative window: ``steps`` iterations, each
        verifying up to ``depth`` draft tokens in ONE target pass.

        Per iteration, per slot: propose ``depth`` tokens (n-gram lookup
        over the fed-token history, or greedy steps of the layer draft),
        run one S = depth + 1 token ``T.verify_step``, then walk the S
        positions in order: position j's target draw (the slot's policy
        with its j-th key split) is the token sequential decoding would
        emit there, so a proposal is accepted iff it matches; the first
        mismatch emits the draw itself (deterministic residual) and stops
        the round.  Only the accepted prefix is committed to the ring and
        keys advance exactly once per emitted token — the sequential body
        is the S = 1 special case, so streams are depth-invariant.
        Ingesting (chunked-prefill) slots keep their one-token-per-
        iteration behavior: their columns >= 1 are never candidates."""
        S_pos = depth + 1
        has_draft_model = draft.kind == "layers"

        def round_body(params, dparams, cache, dcache, st):
            feeding = st["bpos"] < st["avail"]
            buf_tok = jnp.take_along_axis(
                st["buf"],
                jnp.minimum(st["bpos"], st["buf"].shape[1] - 1)[:, None],
                axis=1)[:, 0]
            tok_in = jnp.where(feeding, buf_tok, st["tok"])
            stalled = st["more"] & ~feeding
            stepping = st["act"] & ~stalled
            speculating = stepping & ~feeding
            cur = st["cur"]
            js = jnp.arange(S_pos, dtype=cur.dtype)
            cap_ok = (cur[:, None] + js[None, :]) < max_len      # (B, S)

            # --- proposals (B, depth)
            if has_draft_model:
                props = []
                d_tok, d_cur = tok_in, cur
                # S_pos draft steps: feeds [tok_in, d1..d_depth], so the
                # draft ring also covers the last (bonus) position on
                # full acceptance; rejected columns are struck from its
                # position index below.
                for j in range(S_pos):
                    act_j = (stepping if j == 0
                             else speculating & cap_ok[:, j])
                    dlogits, dcache = T.decode_step(
                        draft_cfg, dparams, dcache, d_tok, d_cur, act_j,
                        cache_shardings=draft_cache_shardings)
                    d_cur = d_cur + act_j.astype(d_cur.dtype)
                    if j < depth:
                        d_tok = jnp.argmax(dlogits, -1).astype(jnp.int32)
                        props.append(d_tok)
                props = jnp.stack(props, axis=1)
            else:
                props = D.ngram_propose(st["hist"], cur, tok_in, depth)

            # --- one multi-token target pass over [tok_in | proposals]
            fed = jnp.concatenate([tok_in[:, None], props], axis=1)
            cand = jnp.concatenate(
                [stepping[:, None], speculating[:, None] & cap_ok[:, 1:]],
                axis=1)                                          # (B, S)
            # the draft ring (layer draft) stays slot-major even in paged
            # mode — only the target cache resolves through the page table
            pages = ((st["ptab"], page_size)
                     if page_size is not None else None)
            logits, updates = T.verify_step(cfg, params, cache, fed, cur,
                                            cand, pages=pages)
            last_prompt = (feeding & ~st["more"]
                           & (st["bpos"] + 1 >= st["avail"]))

            # --- in-order accept / residual walk (j == emission index)
            keys_state = st["keys"]
            tok2 = st["tok"]
            done_any = jnp.zeros_like(st["act"])
            nemit = jnp.zeros_like(cur)
            cols = []
            emit_prev = s_prev = None
            for j in range(S_pos):
                if j == 0:
                    valid_j = stepping
                    emit_j = stepping & (~feeding | last_prompt)
                else:
                    valid_j = (emit_prev & ~done_any & cand[:, j]
                               & (fed[:, j] == s_prev))
                    emit_j = valid_j
                ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys_state)
                s_j = S.sample_tokens(logits[:, j], st["temp"],
                                      st["top_k"], st["top_p"], ks[:, 1],
                                      spec=logits_spec)
                nemit = nemit + emit_j.astype(cur.dtype)
                left_j = st["left"] - nemit
                done_j = (emit_j & ((s_j == st["eos"]) | (left_j <= 0))
                          | (valid_j & (cur + j + 1 >= max_len)))
                done_any = done_any | done_j
                keys_state = jnp.where(emit_j[:, None], ks[:, 0],
                                       keys_state)
                tok2 = jnp.where(emit_j, s_j, tok2)
                cols.append((valid_j, emit_j, s_j))
                emit_prev, s_prev = emit_j, s_j
            valid = jnp.stack([c[0] for c in cols], axis=1)      # (B, S)
            emits_r = jnp.stack([c[1] for c in cols], axis=1)
            toks_r = jnp.stack([c[2] for c in cols], axis=1)

            # --- commit the accepted prefix (rejected tokens never wrote)
            cache = T.commit_verify_writes(cache, updates, cur, valid,
                                           cache_shardings=cache_shardings,
                                           pages=pages)
            if has_draft_model:
                # the draft wrote as it proposed; strike rejected columns
                # from its position index so they can't shadow the slot
                for j in range(1, S_pos):
                    dcache = KC.invalidate_positions(
                        dcache, cur + j, cand[:, j] & ~valid[:, j])
            hist = st["hist"]
            iota = jnp.arange(hist.shape[1], dtype=cur.dtype)[None, :]
            for j in range(S_pos):
                hit = (iota == (cur + j)[:, None]) & valid[:, j][:, None]
                hist = jnp.where(hit, fed[:, j][:, None], hist)

            st2 = {**st,
                   "tok": tok2,
                   "cur": cur + valid.astype(cur.dtype).sum(axis=1),
                   "act": st["act"] & ~done_any,
                   "keys": keys_state,
                   "bpos": st["bpos"] + feeding.astype(st["bpos"].dtype),
                   "left": st["left"] - nemit,
                   "hist": hist}
            accepted = valid[:, 1:].astype(jnp.int32).sum(axis=1)
            # count only REAL proposals: the n-gram draft pads unknown
            # positions with -1 (guaranteed rejects), which would deflate
            # accept_rate below what the draft actually achieves on the
            # positions it dared to predict
            proposed = ((cand[:, 1:] & (fed[:, 1:] >= 0))
                        .astype(jnp.int32).sum(axis=1))
            return cache, dcache, st2, (toks_r, emits_r, accepted,
                                        proposed)

        if has_draft_model:
            def window(params, dparams, cache, dcache, st):
                def body(carry, _):
                    cache, dcache, st = carry
                    cache, dcache, st2, ys = round_body(
                        params, dparams, cache, dcache, st)
                    return (cache, dcache, st2), ys
                (cache, dcache, st), (toks, emits, acc, prop) = \
                    jax.lax.scan(body, (cache, dcache, st), None,
                                 length=steps)
                return cache, dcache, st, toks, emits, acc, prop
        else:
            def window(params, cache, st):
                def body(carry, _):
                    cache, st = carry
                    cache, _, st2, ys = round_body(params, None, cache,
                                                   None, st)
                    return (cache, st2), ys
                (cache, st), (toks, emits, acc, prop) = jax.lax.scan(
                    body, (cache, st), None, length=steps)
                return cache, st, toks, emits, acc, prop

        return window

    @classmethod
    def from_artifact(cls, path: str, *, max_slots: int, max_len: int,
                      source: jax.Array | None = None,
                      backend: str | None = None,
                      sampling: SamplingParams | None = None,
                      sync_every: int = 8,
                      prefill_chunk: int | None = None,
                      mesh: jax.sharding.Mesh | None = None,
                      spec_depth: int = 0,
                      draft: str | DraftSpec | None = None,
                      cache_layout: str = "ring",
                      page_size: int | None = None,
                      n_pages: int | None = None) -> "Engine":
        """Boot an engine straight from a saved compression artifact —
        the compress-offline / serve-forever workflow across processes."""
        from repro.api import load_artifact  # local: api imports models too

        art = load_artifact(path)
        return cls(art.cfg, art.params, max_slots=max_slots, max_len=max_len,
                   source=source, backend=backend, sampling=sampling,
                   sync_every=sync_every, prefill_chunk=prefill_chunk,
                   mesh=mesh, spec_depth=spec_depth, draft=draft,
                   cache_layout=cache_layout, page_size=page_size,
                   n_pages=n_pages)

    # -- back-compat conveniences -------------------------------------------

    @property
    def slot_req(self) -> list[Request | None]:
        return self.scheduler.slot_req

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def unfinished(self) -> dict[str, int]:
        """Requests not yet finished: queued vs admitted-but-mid-flight."""
        return {"queued": self.scheduler.queue_depth,
                "in_flight": self.scheduler.occupancy}

    @property
    def mesh_str(self) -> str:
        """Mesh shape joined over ALL axes in mesh order (e.g. "1x1",
        "2x4", "2x16x16" for a multi-pod mesh)."""
        return "x".join(str(self.mesh.shape[a]) for a in self.mesh.axis_names)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> Request:
        return self.scheduler.submit(req)

    def _finish(self, slot: int):
        self.finished.append(self.scheduler.slot_req[slot])
        self.scheduler.release(slot)
        st = self._st
        st["act"][slot] = False
        st["avail"][slot] = 0
        st["bpos"][slot] = 0
        st["more"][slot] = False
        st["left"][slot] = 0
        if self._pages is not None:
            for pg in self._slot_pages[slot]:
                if self._pages.free(pg):
                    # last holder gone: retire the page's prefix key so a
                    # future prompt can't map to recycled content
                    self._prefixes.drop_page(pg)
            self._slot_pages[slot] = []
            st["ptab"][slot] = 0

    # -- paged admission helpers ---------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Worst-case page count for ``req``: its write reach is known at
        admission (prompt + generation budget, capped by the ring), so
        admission can reserve up front and the device loop never faults.
        Conservative — ignores prefix sharing, so a fitting wave always
        has real pages even if every registry lookup misses."""
        reach = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return -(-reach // self.page_size)

    def _assign_pages(self, slot: int, req: Request, first_len: int):
        """Map ``req``'s logical pages to physical ones: longest
        registry-hit prefix is *retained* (refcount++, no copy), the rest
        freshly allocated.  Returns (mapping, scatter_cols): the full
        physical mapping for the ptab row, and which logical pages the
        wave prefill must scatter (the non-shared ones).

        Copy-on-write resolves at admission: only prefix pages FULLY
        covered by this wave's prefill are shareable, and the first
        logical page past the shared run is by definition divergent —
        its content comes from this request's own prefill scatter, so
        the "copy" is free.  Generation never touches shared pages
        (writes start at first_len >= shared run end)."""
        ps = self.page_size
        n_need = self._pages_needed(req)
        shared: list[int] = []
        lim = min(n_need, first_len // ps)
        for j in range(lim):
            pg = self._prefixes.lookup(prefix_key(req.prompt, j, ps))
            if pg is None:
                break
            shared.append(pg)
        for pg in shared:
            self._pages.retain(pg)
        if shared and n_need > len(shared):
            # first divergent page: a fork in COW terms, but the new
            # content arrives via this request's own prefill scatter —
            # no device copy needed, just a fresh page
            self._pages.cow_forks += 1
        own = self._pages.alloc(n_need - len(shared))
        mapping = shared + own
        for j in range(len(shared), n_need):
            # register pages whose content this wave's prefill fully
            # determines (complete, never-rewritten prompt prefixes)
            if (j + 1) * ps <= first_len:
                self._prefixes.register(prefix_key(req.prompt, j, ps),
                                        mapping[j])
        self._slot_pages[slot] = list(mapping)
        row = self._st["ptab"][slot]
        row[:] = 0
        row[: n_need] = mapping
        return mapping, list(range(len(shared), n_need))

    def _admit(self):
        if self._pages is None:
            wave = self.scheduler.take_wave()
        else:
            # page-budget admission: reserve each request's worst-case
            # reach up front (head-of-line FIFO — see take_wave).  The
            # budget is conservative (ignores prefix sharing); actual
            # allocation below may use fewer pages via retained prefixes.
            budget = self._pages.free_count

            def fits(req: Request) -> bool:
                nonlocal budget
                need = self._pages_needed(req)
                if need > budget:
                    return False
                budget -= need
                return True

            wave = self.scheduler.take_wave(fits)
        if not wave:
            return
        first_lens = [self.scheduler.first_chunk_len(r) for _, r in wave]
        # Bucket the wave to power-of-two (rows, prompt-len) shapes so a
        # stream of ragged admissions reuses O(log) jit traces.  The row
        # cap is the slot count; the length cap is max_len (padding past
        # the ring would silently drop a fittable prompt prefix).
        W = _bucket(len(wave), self.B)
        P = _bucket(max(first_lens), self.max_len)
        toks = np.zeros((W, P), np.int32)
        lens = np.zeros((W,), np.int32)
        for i, (_, r) in enumerate(wave):
            toks[i, : first_lens[i]] = r.prompt[: first_lens[i]]
            lens[i] = first_lens[i]
        logits, new_cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        slots = jnp.asarray([s for s, _ in wave])
        if self._pages is None:
            self.cache = _merge_slot(self.cache, new_cache, slots)
        else:
            rows, cols, phys = [], [], []
            for i, (slot, r) in enumerate(wave):
                mapping, scat = self._assign_pages(slot, r, first_lens[i])
                for j in scat:
                    rows.append(i)
                    cols.append(j)
                    phys.append(mapping[j])
            if phys:
                # non-shared pages only: shared prefixes are already
                # resident and must not be rewritten (their tail slots in
                # new_cache hold pos=-1 filler, same as fresh pages get)
                self.cache = _merge_slot_paged(
                    self.cache, new_cache, jnp.asarray(rows),
                    jnp.asarray(cols), jnp.asarray(phys), self.page_size)
        if self.draft_cache is not None:
            # the layer draft consumes the same wave so its ring tracks
            # the target's (its logits here are irrelevant)
            _, dnew = self._draft_prefill(
                self.draft_params, jnp.asarray(toks), jnp.asarray(lens))
            self.draft_cache = _merge_slot(self.draft_cache, dnew, slots)
        # Sample each wave row's first token with the SAME policy + key
        # split the decode window would use — a request's stream is then
        # identical whether its first token comes from the wave prefill
        # (whole prompt consumed) or from the loop's last ingest step
        # (chunked).  At temperature=0 this is exact argmax, matching the
        # seed engine.
        specs = [r.sampling or self.sampling for _, r in wave]
        keys0 = np.stack([sp.slot_key(r.uid)
                          for sp, (_, r) in zip(specs, wave)])
        ks = jax.vmap(lambda k: jax.random.split(k, 2))(jnp.asarray(keys0))
        n = len(wave)
        first = np.asarray(S.sample_tokens(
            logits[:n],
            jnp.asarray([sp.temperature for sp in specs], jnp.float32),
            jnp.asarray([sp.top_k for sp in specs], jnp.int32),
            jnp.asarray([sp.top_p for sp in specs], jnp.float32),
            ks[:, 1]))
        ks = np.asarray(ks)
        self.host_syncs += 1
        self.admission_syncs += 1
        st = self._st
        for i, (slot, r) in enumerate(wave):
            sp = specs[i]
            st["cur"][slot] = first_lens[i]
            st["keys"][slot] = keys0[i]
            st["temp"][slot] = sp.temperature
            st["top_k"][slot] = sp.top_k
            st["top_p"][slot] = sp.top_p
            st["eos"][slot] = -1 if r.eos_id is None else r.eos_id
            st["bpos"][slot] = 0
            st["act"][slot] = True
            if "hist" in st:
                # the WHOLE prompt is known at admission (even the not-
                # yet-ingested tail): seed the n-gram corpus up front
                st["hist"][slot] = 0
                st["hist"][slot, : len(r.prompt)] = r.prompt
            rest = r.prompt[first_lens[i]:]
            if rest.size == 0:
                # whole prompt prefilled: emit the first generated token
                # right away (as the seed engine did) and advance the key
                st["keys"][slot] = ks[i, 0]
                r.out_tokens.append(int(first[i]))
                self._admit_tokens += 1
                st["tok"][slot] = first[i]
                st["left"][slot] = r.max_new_tokens - 1
                st["avail"][slot] = 0
                st["more"][slot] = False
                if r.done:
                    self._finish(slot)
            else:
                # chunked prefill: stream the remainder through the
                # decode loop's ingest buffer
                self.scheduler.set_pending(slot, rest)
                self._load_chunk(slot)
                st["tok"][slot] = 0
                st["left"][slot] = r.max_new_tokens

    def _load_chunk(self, slot: int):
        chunk = self.scheduler.next_chunk(slot)
        st = self._st
        w = chunk.shape[0]
        st["buf"][slot, :w] = chunk
        st["avail"][slot] = w
        st["bpos"][slot] = 0
        st["more"][slot] = self.scheduler.pending_len(slot) > 0

    def _refill(self):
        st = self._st
        for slot, r in enumerate(self.scheduler.slot_req):
            if (r is not None and st["act"][slot]
                    and st["bpos"][slot] >= st["avail"][slot]
                    and self.scheduler.pending_len(slot) > 0):
                self._load_chunk(slot)

    # -- one engine step (= one decode window) -------------------------------

    def step(self):
        """Admit + refill, then run one ``sync_every``-token fused decode
        window and harvest it (the single host sync of the step).

        Wall-clock accrues HERE (not in run()), so callers driving
        ``step()`` directly — benches, external event loops — still get a
        meaningful ``tokens_per_s`` out of :meth:`metrics`.  Idle no-op
        calls (nothing active, nothing admitted) accrue nothing: an
        event loop polling an empty engine must not dilute the rate."""
        t0 = time.perf_counter()
        self._admit()
        self._refill()
        st = self._st
        if not st["act"].any():
            return
        # window-boundary snapshot: the load THIS window runs with —
        # folded into the means in _harvest, atomically with `windows`
        occ, qd = self.scheduler.occupancy, self.scheduler.queue_depth
        state = {k: jnp.asarray(v) for k, v in st.items()}
        acc = prop = None
        if self.draft_cache is not None:
            (self.cache, self.draft_cache, state, toks, emits, acc,
             prop) = self._window(self.params, self.draft_params,
                                  self.cache, self.draft_cache, state)
        elif self.spec_depth > 0:
            self.cache, state, toks, emits, acc, prop = self._window(
                self.params, self.cache, state)
        else:
            self.cache, state, toks, emits = self._window(
                self.params, self.cache, state)
        self._harvest(state, toks, emits, occ, qd, acc, prop)
        self._run_seconds += time.perf_counter() - t0

    def _harvest(self, state, toks, emits, occ: int, qd: int,
                 acc=None, prop=None):
        toks = np.asarray(toks)                 # (K, B) or (K, B, S)
        emits = np.asarray(emits)
        if toks.ndim == 2:                      # non-speculative window
            toks, emits = toks[:, :, None], emits[:, :, None]
        self._st = {k: np.array(v) for k, v in state.items()}
        # every window-scoped counter advances together, here and only
        # here — a mid-stream metrics() call never sees sums from one
        # window paired with counts from another
        self.host_syncs += 1
        self.windows += 1
        self.tokens_emitted += int(emits.sum())
        self._occupancy_sum += occ
        self._queue_depth_sum += qd
        if acc is not None:
            self.draft_accepted += int(np.asarray(acc).sum())
            self.draft_proposed += int(np.asarray(prop).sum())
        slot_req = self.scheduler.slot_req
        for k in range(toks.shape[0]):
            for j in range(toks.shape[2]):
                for i in np.nonzero(emits[k, :, j])[0]:
                    slot_req[i].out_tokens.append(int(toks[k, i, j]))
        for slot, r in enumerate(slot_req):
            if r is not None and not self._st["act"][slot]:
                self._finish(slot)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive until drained or ``max_steps`` windows.  On timeout the
        engine warns and leaves the backlog inspectable via
        ``engine.unfinished`` (callers distinguish drain from timeout).
        Wall-clock accrues per :meth:`step`, so run() stays additive."""
        steps = 0
        while self.scheduler.has_work and steps < max_steps:
            self.step()
            steps += 1
        if self.scheduler.has_work:
            u = self.unfinished
            warnings.warn(
                f"Engine.run stopped at max_steps={max_steps} with "
                f"{u['queued']} queued and {u['in_flight']} in-flight "
                f"requests unfinished (not a drain)", RuntimeWarning,
                stacklevel=2)
        return self.finished

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict[str, Any]:
        """Serving counters since construction (host_syncs counts one per
        decode-window harvest plus one per admission wave).

        Safe to call mid-stream: window-scoped sums and ``windows``
        advance atomically at each harvest, and the instantaneous
        ``occupancy``/``queue_depth`` read the scheduler — the host-side
        truth at every window boundary — never the device mirror's
        active flags (which are stale between harvests)."""
        tokens = self.tokens_emitted + self._admit_tokens
        w = max(self.windows, 1)
        pool = self._pages
        return {
            "tokens": tokens,
            "windows": self.windows,
            "sync_every": self.sync_every,
            "cache_layout": self.cache_layout,
            "page_size": self.page_size or 0,
            "pages_total": 0 if pool is None else self.n_pages,
            "pages_free": 0 if pool is None else pool.free_count,
            "pages_shared": 0 if pool is None else pool.share_events,
            "pages_peak": 0 if pool is None else pool.peak_used,
            "cow_forks": 0 if pool is None else pool.cow_forks,
            "mesh": self.mesh_str,
            "spec_depth": self.spec_depth,
            "draft": (None if self.draft is None else
                      (self.draft.kind if self.draft.kind == "ngram"
                       else f"layers:{self.draft.layers}")),
            "draft_proposed": self.draft_proposed,
            "draft_accepted": self.draft_accepted,
            "accept_rate": (self.draft_accepted / self.draft_proposed
                            if self.draft_proposed else 0.0),
            "host_syncs": self.host_syncs,
            "admission_syncs": self.admission_syncs,
            "host_syncs_per_token": self.host_syncs / max(tokens, 1),
            "decode_syncs_per_token": self.windows / max(self.tokens_emitted, 1),
            "occupancy": self.scheduler.occupancy,
            "queue_depth": self.scheduler.queue_depth,
            "occupancy_mean": self._occupancy_sum / w,
            "queue_depth_mean": self._queue_depth_sum / w,
            "run_seconds": self._run_seconds,
            "tokens_per_s": tokens / self._run_seconds
                            if self._run_seconds else 0.0,
        }
