"""On-device token sampling for the serving engine.

``SamplingParams`` is the per-request policy (greedy / temperature /
top-k / top-p); ``sample_tokens`` is the batched, jit-friendly kernel the
executor's fused decode loop calls every step.  Every knob is a per-slot
*array* (not a Python value), so one trace serves any mix of requests —
a greedy slot and a top-p slot ride the same ``lax.scan`` iteration.

Determinism: each slot carries its own PRNG key (derived from
``SamplingParams.seed`` and the request uid), advanced once per *emitted*
token — a request's sampled stream is therefore reproducible run-to-run
and independent of its batch-mates or of scheduler stalls.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-1e30)
_TEMP_EPS = 1e-6
# Scaled logits are clipped to +-_SCALED_MAX before filtering: a tiny
# temperature divides logits toward float32 infinity, and one inf turns
# the top-p softmax (and then the whole filtered row) into NaN.  The
# bound sits well inside float32 range but above any real logit scale,
# and NEG_INF masking stays strictly below it, so ordering — hence the
# sampled stream — is unchanged for sane inputs.
_SCALED_MAX = jnp.float32(1e29)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy.

    temperature  0.0 -> greedy argmax (exact parity with the seed engine);
                 otherwise logits are scaled by 1/temperature.
    top_k        keep only the k highest logits (0 -> disabled).
    top_p        keep the minimal nucleus whose probability mass reaches
                 top_p, computed on the temperature-scaled distribution
                 after top-k (1.0 -> disabled).
    seed         folded with the request uid into the slot's PRNG key.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    def slot_key(self, uid: int) -> np.ndarray:
        """The (2,) uint32 PRNG key a slot starts from for this request."""
        return np.asarray(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), uid))


GREEDY = SamplingParams()


def filtered_logits(logits: jax.Array, top_k: jax.Array,
                    top_p: jax.Array) -> jax.Array:
    """Apply per-row top-k then minimal-nucleus top-p masking.

    logits (B, V) float32; top_k (B,) int32 (0 disables); top_p (B,)
    float32 (>= 1 disables).  Top-k keeps *exactly* k entries (ties broken
    by argsort order); top-p keeps the smallest prefix of the sorted
    distribution whose cumulative probability reaches top_p (the entry
    that crosses the threshold is kept; the top-1 always survives).
    """
    V = logits.shape[-1]
    order = jnp.argsort(-logits, axis=-1)            # descending indices
    ranks = jnp.argsort(order, axis=-1)              # rank of each entry
    k = jnp.where(top_k > 0, jnp.minimum(top_k, V), V)
    keep_k = ranks < k[:, None]
    masked = jnp.where(keep_k, logits, NEG_INF)

    sorted_l = jnp.take_along_axis(masked, order, axis=-1)
    probs = jax.nn.softmax(sorted_l, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs      # mass strictly above
    keep_sorted = before < top_p[:, None]
    keep_sorted = keep_sorted.at[:, 0].set(True)
    keep_p = jnp.take_along_axis(keep_sorted, ranks, axis=-1)
    return jnp.where(keep_k & keep_p, logits, NEG_INF)


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array,
                  keys: jax.Array, *, spec=None) -> jax.Array:
    """One sampled token per row.  logits (B, V); knobs (B,) arrays;
    keys (B, 2) uint32 per-slot PRNG keys (use-once — the caller carries
    the split).  Rows with temperature below the ``_TEMP_EPS`` floor
    (including 0) return exact argmax — a sub-floor temperature is
    already a collapsed distribution, and scaling by its reciprocal
    would overflow float32; an all-greedy batch skips the sort-based
    filtering entirely (lax.cond), so a greedy serving engine pays
    nothing for the sampling machinery.

    ``spec`` (optional NamedSharding for the (B, V) logits: slot axis
    sharded, vocab replicated) pins the sampler's working set under a
    mesh.  Logits arrive vocab-sharded from the tensor-parallel lm_head;
    every sampling op (argsort/cumsum over V, the per-row categorical
    draw) is row-local, so one explicit reshard up front makes the whole
    filter+draw local to the slot shard instead of letting SPMD re-derive
    (and possibly re-gather) per op."""
    logits = logits.astype(jnp.float32)
    if spec is not None:
        logits = jax.lax.with_sharding_constraint(logits, spec)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # Temperatures below the clamp floor are semantically greedy (the
    # distribution has collapsed onto argmax) — route them to the exact
    # argmax branch instead of scaling logits by up to 1/_TEMP_EPS, which
    # could overflow float32 and NaN the whole filtered row.
    is_greedy = temperature < _TEMP_EPS

    def sampled(_):
        t = jnp.maximum(temperature, _TEMP_EPS)[:, None]
        scaled = jnp.clip(logits / t, -_SCALED_MAX, _SCALED_MAX)
        masked = filtered_logits(scaled, top_k, top_p)
        s = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)
        return jnp.where(is_greedy, greedy, s)

    return jax.lax.cond(jnp.all(is_greedy), lambda _: greedy, sampled, None)


def split_keys(keys: jax.Array) -> jax.Array:
    """Advance a batch of per-slot PRNG keys one step: (B, 2) uint32 ->
    (B, 2, 2) where [:, 0] is the draw key for this step and [:, 1] the
    chain carried forward.  One helper so the decode window, the
    speculative window and the admission path derive keys identically —
    the per-request stream depends only on how many tokens that slot has
    *emitted*, which is what makes overlapped/staged admission
    token-for-token equal to the sync engine.  Safe to call from the
    admission worker thread: pure jax dispatch, no host state."""
    return jax.vmap(lambda k: jax.random.split(k, 2))(keys)


# Jitted admission-time sampler.  Admission used to call sample_tokens
# eagerly (op-by-op dispatch on the wave's first logits); both the sync
# and the overlapped engine now share this one jitted entry point so the
# first token of a request is bitwise identical whichever path admitted
# it — sample_tokens is batch-invariant per row, so bucket padding rows
# cannot perturb real rows.  ``spec`` stays static (it is a hashable
# NamedSharding or None, not an array).
sample_tokens_jit = jax.jit(sample_tokens, static_argnames=("spec",))
