"""Host-side plumbing for the overlapped serving pipeline.

The overlapped engine keeps (up to) two decode windows in flight and
blocks the host only on the *trailing* window's packed status array —
everything else the host used to do synchronously at a window boundary
is either expressed as device dataflow (slot merges chained onto the
leading window's output futures) or deferred onto the token backlog:

  * ``InflightWindow`` is the per-dispatch record: the output futures a
    later boundary will harvest, plus the host-side snapshot (slot ->
    request map, occupancy/queue depth, dispatch index) that makes the
    harvest interpretable after the scheduler has moved on.
  * ``TokenBacklog`` is a single worker thread draining a FIFO of
    closures (MaxText's ``detokenize_backlog`` shape): per-window token
    transfer + detokenize + stream callbacks run there, so the main loop
    never blocks on Python-side token handling.  Exceptions are captured
    and re-raised on the submitting thread at the next ``put``/``flush``
    /``close`` so a crashed worker fails the run instead of hanging it.

Ordering contract: items are processed strictly in put() order by one
worker, so per-request token order is exactly dispatch order — this is
what keeps overlapped streams token-for-token identical to the sync
engine's.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable

__all__ = ["InflightWindow", "TokenBacklog"]

_STOP = object()


@dataclasses.dataclass
class InflightWindow:
    """One dispatched-but-unharvested decode window.

    ``status`` is the only array the boundary blocks on: a packed (2, B)
    int32 of (active, buffer position) stacked on device at dispatch, so
    harvesting costs one transfer instead of one per leaf.  ``toks`` /
    ``emits`` (and the spec counters) are handed to the backlog worker,
    which transfers them off the critical path.  ``slot_reqs`` snapshots
    the slot -> request map at dispatch: the scheduler may re-assign a
    slot at a later boundary before this window is harvested, and tokens
    must be credited to the request that actually occupied the slot.
    """

    index: int                      # dispatch sequence number
    status: Any                     # (2, B) int32 device future
    toks: Any                       # (B, steps[, S]) token futures
    emits: Any                      # (B, steps[, S]) emit-mask futures
    slot_reqs: list                 # slot -> Request at dispatch time
    occ: int                        # scheduler occupancy at dispatch
    qd: int                         # scheduler queue depth at dispatch
    overlapped: bool                # dispatched before prior completed?
    acc: Any = None                 # spec: accepted-count future
    prop: Any = None                # spec: proposed-count future


class TokenBacklog:
    """A FIFO of host-side work items drained by one daemon thread.

    Items are zero-argument callables (closures over device futures).
    The thread is started lazily on the first ``put`` so a sync engine
    never spawns it.  ``flush`` blocks until every queued item has run;
    ``close`` flushes, stops the thread, and joins it — both re-raise
    the first exception a work item threw.
    """

    def __init__(self, name: str = "token-backlog"):
        self._name = name
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None
        self._closed = False

    @property
    def started(self) -> bool:
        return self._thread is not None

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _ensure_thread(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._drain, name=self._name, daemon=True)
            self._thread.start()

    def _drain(self):
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                if self._err is None:
                    item()
            except BaseException as e:  # noqa: BLE001 — repo rt on main thread
                self._err = e
            finally:
                self._q.task_done()

    def _reraise(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError(
                f"{self._name} worker failed while draining") from err

    def put(self, item: Callable[[], None]):
        if self._closed:
            raise RuntimeError(f"{self._name} is closed")
        self._reraise()
        self._ensure_thread()
        self._q.put(item)

    def flush(self):
        """Block until every item queued so far has been processed."""
        if self._thread is not None:
            self._q.join()
        self._reraise()

    def close(self):
        """Flush, stop, and join the worker.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._q.join()
            self._q.put(_STOP)
            self._thread.join()
            self._thread = None
        self._reraise()
