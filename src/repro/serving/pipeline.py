"""Host-side plumbing for the overlapped serving pipeline.

The overlapped engine keeps up to ``pipeline_depth`` decode windows in
flight and blocks the host only on the *trailing* window's packed status
array — everything else the host used to do synchronously at a window
boundary is either expressed as device dataflow (slot merges chained
onto the leading window's output futures) or deferred onto a worker:

  * ``InflightWindow`` is the per-dispatch record: the output futures a
    later boundary will harvest, plus the host-side snapshot (slot ->
    request map, occupancy/queue depth, dispatch index) that makes the
    harvest interpretable after the scheduler has moved on.
  * ``TokenBacklog`` is a single worker thread draining a FIFO of
    closures (MaxText's ``detokenize_backlog`` shape): per-window token
    transfer + detokenize + stream callbacks run there, so the main loop
    never blocks on Python-side token handling.  Exceptions are captured
    and re-raised on the submitting thread at the next ``put``/``flush``
    /``close`` so a crashed worker fails the run instead of hanging it.
  * ``AdmissionWorker`` is the admission-prefill thread: it pops
    queue-head requests (``StagedWave`` granularity) and dispatches
    their wave prefill + first-token sample as DEVICE FUTURES, so a long
    prompt's prefill overlaps in-flight decode instead of stalling the
    dispatch loop.  The worker never mutates scheduler/pool/mirror
    state — everything host-visible merges on the main thread at a
    window boundary, which is what keeps streams token-for-token equal
    to the sync engine (prefill is row-independent and the first-token
    sample is batch-invariant per row, so wave composition is free).

Ordering contract: items are processed strictly in put() order by one
worker, so per-request token order is exactly dispatch order — this is
what keeps overlapped streams token-for-token identical to the sync
engine's.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable

__all__ = ["AdmissionWorker", "InflightWindow", "PreemptedRecord",
           "StagedEntry", "StagedWave", "TokenBacklog"]

_STOP = object()


@dataclasses.dataclass
class InflightWindow:
    """One dispatched-but-unharvested decode window.

    ``status`` is the only array the boundary blocks on: a packed 1-D
    int32 concatenation of (active, buffer position[, gen][, accept/
    propose sums][, swap seq/slot], active-iteration count) built on
    device at dispatch, so harvesting costs one transfer instead of one
    per leaf; the harvest parses it positionally by the same layout.  ``toks`` /
    ``emits`` (and the spec counters) are handed to the backlog worker,
    which transfers them off the critical path.  ``slot_reqs`` snapshots
    the slot -> request map at dispatch: the scheduler may re-assign a
    slot at a later boundary before this window is harvested, and tokens
    must be credited to the request that actually occupied the slot.
    """

    index: int                      # dispatch sequence number
    status: Any                     # (R, B) int32 device future
    toks: Any                       # (B, steps[, S]) token futures
    emits: Any                      # (B, steps[, S]) emit-mask futures
    slot_reqs: list                 # slot -> Request at dispatch time
    occ: int                        # scheduler occupancy at dispatch
    qd: int                         # scheduler queue depth at dispatch
    overlapped: bool                # dispatched before prior completed?
    acc: Any = None                 # spec: accepted-count future
    prop: Any = None                # spec: proposed-count future
    n_active: Any = None            # (steps,) stepping-slot counts future
    stage_entries: list | None = None  # continuous: stage table snapshot


@dataclasses.dataclass
class StagedWave:
    """One admission wave prepared off the dispatch path: prompts
    prefilled and first tokens sampled as device futures, awaiting its
    main-thread merge (slot placement or stage-row scatter).  ``merged``
    counts the leading requests already consumed — a wave larger than
    the free slots (or page budget) merges across several boundaries,
    head-of-line FIFO throughout."""

    reqs: list                      # policy-ordered run of staged Requests
    first_lens: list                # wave-prefill coverage per request
    specs: list                     # resolved SamplingParams per request
    keys0: Any                      # (n, 2) uint32 base PRNG keys (host)
    eos: Any                        # (n,) int32 eos ids (host)
    full: Any                       # (n,) bool whole-prompt-prefilled
    ks: Any                         # (W, 2, 2) split keys (device)
    first: Any                      # (W,) first sampled tokens (device)
    new_cache: Any                  # slot-major prefill cache (device)
    draft_new_cache: Any = None     # layer-draft twin (device)
    merged: int = 0                 # leading reqs already merged
    # prefill-skip (prefix-affinity): request i rides prefill row
    # rows[i] of the W-bucketed arrays, or rows[i] == -1 when its whole
    # first chunk is covered by resident registry pages and it admits
    # with ZERO prefill — cur starts at the shared coverage and the
    # prompt remainder streams through the decode loop's ingest buffer.
    # keys0/eos/full/specs are per-request (length n); ks/first/
    # new_cache are per-prefill-row (width W <= n).  None rows => all
    # requests prefill (one row each, pre-refactor layout).
    rows: list | None = None


@dataclasses.dataclass
class StagedEntry:
    """One request scattered into the device-side staging queue
    (continuous batching): the host-known carry row it was staged with,
    kept until a harvested window confirms the in-scan install so the
    mirror/scheduler can be updated retroactively."""

    req: Any
    host_row: dict                  # carry-leaf name -> per-slot row (np)
    pending: Any                    # un-ingested prompt tail (np) or None
    pages: list | None              # paged: physical pages already owned
    seq: int                        # staging sequence number (device key)
    keys0: Any                      # (2,) uint32 mirror placeholder
    full: bool                      # whole prompt covered by the prefill


@dataclasses.dataclass
class PreemptedRecord:
    """Everything needed to resurrect a preempted slot: the carry row as
    it stood at eviction (sampling state, cur, ring buffer columns), the
    un-ingested prompt tail, and the pages it held with their alloc
    stamps.  On re-admission, pages whose stamp is unchanged still hold
    the victim's content (resurrect/retain); recycled ones are rebuilt
    by re-prefilling the already-fed token history over just those
    pages."""

    req: Any
    host_row: dict                  # carry-leaf name -> per-slot row (np)
    pending: Any                    # un-ingested prompt tail (np) or None
    pages: list                     # physical pages held at eviction
    stamps: list                    # pool alloc stamp per page at eviction
    cur: int                        # fed-token count at eviction
    keys0: Any                      # (2,) uint32 base PRNG key (mirror)


class TokenBacklog:
    """A FIFO of host-side work items drained by one daemon thread.

    Items are zero-argument callables (closures over device futures).
    The thread is started lazily on the first ``put`` so a sync engine
    never spawns it.  ``flush`` blocks until every queued item has run;
    ``close`` flushes, stops the thread, and joins it — both re-raise
    the first exception a work item threw.
    """

    def __init__(self, name: str = "token-backlog"):
        self._name = name
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._err: BaseException | None = None
        self._closed = False

    @property
    def started(self) -> bool:
        return self._thread is not None

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _ensure_thread(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._drain, name=self._name, daemon=True)
            self._thread.start()

    def _drain(self):
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                if self._err is None:
                    item()
            except BaseException as e:  # noqa: BLE001 — repo rt on main thread
                self._err = e
            finally:
                self._q.task_done()

    def _reraise(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError(
                f"{self._name} worker failed while draining") from err

    def put(self, item: Callable[[], None]):
        if self._closed:
            raise RuntimeError(f"{self._name} is closed")
        self._reraise()
        self._ensure_thread()
        self._q.put(item)

    def flush(self):
        """Block until every item queued so far has been processed."""
        if self._thread is not None:
            self._q.join()
        self._reraise()

    def close(self):
        """Flush, stop, and join the worker.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._q.join()
            self._q.put(_STOP)
            self._thread.join()
            self._thread = None
        self._reraise()


class AdmissionWorker:
    """Admission-prefill worker: one daemon thread turning queue-head
    requests into ``StagedWave``s of device futures.

    Division of labor (the thread-safety contract):

      * ``take(max_n)`` — engine-provided, pops requests off the
        scheduler queue under the engine's admission lock (the only
        scheduler surface the worker touches).
      * ``prepare(reqs) -> StagedWave`` — engine-provided, DEVICE
        dispatch only: wave prefill + first-token sample.  jax dispatch
        is thread-safe; nothing host-visible is mutated.
      * the main thread drains prepared waves via ``poll()`` at window
        boundaries and owns all scheduler/pool/mirror mutation.

    ``capacity`` bounds look-ahead: the worker stages at most that many
    requests beyond what the main thread has merged, so prefilled-but-
    unmerged cache trees can't grow without bound.  Errors are captured
    and re-raised on the main thread at the next ``poll``/``close``."""

    def __init__(self, take: Callable[[int], list],
                 prepare: Callable[[list], Any],
                 name: str = "admission-prefill"):
        self._take = take
        self._prepare = prepare
        self._name = name
        self._cv = threading.Condition()
        self._out: list = []
        self._err: BaseException | None = None
        self._capacity = 0
        self._busy = False
        self._stop = False
        self._thread: threading.Thread | None = None
        self.waves_prepared = 0
        self.prepare_seconds = 0.0     # worker-thread time (profiler)

    @property
    def started(self) -> bool:
        return self._thread is not None

    @property
    def busy(self) -> bool:
        """True while the worker holds un-polled output or is preparing."""
        with self._cv:
            return self._busy or bool(self._out)

    def _ensure_thread(self):
        if self._thread is None and not self._stop:
            self._thread = threading.Thread(
                target=self._run, name=self._name, daemon=True)
            self._thread.start()

    def kick(self, capacity: int):
        """Main thread: update the staging budget and wake the worker.
        Called at submit time and after each boundary merge."""
        with self._cv:
            self._capacity = max(0, capacity)
            if self._capacity > 0:
                self._ensure_thread()
            self._cv.notify_all()

    def _run(self):
        import time
        while True:
            with self._cv:
                while not self._stop and self._capacity <= 0:
                    self._cv.wait()
                if self._stop:
                    return
                cap = self._capacity
            try:
                reqs = self._take(cap)
                if not reqs:
                    with self._cv:
                        # nothing queued: sleep until the next kick
                        # (capacity will be re-announced then)
                        self._capacity = 0
                        self._busy = False
                        self._cv.notify_all()
                    continue
                with self._cv:
                    self._busy = True
                    self._capacity -= len(reqs)
                t0 = time.perf_counter()
                wave = self._prepare(reqs)
                dt = time.perf_counter() - t0
                with self._cv:
                    self._out.append(wave)
                    self.waves_prepared += 1
                    self.prepare_seconds += dt
                    self._busy = False
                    self._cv.notify_all()
            except BaseException as e:  # noqa: BLE001 — reraised on main
                with self._cv:
                    self._err = e
                    self._busy = False
                    self._stop = True
                    self._cv.notify_all()
                return

    def poll(self) -> list:
        """Main thread: drain every prepared wave; re-raises a worker
        crash (once) so a failed prefill fails the run, not hangs it."""
        with self._cv:
            out, self._out = self._out, []
            err, self._err = self._err, None
        if err is not None:
            raise RuntimeError(
                f"{self._name} worker failed while staging") from err
        return out

    def wait(self, timeout: float | None = None) -> bool:
        """Main thread: block until a prepared wave (or a crash) is
        available, or the timeout lapses.  Returns True when ``poll()``
        would yield something.  Blocks through the kicked-but-not-yet-
        scheduled gap too — the caller checks there is genuinely work
        upstream before waiting."""
        with self._cv:
            self._cv.wait_for(
                lambda: bool(self._out) or self._err is not None,
                timeout=timeout)
            return bool(self._out) or self._err is not None

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
