"""Admission policy and slot lifecycle for the serving engine.

The scheduler owns everything *about requests* that is not model math:
the FIFO queue, the slot -> request map, per-slot un-ingested prompt
remainders (chunked prefill), and admission-time validation.  The
executor (``engine.Engine``) asks it for admission waves and prompt
chunks and tells it when slots finish; it never touches device state.

Chunked prefill: a prompt longer than ``prefill_chunk`` is admitted in
pieces — the first ``prefill_chunk`` tokens go through the batched wave
prefill, the remainder is streamed through the decode loop's ingest
buffer chunk by chunk, so one long prompt never stalls the whole decode
batch behind a single huge prefill wave.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np

from repro.serving.policy import AdmissionPolicy, get_policy
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                    # (P,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    sampling: SamplingParams | None = None  # None -> engine default
    truncate: bool = False                # allow prompt truncation at submit
    truncated: bool = False               # set when truncation happened
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    # streaming callback: called once per emitted token as (req, token).
    # Under the overlapped engine this runs on the backlog worker thread.
    on_token: Callable | None = None
    # wall-clock stamps (perf_counter domain) for ttft accounting; the
    # scheduler stamps submission, the engine stamps the first emit.
    submitted_at: float | None = None
    first_token_at: float | None = None

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new_tokens:
            return True
        return bool(self.out_tokens) and self.out_tokens[-1] == self.eos_id


class Scheduler:
    """Policy-driven admission + slot lifecycle + chunked-prefill
    bookkeeping.  Admission *order* is delegated to an
    ``AdmissionPolicy`` (default ``fifo``, bit-identical to the old
    hardcoded head-of-line loop); slot *choice* stays shard-aware here."""

    def __init__(self, max_slots: int, max_len: int,
                 prefill_chunk: int | None = None, slot_shards: int = 1,
                 policy: str | AdmissionPolicy | None = None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if slot_shards < 1 or max_slots % slot_shards:
            raise ValueError(
                f"slot_shards={slot_shards} must divide max_slots="
                f"{max_slots} (each addressable shard owns whole slots)")
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        # When the engine's cache pool is slot-sharded over a mesh, slots
        # [k*max_slots/slot_shards, (k+1)*...) live on shard k.  Admission
        # packs a wave into as few shards as possible so the wave-prefill
        # scatter touches few shards' rows instead of gathering the pool.
        self.slot_shards = slot_shards
        self.policy = get_policy(policy)
        self.queue: deque[Request] = deque()
        self.slot_req: list[Request | None] = [None] * max_slots
        # preempted requests awaiting re-admission (lazy page reservation
        # evicted them mid-stream); they count as waiting work but are
        # re-placed by the engine's resurrection path, not the queue.
        self.parked: list[Request] = []
        # requests popped off the queue by the admission worker for
        # prefill STAGING: no slot yet, but no longer queued.  FIFO is
        # preserved end-to-end: take_staged pops the queue head, place*
        # consumes the staged head.
        self.staged: deque[Request] = deque()
        # un-ingested prompt tail per slot (chunked prefill)
        self._pending: list[np.ndarray | None] = [None] * max_slots
        self.admitted_uids: list[int] = []    # admission order (FIFO audit)

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> Request:
        """Validate and enqueue.  The ring holds ``max_len`` positions and
        generation needs at least one, so prompts are capped at
        ``max_len - 1``: longer ones raise, or are truncated to their
        *last* max_len - 1 tokens when ``req.truncate`` is set.
        ``max_new_tokens`` must be >= 1 — admission always emits the
        first sampled token, so a zero/negative budget would silently
        overshoot it."""
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request uid={req.uid}: max_new_tokens="
                f"{req.max_new_tokens} must be >= 1 (admission emits the "
                f"first generated token unconditionally)")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        cap = self.max_len - 1
        if prompt.shape[0] > cap:
            if not req.truncate:
                raise ValueError(
                    f"request uid={req.uid}: prompt length {prompt.shape[0]} "
                    f"exceeds the engine's max_len - 1 = {cap} (the ring "
                    f"needs one free position to generate); shorten the "
                    f"prompt, raise max_len, or set Request.truncate=True "
                    f"to keep the last {cap} tokens")
            prompt = prompt[-cap:]
            req.truncated = True
        if prompt.shape[0] == 0:
            raise ValueError(f"request uid={req.uid}: empty prompt")
        req.prompt = prompt
        if req.submitted_at is None:
            req.submitted_at = time.perf_counter()
        self.queue.append(req)
        return req

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _wave_slot_order(self, want: int) -> list[int]:
        """Free slots ordered shard-group-aware for a wave of ``want``
        requests: the tightest single group that fits the whole wave
        (best fit — emptier groups stay contiguous for bigger waves),
        else fullest-first so the wave spans the fewest groups."""
        free = self.free_slots()
        if self.slot_shards == 1 or not free:
            return free
        per = self.max_slots // self.slot_shards
        groups: dict[int, list[int]] = {}
        for s in free:
            groups.setdefault(s // per, []).append(s)
        by_size = sorted(groups.values(), key=lambda g: (len(g), g[0]))
        fit = next((g for g in by_size if len(g) >= want), None)
        if fit is not None:
            rest = [g for g in by_size if g is not fit]
            return fit + [s for g in rest for s in g]
        by_size.sort(key=lambda g: (-len(g), g[0]))
        return [s for g in by_size for s in g]

    def take_wave(self, fits=None) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots in the order the
        admission policy chooses (slot choice is shard-aware, see
        ``_wave_slot_order``; the default ``fifo`` policy reproduces the
        old head-of-line loop bit-identically).

        ``fits(req) -> bool``, when given, gates each admission on a
        resource check beyond free slots (the paged engine's page
        budget).  Under ``fifo`` the first request that does not fit
        ends the wave rather than being skipped — later smaller requests
        never starve an earlier large one; other policies document their
        own fairness contracts."""
        wave = []
        free = self._wave_slot_order(min(len(self.free_slots()),
                                         len(self.queue)))
        for req in self.policy.select(self.queue, len(free), fits):
            slot = free.pop(0)
            self.slot_req[slot] = req
            self.admitted_uids.append(req.uid)
            wave.append((slot, req))
        return wave

    def take_staged(self, max_n: int, fits=None) -> list[Request]:
        """Pop up to ``max_n`` queued requests (policy order) into the
        staged set (the admission worker's input).  Staged requests have
        been *committed to* in admission order — they are prefilled
        ahead of slot availability and must be placed via
        ``place``/``place_wave`` strictly in this order."""
        out = self.policy.select(self.queue, max_n, fits)
        self.staged.extend(out)
        return out

    def place(self, slot: int, req: Request):
        """Bind a previously staged request to a now-free slot.  Must be
        called in staged (admission) order — the ordering contract the
        synchronous ``take_wave`` enforces is preserved by construction.
        A *parked* (preempted) request may also be placed: it was already
        admitted once, so it re-binds outside the staged order."""
        if self.slot_req[slot] is not None:
            raise RuntimeError(
                f"slot {slot} is occupied by uid="
                f"{self.slot_req[slot].uid}; release it first")
        for i, p in enumerate(self.parked):
            if p is req:                  # identity, not __eq__ (arrays)
                self.parked.pop(i)
                self.slot_req[slot] = req
                self.admitted_uids.append(req.uid)
                return
        if not self.staged or self.staged[0] is not req:
            raise RuntimeError(
                f"place(uid={req.uid}) out of staged FIFO order "
                f"(head is uid={self.staged[0].uid if self.staged else None})")
        self.staged.popleft()
        self.slot_req[slot] = req
        self.admitted_uids.append(req.uid)

    def place_wave(self, reqs: list[Request]) -> list[tuple[int, Request]]:
        """Bind a FIFO run of staged requests to free slots, shard-aware
        like ``take_wave`` (the overlapped engine's boundary merge)."""
        free = self._wave_slot_order(len(reqs))
        placed = []
        for req in reqs:
            slot = free.pop(0)
            self.place(slot, req)
            placed.append((slot, req))
        return placed

    def first_chunk_len(self, req: Request) -> int:
        """Prompt tokens the admission wave prefill covers for ``req``."""
        if self.prefill_chunk is None:
            return len(req.prompt)
        return min(len(req.prompt), self.prefill_chunk)

    def set_pending(self, slot: int, rest: np.ndarray):
        self._pending[slot] = rest if rest.size else None

    def pending_len(self, slot: int) -> int:
        p = self._pending[slot]
        return 0 if p is None else int(p.shape[0])

    def next_chunk(self, slot: int) -> np.ndarray:
        """Pop the next <= prefill_chunk pending prompt tokens for a slot.
        Without a configured chunk the ingest buffer is one column wide
        (W = prefill_chunk or 1 in the engine), so chunks cap at 1 —
        pending tails only exist un-chunked on the prefill-skip and
        preemption-restore paths."""
        p = self._pending[slot]
        if p is None:
            return np.zeros((0,), np.int32)
        width = self.prefill_chunk or 1
        chunk, rest = p[:width], p[width:]
        self._pending[slot] = rest if rest.size else None
        return chunk

    # -- lifecycle / metrics -------------------------------------------------

    def release(self, slot: int):
        self.slot_req[slot] = None
        self._pending[slot] = None

    def preempt(self, slot: int) -> tuple[Request, np.ndarray]:
        """Evict a running slot's request into the parked set (lazy page
        reservation ran the pool dry and the policy picked this victim).
        Returns the request and its un-ingested prompt tail; the engine
        snapshots both into its resurrection record and re-binds via
        ``place`` when pages free up."""
        req = self.slot_req[slot]
        if req is None:
            raise RuntimeError(f"preempt({slot}): slot is empty")
        pending = self._pending[slot]
        self.slot_req[slot] = None
        self._pending[slot] = None
        self.parked.append(req)
        return req, (pending if pending is not None
                     else np.zeros((0,), np.int32))

    @property
    def queue_depth(self) -> int:
        """Requests waiting for a slot: still queued, staged (popped
        for prefill by the admission worker but not yet placed), or
        parked (preempted, awaiting re-admission)."""
        return len(self.queue) + len(self.staged) + len(self.parked)

    @property
    def occupancy(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self.staged) or bool(self.parked)
                or any(r is not None for r in self.slot_req))
