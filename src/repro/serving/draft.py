"""Draft proposers for speculative decoding on the serving engine.

Two draft families, selected by the engine's ``draft=`` spec string:

  "ngram"      prompt-lookup proposer: match the token about to be fed
               (and its predecessor) against the slot's own fed-token
               history and propose the tokens that followed the most
               recent earlier occurrence.  No parameters, no extra cache
               — pays off on repetitive continuations (code, extraction,
               self-repetition).
  "layers:K"   self-draft from the target's own first K layers (shared
               embed / final norm / lm_head, zero extra parameters): the
               truncated stack runs its own (cheap, K-layer) ring cache
               and proposes greedily.  The classic layer-skip draft.

Proposals are *guesses*: the target's verify step accepts a proposal only
when it equals the token the target's own sampler would have emitted
(per-slot key stream and all), so draft quality affects throughput, never
the token stream.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """Parsed ``draft=`` engine option."""

    kind: str                  # "ngram" | "layers"
    layers: int = 0            # draft depth for kind == "layers"

    @classmethod
    def parse(cls, spec: "str | DraftSpec | None") -> "DraftSpec | None":
        if spec is None or isinstance(spec, DraftSpec):
            return spec
        s = str(spec).strip().lower()
        if s in ("", "none"):
            return None
        if s == "ngram":
            return cls("ngram")
        for sep in (":", "="):
            if s.startswith("layers" + sep):
                try:
                    k = int(s.split(sep, 1)[1])
                except ValueError:
                    break
                return cls("layers", k)
        raise ValueError(
            f"draft spec {spec!r} not understood: expected 'ngram' or "
            f"'layers:K' (first K layers of the target as a self-draft)")


def ngram_propose(hist: jax.Array, cur: jax.Array, tok_in: jax.Array,
                  depth: int) -> jax.Array:
    """Prompt-lookup proposals.  hist: (B, L) fed-token history (position
    p holds the token fed at p; entries at p >= cur are stale).  cur: (B,)
    next feed position; tok_in: (B,) the token about to be fed at cur.

    Longest-available-suffix matching: look for the current 3-gram suffix
    (hist[cur-2], hist[cur-1], tok_in) in history; if it never occurred,
    fall back to the 2-gram (hist[cur-1], tok_in), then the unigram
    tok_in.  Each candidate match ends strictly before cur - 1, so the
    chosen occurrence always has at least one following history token to
    propose (a match flush against the tail would propose only stale
    positions — the failure mode that pinned accept_rate at 0.0 on
    perfectly periodic text, where the MOST RECENT bigram occurrence is
    always the one at the tail).  Unknown positions are filled with -1 —
    never equal to a sampled token, so verification just rejects them."""
    B, Lh = hist.shape

    def suffix(off):
        return jnp.take_along_axis(
            hist, jnp.clip(cur - off, 0, Lh - 1)[:, None], axis=1)[:, 0]

    t1, t2 = suffix(1), suffix(2)
    idx = jnp.arange(Lh, dtype=cur.dtype)
    # match position i: hist[i] == tok_in, with i + 1 < cur so the first
    # proposed token hist[i + 1] is real history, not a stale slot
    base = (hist == tok_in[:, None]) & (idx[None, :] + 1 < cur[:, None])
    z = jnp.zeros((B, 1), bool)
    p2 = jnp.concatenate([z, hist[:, :-1] == t1[:, None]], axis=1)
    p3 = jnp.concatenate([z, z, hist[:, :-2] == t2[:, None]], axis=1)

    def best(m):
        # most recent qualifying occurrence, -1 when none
        return jnp.max(jnp.where(m, idx[None, :], -1), axis=1)

    q3 = best(base & p2 & p3 & (cur[:, None] >= 2))
    q2 = best(base & p2 & (cur[:, None] >= 1))
    q1 = best(base)
    q = jnp.where(q3 >= 0, q3, jnp.where(q2 >= 0, q2, q1))
    offs = q[:, None] + 1 + jnp.arange(depth, dtype=cur.dtype)[None, :]
    known = (q[:, None] >= 0) & (offs < cur[:, None])
    prop = jnp.take_along_axis(hist, jnp.clip(offs, 0, Lh - 1), axis=1)
    return jnp.where(known, prop, jnp.int32(-1))


def make_layer_draft(cfg: ModelConfig, params,
                     k: int) -> tuple[ModelConfig, dict]:
    """Self-draft from the target's first ``k`` layers.

    Returns (draft_cfg, draft_params) where the params VIEW shares the
    target's leaves (embed, final norm, lm_head, the first k blocks) —
    no new weights.  ``expanded_layers`` of the truncated config is by
    construction the first k kinds of the target's, so per-layer state
    (e.g. Fisher-allocated ranks indexed by global layer position) lines
    up."""
    if not 1 <= k <= cfg.num_layers:
        raise ValueError(
            f"layers draft wants {k} layers; target has {cfg.num_layers}")
    kinds = cfg.expanded_layers()[:k]
    if any(kd in ("mamba", "rglru") for kd in kinds):
        raise ValueError("layers draft cannot include recurrent blocks")
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft{k}",
                               num_layers=k)
    dparams = {kk: params[kk] for kk in ("embed", "final_norm")}
    if "lm_head" in params:
        dparams["lm_head"] = params["lm_head"]
    if "encoder" in params:
        dparams["encoder"] = params["encoder"]
    npfx = len(cfg.prefix_pattern)
    if not cfg.scan_layers or cfg.num_periods == 0:
        dparams["prefix"] = tuple(params["prefix"][:k])
        dparams["blocks"], dparams["suffix"] = (), ()
        return dcfg, dparams
    dparams["prefix"] = tuple(params["prefix"][:min(k, npfx)])
    blocks, suffix = (), ()
    body = k - npfx
    if body > 0:
        m, rem = divmod(body, cfg.period)
        if m > 0:
            blocks = tuple(jax.tree.map(lambda a: a[:m], b)
                           for b in params["blocks"])
        if rem > 0:
            suffix = tuple(jax.tree.map(lambda a: a[m], b)
                           for b in params["blocks"][:rem])
    dparams["blocks"], dparams["suffix"] = blocks, suffix
    return dcfg, dparams
