"""Draft proposers for speculative decoding on the serving engine.

Two draft families, selected by the engine's ``draft=`` spec string:

  "ngram"      prompt-lookup proposer: match the token about to be fed
               (and its predecessor) against the slot's own fed-token
               history and propose the tokens that followed the most
               recent earlier occurrence.  No parameters, no extra cache
               — pays off on repetitive continuations (code, extraction,
               self-repetition).
  "layers:K"   self-draft from the target's own first K layers (shared
               embed / final norm / lm_head, zero extra parameters): the
               truncated stack runs its own (cheap, K-layer) ring cache
               and proposes greedily.  The classic layer-skip draft.

Proposals are *guesses*: the target's verify step accepts a proposal only
when it equals the token the target's own sampler would have emitted
(per-slot key stream and all), so draft quality affects throughput, never
the token stream.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """Parsed ``draft=`` engine option."""

    kind: str                  # "ngram" | "layers"
    layers: int = 0            # draft depth for kind == "layers"

    @classmethod
    def parse(cls, spec: "str | DraftSpec | None") -> "DraftSpec | None":
        if spec is None or isinstance(spec, DraftSpec):
            return spec
        s = str(spec).strip().lower()
        if s in ("", "none"):
            return None
        if s == "ngram":
            return cls("ngram")
        for sep in (":", "="):
            if s.startswith("layers" + sep):
                try:
                    k = int(s.split(sep, 1)[1])
                except ValueError:
                    break
                return cls("layers", k)
        raise ValueError(
            f"draft spec {spec!r} not understood: expected 'ngram' or "
            f"'layers:K' (first K layers of the target as a self-draft)")


def ngram_propose(hist: jax.Array, cur: jax.Array, tok_in: jax.Array,
                  depth: int) -> jax.Array:
    """Prompt-lookup proposals.  hist: (B, L) fed-token history (position
    p holds the token fed at p; entries at p >= cur are stale).  cur: (B,)
    next feed position; tok_in: (B,) the token about to be fed at cur.

    Matches the bigram (hist[cur-1], tok_in) against history and proposes
    the ``depth`` tokens that followed its most recent earlier occurrence.
    Unknown positions are filled with -1 — never equal to a sampled token,
    so they are simply rejected by verification."""
    B, Lh = hist.shape
    prev = jnp.take_along_axis(
        hist, jnp.clip(cur - 1, 0, Lh - 1)[:, None], axis=1)[:, 0]
    idx = jnp.arange(Lh - 1, dtype=cur.dtype)
    m = ((hist[:, :-1] == prev[:, None]) & (hist[:, 1:] == tok_in[:, None])
         & (idx[None, :] + 1 < cur[:, None]) & (cur[:, None] >= 2))
    p = jnp.max(jnp.where(m, idx[None, :], -1), axis=1)       # (B,) or -1
    offs = p[:, None] + 2 + jnp.arange(depth, dtype=cur.dtype)[None, :]
    known = (p[:, None] >= 0) & (offs < cur[:, None])
    prop = jnp.take_along_axis(hist, jnp.clip(offs, 0, Lh - 1), axis=1)
    return jnp.where(known, prop, jnp.int32(-1))


def make_layer_draft(cfg: ModelConfig, params,
                     k: int) -> tuple[ModelConfig, dict]:
    """Self-draft from the target's first ``k`` layers.

    Returns (draft_cfg, draft_params) where the params VIEW shares the
    target's leaves (embed, final norm, lm_head, the first k blocks) —
    no new weights.  ``expanded_layers`` of the truncated config is by
    construction the first k kinds of the target's, so per-layer state
    (e.g. Fisher-allocated ranks indexed by global layer position) lines
    up."""
    if not 1 <= k <= cfg.num_layers:
        raise ValueError(
            f"layers draft wants {k} layers; target has {cfg.num_layers}")
    kinds = cfg.expanded_layers()[:k]
    if any(kd in ("mamba", "rglru") for kd in kinds):
        raise ValueError("layers draft cannot include recurrent blocks")
    dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft{k}",
                               num_layers=k)
    dparams = {kk: params[kk] for kk in ("embed", "final_norm")}
    if "lm_head" in params:
        dparams["lm_head"] = params["lm_head"]
    if "encoder" in params:
        dparams["encoder"] = params["encoder"]
    npfx = len(cfg.prefix_pattern)
    if not cfg.scan_layers or cfg.num_periods == 0:
        dparams["prefix"] = tuple(params["prefix"][:k])
        dparams["blocks"], dparams["suffix"] = (), ()
        return dcfg, dparams
    dparams["prefix"] = tuple(params["prefix"][:min(k, npfx)])
    blocks, suffix = (), ()
    body = k - npfx
    if body > 0:
        m, rem = divmod(body, cfg.period)
        if m > 0:
            blocks = tuple(jax.tree.map(lambda a: a[:m], b)
                           for b in params["blocks"])
        if rem > 0:
            suffix = tuple(jax.tree.map(lambda a: a[m], b)
                           for b in params["blocks"][:rem])
    dparams["blocks"], dparams["suffix"] = blocks, suffix
    return dcfg, dparams
