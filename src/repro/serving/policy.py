"""Pluggable admission policies for the serving scheduler.

Admission used to be one hardcoded loop inside ``Scheduler.take_wave``:
strict head-of-line FIFO, one non-fitting request blocking everything
behind it.  Now that pages (not slots) are the scarce resource — the
paged latent pool is what ReCalKV's compression buys — the *order* work
enters the device is a real scheduling lever, so it lives here as a
policy object the scheduler consults:

  ``fifo``            today's behavior, bit-identical: requests admit in
                      submission order and the first one that does not
                      fit ends the wave.  The default everywhere.
  ``prefix-affinity`` group queued requests by their shared-prefix
                      registry key (the first-page ``prefix_key``) so
                      one wave prefills a recurring system prompt once
                      and every sharer retains/resurrects its pages via
                      the existing COW path.  Requires the paged layout.
                      Fairness: the queue head is always admitted first;
                      only requests sharing a key with an
                      already-selected request jump the line, and a
                      non-fitting head still ends the wave.
  ``reach-packing``   admit short requests past a blocked long one (an
                      explicit opt-out of strict FIFO).  Fairness bound:
                      a blocked request may be bypassed in at most
                      ``max_bypass`` selection rounds; after that it
                      becomes a hard barrier no later request passes, so
                      its worst-case extra wait is ``max_bypass``
                      admission rounds, never unbounded.

Policies also choose the *victim* under lazy page reservation: when the
pool exhausts mid-stream, ``pick_victim`` names the slot the engine
preempts back to the staging queue (see ``Engine`` / ``lazy_pages``).

``select`` mutates the queue it is given (popping what it admits) and
must respect ``fits`` — a stateful engine-provided closure that debits a
resource budget on success, so a policy must call it at most once per
selected request and only for requests it actually admits.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Callable

from repro.serving.pages import prefix_key

if TYPE_CHECKING:                      # scheduler imports us at runtime
    from repro.serving.scheduler import Request

__all__ = ["AdmissionPolicy", "FifoPolicy", "PrefixAffinityPolicy",
           "ReachPackingPolicy", "get_policy"]


class AdmissionPolicy:
    """Interface: admission order + preemption victim choice.

    ``configure`` is called once by the engine with the layout facts a
    policy may key on (page size, the prefix registry).  ``select`` pops
    up to ``limit`` requests off ``queue`` in admission order;
    ``pick_victim`` names a slot from ``candidates`` (admission order,
    oldest first) when the engine must preempt."""

    name = "base"
    #: set by policies that reorder admission using first-page prefix
    #: keys; the engine gates the prefill-skip fast path on it.
    groups_by_prefix = False

    def __init__(self):
        self.page_size: int | None = None
        self.registry = None

    def configure(self, *, page_size: int | None = None, registry=None):
        self.page_size = page_size
        self.registry = registry

    def select(self, queue: deque, limit: int,
               fits: Callable[[Request], bool] | None = None
               ) -> list[Request]:
        raise NotImplementedError

    def pick_victim(self, candidates: list[tuple[int, Request]]) -> int:
        """Default victim: the YOUNGEST admission (last in admission
        order) — it has the least sunk prefill/decode work to redo and
        the oldest requests keep their latency promise."""
        return candidates[-1][0]


class FifoPolicy(AdmissionPolicy):
    """Strict head-of-line FIFO — the pre-policy behavior, preserved
    bit-identically: pop the head while it fits; the first request that
    does not fit ends the wave (later smaller requests never starve an
    earlier large one)."""

    name = "fifo"

    def select(self, queue, limit, fits=None):
        out: list[Request] = []
        while queue and len(out) < limit:
            if fits is not None and not fits(queue[0]):
                break
            out.append(queue.popleft())
        return out


class PrefixAffinityPolicy(AdmissionPolicy):
    """FIFO with shared-prefix pull-forward: after each pick, queued
    requests whose first-page prefix key matches an already-selected
    request (or a prefix already resident in the registry) are pulled
    into the same wave, so the shared pages prefill once and every
    sharer retains them at its own admission.  The queue head is never
    bypassed — when no sharer is pending, selection IS FIFO."""

    name = "prefix-affinity"
    groups_by_prefix = True

    def _key(self, req: Request):
        ps = self.page_size
        if ps is None or len(req.prompt) < ps:
            return None
        return prefix_key(req.prompt, 0, ps)

    def select(self, queue, limit, fits=None):
        out: list[Request] = []
        keys: set = set()
        while queue and len(out) < limit:
            pick = 0
            if keys:
                for i, r in enumerate(queue):
                    k = self._key(r)
                    if k is not None and k in keys:
                        pick = i
                        break
            req = queue[pick]
            if fits is not None and not fits(req):
                # conservative: a non-fitting pick ends the wave whether
                # it was the head or a pulled-forward sharer — partial
                # groups admit, the remainder rides the next wave
                break
            del queue[pick]
            out.append(req)
            k = self._key(req)
            if k is not None:
                keys.add(k)
                if self.registry is not None:
                    # seed affinity from residency too: sharers of a
                    # prefix some RETIRED request left in the registry
                    # group even when the holder is long gone
                    keys.add(k)
        return out


class ReachPackingPolicy(AdmissionPolicy):
    """Opt-out of strict FIFO: a request that does not fit is bypassed
    and later, smaller requests may admit past it.

    Fairness bound (documented contract): each request counts the
    selection rounds in which it was passed over; once that count
    reaches ``max_bypass`` the request becomes a BARRIER — nothing
    behind it admits until it does.  A blocked request therefore waits
    at most ``max_bypass`` admission rounds longer than strict FIFO
    would have made it wait, never unboundedly."""

    name = "reach-packing"

    def __init__(self, max_bypass: int = 4):
        super().__init__()
        if max_bypass < 0:
            raise ValueError("max_bypass must be >= 0")
        self.max_bypass = max_bypass
        self._bypassed: dict[int, int] = {}      # uid -> rounds passed over

    def select(self, queue, limit, fits=None):
        out: list[Request] = []
        if fits is None:
            # no resource gate: nothing can block, selection is FIFO
            while queue and len(out) < limit:
                out.append(queue.popleft())
            return out
        skipped_this_round: list[int] = []
        i = 0
        while i < len(queue) and len(out) < limit:
            req = queue[i]
            if fits(req):
                del queue[i]
                out.append(req)
                self._bypassed.pop(req.uid, None)
                continue
            if self._bypassed.get(req.uid, 0) >= self.max_bypass:
                break                             # barrier: stop the scan
            skipped_this_round.append(req.uid)
            i += 1
        if out:
            # only rounds that admitted someone *past* a blocked request
            # count against the bound — an empty wave starves nobody
            for uid in skipped_this_round:
                self._bypassed[uid] = self._bypassed.get(uid, 0) + 1
        return out


_POLICIES = {
    "fifo": FifoPolicy,
    "prefix-affinity": PrefixAffinityPolicy,
    "reach-packing": ReachPackingPolicy,
}


def get_policy(policy: str | AdmissionPolicy | None) -> AdmissionPolicy:
    """Resolve a policy name (or pass through an instance).  ``None``
    means ``fifo``."""
    if policy is None:
        return FifoPolicy()
    if isinstance(policy, AdmissionPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown admission policy {policy!r}: expected one of "
            f"{sorted(_POLICIES)}") from None
