"""Serving subsystem: scheduler (admission) / sampler (token choice) /
engine (executor with the fused device-resident decode loop)."""

from repro.serving.engine import Engine
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "Request", "SamplingParams", "Scheduler"]
