from repro.serving.engine import Engine, Request

__all__ = ["Engine", "Request"]
