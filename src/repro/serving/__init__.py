"""Serving subsystem: scheduler (admission) / sampler (token choice) /
draft (speculative proposers) / engine (executor with the fused
device-resident decode loop)."""

from repro.serving.draft import DraftSpec
from repro.serving.engine import Engine
from repro.serving.policy import (AdmissionPolicy, FifoPolicy,
                                  PrefixAffinityPolicy, ReachPackingPolicy,
                                  get_policy)
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import Request, Scheduler

__all__ = ["AdmissionPolicy", "DraftSpec", "Engine", "FifoPolicy",
           "PrefixAffinityPolicy", "ReachPackingPolicy", "Request",
           "SamplingParams", "Scheduler", "get_policy"]
