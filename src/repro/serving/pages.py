"""Page-pool allocator for the paged latent KV cache.

The ring layout pins ``max_len`` positions per serving slot, so one
long-context request reserves worst-case memory — stranding exactly the
HBM that ReCalKV's compression saved.  The paged layout breaks every
block's ring into fixed-size pages held in one shared pool; a per-slot
page table (a ``(B, n_slot_pages)`` int32 carry leaf on device) maps
slot-page index -> physical page.  This module is the HOST side of that
subsystem: which physical pages exist, who holds references to them, and
which ones hold a registered (shareable) prompt prefix.  Device-side
reads/writes through the table live in ``models.kv_cache`` and
``kernels``; the engine glues the two at admission/retire.

Invariants the allocator maintains (property-tested in test_pages.py):

  * physical page 0 is the NULL page — never allocated, never written;
    unmapped page-table entries point at it and its ``pos`` stays -1, so
    a gathered view of an unmapped slot-page reads as empty ring.
  * every non-null page is on the free list (refcount 0), held by >= 1
    slots (refcount = number of holders), or PARKED (refcount 0 but
    pinned — resident and exempt from recycling); the three sets
    partition the pool, so pages never leak and never double-free.
  * a page with refcount >= 2 (a shared prompt prefix) is read-only by
    construction: the engine only shares pages wholly covered by the
    sharer's prefilled prompt region, and post-admission writes land at
    positions >= that region.  Divergence is resolved at admission time
    (the deterministic specialization of copy-on-write — a request's
    write range is known when it is admitted, so the first divergent
    page gets a private copy up front; see ``PagePool.fork``).

The prefix registry keys shareable pages by (slot-page index, hash of
the FULL token prefix through that page) — latent content at position t
depends causally on all tokens <= t, so two requests may share page j
only when their first (j+1)*page_size tokens are identical.  The
registry holds no references of its own, but its entries outlive their
holders: a page whose refcount hits zero keeps its key until the page
is actually *recycled* by ``alloc`` (the engine drops keys for freshly
allocated pages).  Free pages are reused in LRU order — least recently
freed first — so a recurring system prompt's pages survive in the free
list as long as pool pressure allows, and a registry hit on a
refcount-0 page can ``resurrect`` it instead of re-prefilling.
"""

from __future__ import annotations

import hashlib

import numpy as np

__all__ = ["PagePool", "PrefixRegistry", "prefix_key"]

NULL_PAGE = 0


class PagePool:
    """LRU free-list + per-page refcount allocator over ``n_pages``
    physical pages.  Page 0 is reserved as the null page.

    The free list is ordered by release time (least recently freed
    first); ``alloc`` recycles from the cold end while ``resurrect``
    can pull a still-registered page back out of the middle, which is
    what lets refcount-0 prefix pages keep serving cache hits."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(
                f"n_pages={n_pages}: the pool needs the reserved null page "
                f"plus at least one allocatable page")
        self.n_pages = n_pages
        self._ref = [0] * n_pages
        # dict-as-ordered-set: insertion order == LRU order, O(1) removal
        # from the middle when a free page is resurrected.
        self._free: dict[int, None] = dict.fromkeys(range(1, n_pages))
        # pinned pages are exempt from LRU recycling: at refcount 0 they
        # PARK (off the free list, still resident) instead of joining it,
        # so a cold-start flood can never evict a pinned prefix.
        self._pinned: set[int] = set()
        self.share_events = 0          # cumulative retain() calls
        self.cow_forks = 0             # cumulative divergent-page copies
        self.peak_used = 0             # high-water mark of allocated pages
        self.prefix_resurrections = 0  # refcount-0 pages revived by a hit
        # monotone allocation stamps: a page's stamp changes when alloc()
        # RECYCLES it (content destroyed) — not on resurrect/retain,
        # which preserve content — so a preempted slot can tell on
        # re-admission whether its old pages still hold its content
        # (stamp unchanged) or were overwritten meanwhile.
        self._alloc_seq = 0
        self._last_alloc = [0] * n_pages

    # -- introspection -------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        """Allocated (non-null) pages right now."""
        return self.n_pages - 1 - len(self._free)

    @property
    def shared_now(self) -> int:
        """Pages currently held by more than one slot."""
        return sum(1 for r in self._ref if r >= 2)

    @property
    def pinned(self) -> int:
        """Pages currently pinned against recycling."""
        return len(self._pinned)

    @property
    def parked(self) -> int:
        """Pinned pages at refcount 0: resident, off the free list, not
        held by any slot.  ``used`` counts them as allocated (they are
        not allocatable), so gauges that want live holders should read
        ``used - parked``."""
        return sum(1 for pg in self._pinned if self._ref[pg] == 0)

    def alloc_stamp(self, page: int) -> int:
        """Monotone stamp of the page's latest recycle by ``alloc``.
        Two reads returning the same stamp bracket a window in which the
        page's content was never destroyed (resurrect/retain preserve
        content and do not bump the stamp)."""
        return self._last_alloc[page]

    def assert_consistent(self):
        """Every non-null page is in exactly one of {free list,
        refcount>0, parked}; the three partition the pool.  Cheap enough
        to call from property tests after every operation."""
        held = sum(1 for pg in range(1, self.n_pages) if self._ref[pg] > 0)
        parked = self.parked
        assert not (self._pinned & set(self._free)), \
            f"pinned pages on the free list: {self._pinned & set(self._free)}"
        for pg in self._free:
            assert self._ref[pg] == 0, \
                f"free page {pg} has refcount {self._ref[pg]}"
        assert len(self._free) + held + parked == self.n_pages - 1, (
            f"pool partition broken: free={len(self._free)} held={held} "
            f"parked={parked} != {self.n_pages - 1} allocatable")

    def is_pinned(self, page: int) -> bool:
        return page in self._pinned

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def can_alloc(self, n: int) -> bool:
        return 0 <= n <= len(self._free)

    # -- lifecycle -----------------------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` fresh pages (refcount 1 each), recycling the least
        recently freed first.  A recycled page's old content/identity is
        dead — the caller must drop any registry key for it."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)} "
                f"free of {self.n_pages - 1} allocatable")
        pages = []
        for _ in range(n):
            pg = next(iter(self._free))
            del self._free[pg]
            self._ref[pg] = 1
            self._alloc_seq += 1
            self._last_alloc[pg] = self._alloc_seq
            pages.append(pg)
        self.peak_used = max(self.peak_used, self.used)
        return pages

    def resurrect(self, page: int) -> int:
        """Revive a refcount-0 page (a prefix registry hit on a retired
        prompt): its content is still resident because nothing recycled
        it yet, so the new holder skips the prefill entirely.  Works for
        pages on the free list AND for pinned pages parked off it."""
        if not 0 < page < self.n_pages:
            raise ValueError(
                f"page {page} out of range 1..{self.n_pages - 1}")
        if page in self._free:
            del self._free[page]
        elif not (page in self._pinned and self._ref[page] == 0):
            raise ValueError(
                f"page {page} is not free (refcount {self._ref[page]}); "
                f"use retain() to share a live page")
        self._ref[page] = 1
        self.prefix_resurrections += 1
        self.peak_used = max(self.peak_used, self.used)
        return page

    # -- pinning -------------------------------------------------------------

    def pin(self, page: int):
        """Exempt ``page`` from LRU recycling.  A pinned page at refcount
        0 parks off the free list (content resident, never handed out by
        ``alloc``) until ``unpin`` returns it.  Idempotent."""
        if not 0 < page < self.n_pages:
            raise ValueError(
                f"page {page} out of range 1..{self.n_pages - 1}")
        if page in self._pinned:
            return
        self._pinned.add(page)
        # already free: pull it off the list so alloc can't recycle it
        self._free.pop(page, None)

    def unpin(self, page: int):
        """Lift the recycling exemption; a parked page rejoins the WARM
        end of the free list (it was hot enough to pin).  Idempotent."""
        if page not in self._pinned:
            return
        self._pinned.discard(page)
        if self._ref[page] == 0:
            self._free[page] = None

    def retain(self, page: int) -> int:
        """Share an allocated page: one more holder, no copy."""
        self._check_live(page)
        self._ref[page] += 1
        self.share_events += 1
        return page

    def fork(self, page: int) -> int:
        """Copy-on-write fork: allocate a private replacement for ``page``
        and release this holder's reference to the original.  The caller
        owns filling the new page's content (device copy, or a prefill
        scatter when the content is being recomputed anyway)."""
        self._check_live(page)
        new = self.alloc(1)[0]
        self.cow_forks += 1
        self.free(page)
        return new

    def free(self, page: int) -> bool:
        """Drop one reference; returns True when the page's refcount hit
        zero and it joined the warm end of the free list (or parked, if
        pinned — a pinned page never rejoins the allocatable pool).
        Registry keys stay valid past this point — the page's content is
        resident until ``alloc`` recycles it."""
        self._check_live(page)
        self._ref[page] -= 1
        if self._ref[page] == 0:
            if page not in self._pinned:
                self._free[page] = None
            return True
        return False

    def _check_live(self, page: int):
        if not 0 < page < self.n_pages:
            raise ValueError(
                f"page {page} out of range 1..{self.n_pages - 1} "
                f"(page {NULL_PAGE} is the reserved null page)")
        if self._ref[page] <= 0:
            raise ValueError(f"page {page} is not allocated (double free?)")


def prefix_key(prompt: np.ndarray, page_idx: int, page_size: int):
    """Registry key for slot-page ``page_idx`` of a prompt: the page index
    plus a digest of the ENTIRE token prefix through that page (latent
    content at position t depends on all tokens <= t)."""
    end = (page_idx + 1) * page_size
    tokens = np.ascontiguousarray(np.asarray(prompt[:end], np.int32))
    return page_idx, hashlib.sha1(tokens.tobytes()).digest()


class PrefixRegistry:
    """prefix-hash -> resident physical page, for prompt sharing.

    Holds no references: the engine drops a page's entry when the page
    is recycled by ``alloc`` (NOT when its refcount hits zero — a free
    page's content stays resident, and a later lookup can resurrect it).
    One key per page (a page's content is fixed from registration until
    recycle), first registration wins."""

    def __init__(self):
        self._page_for: dict = {}
        self._key_for: dict[int, tuple] = {}

    def lookup(self, key) -> int | None:
        return self._page_for.get(key)

    def register(self, key, page: int):
        if key in self._page_for or page in self._key_for:
            return
        self._page_for[key] = page
        self._key_for[page] = key

    def pages(self):
        """View of the physical pages currently holding a registered
        prefix (the pin-ranking universe)."""
        return self._key_for.keys()

    def drop_page(self, page: int):
        key = self._key_for.pop(page, None)
        if key is not None:
            del self._page_for[key]

    def __len__(self) -> int:
        return len(self._page_for)
