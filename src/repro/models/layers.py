"""Layer primitives for the model zoo.

Pure functions over explicit parameter dicts (row-vector convention,
``y = x @ W``).  Everything is jit/scan/pjit-friendly: no Python state,
shapes static, f32 for softmax/norm/recurrent accumulators, model dtype
(bf16) for weights and matmul operands.

Attention comes in two data paths:
  * dense     — standard KV (the paper's uncompressed baseline)
  * latent    — ReCalKV: key latents reconstructed (grouped R_k) before
                RoPE; value latents consumed directly via the fused W~_o.
Cross-attention latents use *key absorption* (no RoPE on cross keys, so
``q' = q @ R_k^T`` folds reconstruction into the query — beyond-paper,
see DESIGN.md §2).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.config import ModelConfig

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for GPT-NeoX-style rotation.  positions: any shape."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., H, dh) with cos/sin (..., dh/2) broadcast over the H axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1
    ).astype(x.dtype)


def maybe_head_norm(x: jax.Array, scale: jax.Array | None, eps: float) -> jax.Array:
    """Per-head RMSNorm (qk-norm).  x: (..., H, dh), scale: (dh,)."""
    if scale is None:
        return x
    return rmsnorm(x, scale, eps)


# ---------------------------------------------------------------------------
# Masked softmax-attention core (query-chunked, O(chunk * S) memory)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def shard_hint(x: jax.Array, roles: tuple[str | None, ...]) -> jax.Array:
    """Best-effort sharding constraint by logical role.

    roles: per-dim "batch" / "seq" / None.  Resolves against the ambient
    mesh (try (pod, data) then data for batch; "model" for seq); outside
    any mesh the constraint raises and we no-op — tests and single-device
    runs are unaffected."""
    for batch_axes in (("pod", "data"), "data"):
        spec = tuple(
            batch_axes if r == "batch" else ("model" if r == "seq" else None)
            for r in roles)
        try:
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(*spec))
        except (RuntimeError, ValueError, KeyError, TypeError):
            continue
    return x


def _attend(
    q: jax.Array,            # (B, Tq, Hq, dh)
    k: jax.Array,            # (B, S, Hkv, dh)
    v: jax.Array,            # (B, S, Hkv, dv)
    mask: jax.Array | None,  # broadcastable to (B, Hq, Tq, S) or None
    scale: float,
) -> jax.Array:
    """Plain masked attention for one query chunk.  Returns (B, Tq, Hq, dv)."""
    B, Tq, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qr = q.reshape(B, Tq, Hkv, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k).astype(jnp.float32) * scale
    if mask is not None:
        m = jnp.broadcast_to(mask, (B, Hq, Tq, k.shape[1])).reshape(
            B, Hkv, g, Tq, k.shape[1]
        )
        logits = jnp.where(m, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(B, Tq, Hq, v.shape[-1])


def _attend_latent_v(
    q: jax.Array,            # (B, Tq, Hq, dh)
    k: jax.Array,            # (B, S, Hkv, dh)   (reconstructed keys)
    zv: jax.Array,           # (B, S, G, r_v)    value latents
    mask: jax.Array | None,
    scale: float,
    group_size: int,
) -> jax.Array:
    """Attention that keeps values in latent space: out (B, Tq, Hq, r_v)."""
    B, Tq, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    G = zv.shape[2]
    s = group_size
    qr = q.reshape(B, Tq, Hkv, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k).astype(jnp.float32) * scale
    if mask is not None:
        m = jnp.broadcast_to(mask, (B, Hq, Tq, S)).reshape(B, Hkv, g, Tq, S)
        logits = jnp.where(m, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(zv.dtype)
    # kv-head (G, s) reads value-group G: fold kv axis -> (G, s*g) query heads
    wg = w.reshape(B, G, s * g, Tq, S)
    o = jnp.einsum("bGhqs,bsGr->bqGhr", wg, zv)
    return o.reshape(B, Tq, Hq, zv.shape[-1])


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int | None) -> jax.Array:
    """(..., Tq, S) boolean mask from absolute positions (−1 = invalid slot)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = (kp >= 0) & (kp <= qp)
    if window is not None:
        m &= kp > qp - window
    return m


def chunked_attention(q, k, v, q_pos, k_pos, *, window, scale, chunk,
                      latent_v=False, group_size=1, causal=True):
    """Query-chunked attention; bounds live memory to (B, chunk, S) logits.

    q_pos: (B, Tq) absolute positions; k_pos: (B, S) (−1 marks empty slots).
    """
    B, T = q.shape[0], q.shape[1]
    attend = (
        partial(_attend_latent_v, group_size=group_size) if latent_v else _attend
    )

    def one(qc, qpc):
        if causal:
            m = causal_mask(qpc, k_pos, window)[:, None, :, :]
        else:
            m = (k_pos >= 0)[:, None, None, :]
        return attend(qc, k, v, m, scale)

    if T <= chunk:
        return one(q, q_pos)
    n = T // chunk
    if T % chunk:
        # Fall back to a single pass for ragged tails (rare: tests only).
        return one(q, q_pos)
    qs = q.reshape(B, n, chunk, *q.shape[2:]).swapaxes(0, 1)
    ps = q_pos.reshape(B, n, chunk).swapaxes(0, 1)
    out = jax.lax.map(lambda ab: one(*ab), (qs, ps))
    return out.swapaxes(0, 1).reshape(B, T, *out.shape[3:])


# ---------------------------------------------------------------------------
# Dense & latent self-attention (full-sequence: training / prefill)
# ---------------------------------------------------------------------------

def reconstruct_keys(zk: jax.Array, r_k: jax.Array, num_kv_heads: int,
                     d_head: int) -> jax.Array:
    """(B, S, G, r_k) x (G, r_k, s*dh) -> (B, S, Hkv, dh)."""
    B, S, _, _ = zk.shape
    k = jnp.einsum("bsgr,grn->bsgn", zk, r_k)              # (B, S, G, s*dh)
    return k.reshape(B, S, num_kv_heads, d_head)


def self_attention_dense(p: Params, x: jax.Array, cfg: ModelConfig,
                         positions: jax.Array, window: int | None,
                         theta: float | None = None, causal: bool = True,
                         use_kernel: bool = True):
    """Returns (y, (k_roped, v)) — the tuple feeds prefill cache writes.

    ``use_kernel=False`` forces the einsum path even under
    ``attn_backend="pallas"`` — the training forward needs it (the Pallas
    kernels carry no autodiff rule)."""
    B, T, _ = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    k = (x @ p["wk"]).reshape(B, T, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, T, Hkv, dh)
    q = maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    k = maybe_head_norm(k, p.get("k_norm"), cfg.norm_eps)
    cos, sin = rope_tables(positions, dh, theta or cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if cfg.attn_seq_shard:
        # Sequence-parallel keys (§Perf iteration 6): head counts that
        # don't divide the model axis would otherwise run attention fully
        # replicated; sharding the key/value sequence axis keeps scores,
        # softmax reductions, and AV contractions distributed.
        k = shard_hint(k, ("batch", "seq", None, None))
        v = shard_hint(v, ("batch", "seq", None, None))
    if use_kernel and cfg.attn_backend == "pallas":
        # Prefill positions are always 0..T-1, which is exactly the flash
        # kernel's block-position mask.
        o = kops.flash_prefill(q, k, v, causal=causal, window=window,
                               scale=dh ** -0.5, block=cfg.attn_block)
    else:
        o = chunked_attention(q, k, v, positions, positions, window=window,
                              scale=dh ** -0.5, chunk=cfg.attn_chunk,
                              causal=causal)
    return o.reshape(B, T, H * dh) @ p["wo"], (k, v)


def self_attention_latent(p: Params, x: jax.Array, cfg: ModelConfig,
                          positions: jax.Array, window: int | None,
                          theta: float | None = None,
                          use_kernel: bool = True):
    """Full-sequence ReCalKV attention.  Returns (y, (zk, zv)) — the latents
    are exactly what prefill writes into the ring cache (pre-RoPE).
    ``use_kernel`` as in :func:`self_attention_dense`."""
    B, T, _ = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    rt = cfg.recalkv
    s = max(1, min(rt.group_size, Hkv))
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    zk = jnp.einsum("btd,gdr->btgr", x, p["l_k"])        # (B, T, G, r_k)
    zv = jnp.einsum("btd,gdr->btgr", x, p["l_v"])        # (B, T, G, r_v)
    k = jnp.einsum("btgr,grn->btgn", zk, p["r_k"]).reshape(B, T, Hkv, dh)
    q = maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    k = maybe_head_norm(k, p.get("k_norm"), cfg.norm_eps)
    cos, sin = rope_tables(positions, dh, theta or cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if cfg.attn_seq_shard:
        k = shard_hint(k, ("batch", "seq", None, None))
        zv = shard_hint(zv, ("batch", "seq", None, None))
    if use_kernel and cfg.attn_backend == "pallas":
        # The flash kernel consumes latent values directly: one value
        # group per s kv heads (v head index = h // (s*g)), producing
        # (B, T, H, r_v) outputs for the fused W~_o — K is reconstructed
        # once here but never cached.
        o_lat = kops.flash_prefill(q, k, zv, causal=True, window=window,
                                   scale=dh ** -0.5, block=cfg.attn_block)
    else:
        o_lat = chunked_attention(q, k, zv, positions, positions,
                                  window=window, scale=dh ** -0.5,
                                  chunk=cfg.attn_chunk,
                                  latent_v=True, group_size=s)
    return jnp.einsum("bthr,hrd->btd", o_lat, p["wo_fused"]), (zk, zv)


# ---------------------------------------------------------------------------
# Cross-attention (VLM / enc-dec).  No RoPE on cross keys.
# ---------------------------------------------------------------------------

def cross_attention_dense(p: Params, x: jax.Array, source_kv: tuple[jax.Array, jax.Array],
                          cfg: ModelConfig) -> jax.Array:
    B, T, _ = x.shape
    H, dh = cfg.num_heads, cfg.d_head
    k, v = source_kv                                      # (B, S, Hkv, dh)
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    q = maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    o = _attend(q, k, v, None, dh ** -0.5)
    return o.reshape(B, T, H * dh) @ p["wo"]


def cross_attention_latent(p: Params, x: jax.Array,
                           source_latents: tuple[jax.Array, jax.Array],
                           cfg: ModelConfig) -> jax.Array:
    """Latent cross-attention with *key absorption*: scores = (q R_k^T) z_k^T.

    Because cross keys carry no positional rotation, reconstruction commutes
    with the score product and we never materialize K (beyond-paper).
    """
    B, T, _ = x.shape
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    zk, zv = source_latents                               # (B, S, G, r)
    G = zk.shape[2]
    s = Hkv // G
    g = H // Hkv
    rank_k, rank_v = zk.shape[-1], zv.shape[-1]
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    q = maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    # Absorb R_k into q:  q'_(h) = q_h @ R_k[g, :, slice(h)]^T  -> (B,T,H,r_k)
    r_k = p["r_k"].reshape(G, rank_k, s, dh)              # (G, r_k, s, dh)
    qg = q.reshape(B, T, G, s * g, dh).reshape(B, T, G, s, g, dh)
    q_abs = jnp.einsum("btGsgd,Grsd->btGsgr", qg, r_k)
    logits = jnp.einsum("btGsgr,bSGr->bGsgtS", q_abs, zk).astype(jnp.float32)
    w = jax.nn.softmax(logits * dh ** -0.5, axis=-1).astype(zv.dtype)
    o_lat = jnp.einsum("bGsgtS,bSGr->btGsgr", w, zv).reshape(B, T, H, rank_v)
    return jnp.einsum("bthr,hrd->btd", o_lat, p["wo_fused"])


def make_cross_source_dense(p: Params, source: jax.Array, cfg: ModelConfig):
    B, S, _ = source.shape
    k = (source @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.d_head)
    v = (source @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.d_head)
    k = maybe_head_norm(k, p.get("k_norm"), cfg.norm_eps)
    return k, v


def make_cross_source_latent(p: Params, source: jax.Array, cfg: ModelConfig):
    zk = jnp.einsum("bsd,gdr->bsgr", source, p["l_k"])
    zv = jnp.einsum("bsd,gdr->bsgr", source, p["l_v"])
    return zk, zv


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): trained-from-scratch latent KV — the paper's "built-in"
# alternative; implemented natively (DESIGN.md §Arch-applicability).
# ---------------------------------------------------------------------------

def mla_attention(p: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array):
    """Full-sequence MLA (training / prefill), non-absorbed form.
    Returns (y, (c_kv, k_rope_post_rope)) for the latent cache."""
    a = cfg.mla
    B, T, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = a.qk_nope_dim, a.qk_rope_dim, a.v_head_dim
    q_lat = rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, T, H, dn + dr)
    kv_a = x @ p["wkv_a"]                                  # (B,T,r_kv + dr)
    c_kv = rmsnorm(kv_a[..., : a.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., a.kv_lora_rank:].reshape(B, T, 1, dr)
    kv = (c_kv @ p["wkv_b"]).reshape(B, T, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_pe = apply_rope(q[..., dn:], cos, sin)
    k_pe = jnp.broadcast_to(apply_rope(k_rope, cos, sin), (B, T, H, dr))
    q_full = jnp.concatenate([q[..., :dn], q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe], axis=-1)
    o = chunked_attention(q_full, k_full, v, positions, positions, window=None,
                          scale=(dn + dr) ** -0.5, chunk=cfg.attn_chunk)
    k_pe_cache = apply_rope(k_rope, cos, sin)[:, :, 0, :]   # (B, T, dr) shared
    return o.reshape(B, T, H * dv) @ p["wo"], (c_kv, k_pe_cache)


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU & capacity-routed MoE
# ---------------------------------------------------------------------------

def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


def _expert_positions(sel: jax.Array, num_experts: int) -> jax.Array:
    """GShard-style position-in-expert.  sel: (N, k) -> pos (N, k) int32."""
    N, k = sel.shape
    counts = jnp.zeros((num_experts,), jnp.int32)
    pos = []
    for j in range(k):
        oh = jax.nn.one_hot(sel[:, j], num_experts, dtype=jnp.int32)  # (N, E)
        within = jnp.cumsum(oh, axis=0) - oh                          # prior same-expert
        pos.append(jnp.take_along_axis(
            within + counts[None, :], sel[:, j : j + 1], axis=1)[:, 0])
        counts = counts + oh.sum(axis=0)
    return jnp.stack(pos, axis=1)


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Capacity-dispatched top-k MoE.  Returns (out, aux_loss).

    Dispatch is index-based (gather into an (E, C, d) buffer, scatter-add
    back) — never materializes a (N, E, C) one-hot.  See DESIGN.md §3.
    """
    m = cfg.moe
    B, T, d = x.shape
    N = B * T
    E, k = m.num_experts, m.top_k
    cap = max(8, int(math.ceil(N * k / E * m.capacity_factor / 8.0)) * 8)

    xt = x.reshape(N, d)
    logits = (xt @ p["router"]).astype(jnp.float32)        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, k)                       # (N, k)
    w = w / (w.sum(axis=-1, keepdims=True) + 1e-9)

    # Aux losses: load-balance + router z-loss.
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce) + m.router_zloss * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2
    )

    pos = _expert_positions(sel, E)                        # (N, k)
    keep = pos < cap
    slot = jnp.where(keep, sel * cap + pos, E * cap)       # overflow -> sink

    # token id occupying each expert slot (sink row E*cap absorbs drops)
    tok_ids = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None], (N, k))
    token_for_slot = jnp.full((E * cap + 1,), 0, jnp.int32).at[
        slot.reshape(-1)].set(tok_ids.reshape(-1), mode="drop")
    filled = jnp.zeros((E * cap + 1,), jnp.bool_).at[
        slot.reshape(-1)].set(True, mode="drop")
    w_for_slot = jnp.zeros((E * cap + 1,), jnp.float32).at[
        slot.reshape(-1)].set(w.reshape(-1), mode="drop")

    buf = jnp.take(xt, token_for_slot[: E * cap], axis=0)  # (E*cap, d)
    buf = jnp.where(filled[: E * cap, None], buf, 0).reshape(E, cap, d)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * hi, p["wo"])
    y = y.reshape(E * cap, d)

    scale = (w_for_slot[: E * cap] * filled[: E * cap]).astype(y.dtype)
    out = jnp.zeros((N, d), y.dtype).at[token_for_slot[: E * cap]].add(
        y * scale[:, None]
    )
    if m.num_shared:
        out = out + swiglu(p["shared"], xt)
    return out.reshape(B, T, d), aux


def ffn(p: Params, x: jax.Array, cfg: ModelConfig, dense: bool) -> tuple[jax.Array, jax.Array]:
    if cfg.moe is not None and not dense:
        return moe_ffn(p, x, cfg)
    return swiglu(p, x), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba): chunked selective scan
# ---------------------------------------------------------------------------

def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x: (B, T, C), w: (K, C).  Returns (y, new_state)
    where state carries the last K-1 inputs for streaming decode."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, T+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return y + b[None, None, :], xp[:, -(K - 1):, :]


def _ssm_chunk_scan(decay: jax.Array, drive: jax.Array, h0: jax.Array):
    """Associative scan of h_t = decay_t * h_{t-1} + drive_t within a chunk.

    decay/drive: (B, Tc, d, n) f32;  h0: (B, d, n).  Returns (h_all, h_last).
    """
    def comb(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])
    # prepend h0 as a pseudo-step with decay 1
    a = jnp.concatenate([jnp.ones_like(decay[:, :1]), decay], axis=1)
    b = jnp.concatenate([h0[:, None], drive], axis=1)
    aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
    return bb[:, 1:], bb[:, -1]


def mamba_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Params | None = None, chunk: int = 128):
    """Mamba-1 block.  Returns (y, new_state).  state carries (h, conv)."""
    mc = cfg.mamba
    B, T, d = x.shape
    di, ds = cfg.mamba_d_inner, mc.d_state
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv1d(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dbc = xc @ p["x_proj"]
    dtr = cfg.mamba_dt_rank
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    Bmat = dbc[..., dtr : dtr + ds].astype(jnp.float32)    # (B,T,ds)
    Cmat = dbc[..., dtr + ds :].astype(jnp.float32)        # (B,T,ds)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (di, ds)

    h0 = (jnp.zeros((B, di, ds), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))

    def step_chunk(h, args):
        xc_c, dt_c, B_c, C_c = args                        # (B, Tc, ...)
        decay = jnp.exp(dt_c[..., None] * A[None, None])   # (B,Tc,di,ds)
        drive = (dt_c * xc_c.astype(jnp.float32))[..., None] * B_c[:, :, None, :]
        h_all, h_new = _ssm_chunk_scan(decay, drive, h)
        y_c = jnp.einsum("btdn,btn->btd", h_all, C_c)
        return h_new, y_c

    if T == 1:
        hT, y = step_chunk(h0, (xc, dt, Bmat, Cmat))
    elif T % chunk == 0 and T > chunk:
        n = T // chunk
        rs = lambda a: a.reshape(B, n, chunk, *a.shape[2:]).swapaxes(0, 1)
        hT, ys = jax.lax.scan(step_chunk, h0, (rs(xc), rs(dt), rs(Bmat), rs(Cmat)))
        y = ys.swapaxes(0, 1).reshape(B, T, di)
    else:
        hT, y = step_chunk(h0, (xc, dt, Bmat, Cmat))

    y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)[None, None, :]
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out.astype(x.dtype), {"h": hT, "conv": new_conv}


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Params | None = None):
    """Gated linear recurrent unit block.  Returns (y, new_state)."""
    B, T, d = x.shape
    W = cfg.lru_width
    main = x @ p["in_main"]                                # (B,T,W)
    gate = jax.nn.gelu(x @ p["in_gate"])
    conv_state = None if state is None else state["conv"]
    main, new_conv = _causal_conv1d(main, p["conv_w"], p["conv_b"], conv_state)

    rg = jax.nn.sigmoid(main @ p["w_a"]).astype(jnp.float32)   # recurrence gate
    ig = jax.nn.sigmoid(main @ p["w_x"]).astype(jnp.float32)   # input gate
    log_a = -_RGLRU_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)                                         # (B,T,W)
    gated = ig * main.astype(jnp.float32)
    drive = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * gated

    h0 = (jnp.zeros((B, W), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    if T == 1:
        h = a[:, 0] * h0 + drive[:, 0]
        hs = h[:, None]
        hT = h
    else:
        def comb(u, v):
            return (u[0] * v[0], u[1] * v[0] + v[1])
        a_ext = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        d_ext = jnp.concatenate([h0[:, None], drive], axis=1)
        _, hh = jax.lax.associative_scan(comb, (a_ext, d_ext), axis=1)
        hs, hT = hh[:, 1:], hh[:, -1]
    y = (hs.astype(x.dtype) * gate) @ p["out_proj"]
    return y, {"h": hT, "conv": new_conv}
