"""Model configuration — one declarative dataclass covering every assigned
architecture family (dense / GQA / SWA / qk-norm / local:global / cross-attn /
MLA / MoE / Mamba-1 / RG-LRU / enc-dec).

A model is a stack of *blocks*.  ``layer_pattern`` names the repeating block
kinds; ``prefix_pattern`` holds non-periodic leading layers (e.g. DeepSeek's
first-k-dense).  The transformer scans over full pattern periods (stacked
params, one lowering per period) and unrolls prefix + remainder — this keeps
HLO size O(period) for 94-layer models.

Block kinds:
  attn        global causal self-attention + FFN (MoE if cfg.moe, MLA if cfg.mla)
  attn_dense  like attn but always a dense FFN (DeepSeek first-k layers)
  local       sliding-window causal self-attention + FFN
  cross       cross-attention to encoder/frontend states + dense FFN (VLM style)
  attn_cross  self-attention + cross-attention + dense FFN (enc-dec decoder)
  mamba       Mamba-1 mixer (no separate FFN)
  rglru       RG-LRU recurrent block + FFN (Griffin / RecurrentGemma)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

BLOCK_KINDS = ("attn", "attn_dense", "local", "cross", "attn_cross", "mamba", "rglru")
ATTN_BACKENDS = ("einsum", "pallas")
ATTN_KINDS = ("attn", "attn_dense", "local", "cross", "attn_cross")
SELF_ATTN_KINDS = ("attn", "attn_dense", "local", "attn_cross")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0          # shared (always-on) experts, DeepSeek style
    first_k_dense: int = 0       # leading layers that keep a dense FFN
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ReCalKVRuntime:
    """Runtime shape info for a latent (compressed) KV cache.

    ``rank_k``/``rank_v`` are the uniform ranks (required for
    scan-over-layers).  When Fisher allocation varies ranks per layer
    (unrolled path), ``ranks_by_layer`` holds (rank_k, rank_v) indexed by
    global layer position ((0, 0) for attention-free layers).
    """

    rank_k: int
    rank_v: int
    group_size: int = 4
    ranks_by_layer: tuple[tuple[int, int], ...] | None = None

    def num_groups(self, num_kv_heads: int) -> int:
        s = max(1, min(self.group_size, num_kv_heads))
        return num_kv_heads // s

    def ranks_for(self, layer_idx: int | None) -> tuple[int, int]:
        if layer_idx is not None and self.ranks_by_layer is not None:
            rk, rv = self.ranks_by_layer[layer_idx]
            if rk:
                return rk, rv
        return self.rank_k, self.rank_v


_NESTED_CONFIGS = {"moe": MoEConfig, "mla": MLAConfig, "mamba": MambaConfig,
                   "rglru": RGLRUConfig}
_DTYPES_BY_NAME = {"bfloat16": jnp.bfloat16, "float16": jnp.float16,
                   "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    layer_pattern: tuple[str, ...] = ("attn",)
    prefix_pattern: tuple[str, ...] = ()
    sliding_window: int | None = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_global: float | None = None  # separate theta for global layers
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma-style sqrt(d_model) embedding scale
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    rglru: RGLRUConfig | None = None
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    cross_source_len: int = 0    # frontend token count (VLM patches / audio frames)
    recalkv: ReCalKVRuntime | None = None
    attn_seq_shard: bool = False  # sequence-parallel K/V (heads % TP != 0)
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 512        # query-chunked attention block (memory ceiling)
    attn_backend: str = "einsum"  # "einsum" reference | "pallas" kernels
    attn_block: int = 256        # pallas kernel tile size (key/query axis)
    cache_quant_bits: int | None = None  # int8-latent self-attn ring cache
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        for k in self.layer_pattern + self.prefix_pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.attn_backend not in ATTN_BACKENDS:
            raise ValueError(
                f"attn_backend must be one of {ATTN_BACKENDS}, "
                f"got {self.attn_backend!r}")
        if self.cache_quant_bits is not None:
            if self.recalkv is None:
                raise ValueError("cache_quant_bits requires a recalkv "
                                 "(latent) cache")
            if self.cache_quant_bits not in (3, 4, 8):
                raise ValueError("cache_quant_bits must be 3, 4 or 8")
        n_body = self.num_layers - len(self.prefix_pattern)
        if n_body < 0:
            raise ValueError("prefix longer than the model")

    # ---- layer layout -----------------------------------------------------

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        return (self.num_layers - len(self.prefix_pattern)) // self.period

    @property
    def suffix_pattern(self) -> tuple[str, ...]:
        rem = (self.num_layers - len(self.prefix_pattern)) % self.period
        return self.layer_pattern[:rem]

    def expanded_layers(self) -> tuple[str, ...]:
        """Per-layer block kinds for the whole stack, in order."""
        return (
            self.prefix_pattern
            + self.layer_pattern * self.num_periods
            + self.suffix_pattern
        )

    # ---- derived dims -----------------------------------------------------

    @property
    def q_dim(self) -> int:
        if self.mla is not None:
            return self.num_heads * (self.mla.qk_nope_dim + self.mla.qk_rope_dim)
        return self.num_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.d_head

    @property
    def mamba_d_inner(self) -> int:
        assert self.mamba is not None
        return self.mamba.expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        assert self.mamba is not None
        return self.mamba.dt_rank or -(-self.d_model // 16)

    @property
    def lru_width(self) -> int:
        assert self.rglru is not None
        return self.rglru.lru_width or self.d_model

    def window_for(self, kind: str) -> int | None:
        """Effective attention window for a block kind (None = unbounded)."""
        if kind == "local":
            if self.sliding_window is None:
                raise ValueError("'local' blocks need cfg.sliding_window")
            return self.sliding_window
        if kind in ("attn", "attn_dense", "attn_cross"):
            # A model whose *global* blocks also slide (h2o-danube) sets
            # sliding_window and uses kind="local" throughout instead.
            return None
        return None

    def cache_len(self, kind: str, seq_len: int) -> int:
        """KV-cache length for one block at a given max sequence length."""
        w = self.window_for(kind)
        return seq_len if w is None else min(w, seq_len)

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------------

    def param_count(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        return sum(self._block_params(k) for k in self.expanded_layers()) + self._extras()

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        return sum(
            self._block_params(k, active_only=True) for k in self.expanded_layers()
        ) + self._extras()

    def _extras(self) -> int:
        d, v = self.d_model, self.vocab_size
        n = v * d + d                      # embed + final norm
        if not self.tie_embeddings:
            n += d * v
        if self.encoder_decoder:
            n += self.num_encoder_layers * self._block_params("attn_dense_enc")
        return n

    def _ffn_params(self, active_only: bool) -> int:
        d = self.d_model
        if self.moe is None:
            return 3 * d * self.d_ff
        m = self.moe
        experts = m.top_k if active_only else m.num_experts
        return (
            3 * d * m.d_expert * (experts + m.num_shared)
            + d * m.num_experts  # router
        )

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            a = self.mla
            return (
                d * a.q_lora_rank
                + a.q_lora_rank * self.num_heads * (a.qk_nope_dim + a.qk_rope_dim)
                + d * (a.kv_lora_rank + a.qk_rope_dim)
                + a.kv_lora_rank * self.num_heads * (a.qk_nope_dim + a.v_head_dim)
                + self.num_heads * a.v_head_dim * d
            )
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    # ---- serialization (compression artifacts) -----------------------------

    def to_dict(self) -> dict:
        """JSON-serializable form (dtype by name; tuples become lists on
        dump and are restored by :meth:`from_dict`)."""
        d = dataclasses.asdict(self)
        d["dtype"] = jnp.dtype(self.dtype).name
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ModelConfig":
        d = dict(d)
        d["dtype"] = _DTYPES_BY_NAME.get(d["dtype"]) or jnp.dtype(d["dtype"])
        for key, sub in _NESTED_CONFIGS.items():
            if d.get(key) is not None:
                d[key] = sub(**d[key])
        if d.get("recalkv") is not None:
            rt = dict(d["recalkv"])
            if rt.get("ranks_by_layer") is not None:
                rt["ranks_by_layer"] = tuple(
                    (int(rk), int(rv)) for rk, rv in rt["ranks_by_layer"])
            d["recalkv"] = ReCalKVRuntime(**rt)
        for key in ("layer_pattern", "prefix_pattern"):
            d[key] = tuple(d[key])
        return cls(**d)

    def _block_params(self, kind: str, active_only: bool = False) -> int:
        d = self.d_model
        if kind == "mamba":
            di, ds = self.mamba_d_inner, self.mamba.d_state
            dtr = self.mamba_dt_rank
            return (
                d * 2 * di + self.mamba.d_conv * di + di
                + di * (dtr + 2 * ds) + dtr * di + di * ds + di + di * d + d
            )
        if kind == "rglru":
            w = self.lru_width
            ffn = 3 * d * self.d_ff
            return 2 * d * w + self.rglru.conv_width * w + 2 * w * w + w * d + ffn + 2 * d
        if kind == "attn_dense_enc":
            return self._attn_params() + 3 * d * self.d_ff + 2 * d
        ffn = (
            3 * d * self.d_ff
            if kind in ("attn_dense", "cross", "attn_cross")
            else self._ffn_params(active_only)
        )
        attn = self._attn_params()
        if kind == "attn_cross":
            attn *= 2  # self + cross attention
        return attn + ffn + 2 * d
