"""Transformer stack assembly: init / train-forward / prefill / decode.

Layer layout (cfg.scan_layers=True):
    params = {
      "embed": (V, d), ["lm_head": (d, V)], "final_norm": (d,),
      "prefix": tuple(block-dicts),             # unrolled leading layers
      "blocks": tuple over period positions,    # leaves stacked (n_periods, ...)
      "suffix": tuple(block-dicts),             # unrolled remainder
      ["encoder"]: {"blocks": tuple(block-dicts), "final_norm": (d,)},
    }
The scan body lowers each pattern period once — HLO stays O(period) even for
94-layer models, which is what makes the 40-cell dry-run tractable.

With cfg.scan_layers=False every layer sits in "prefix" (heterogeneous
per-layer ranks from Fisher allocation become possible; used by the
small-scale quality benchmarks).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import kv_cache as KC
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale, dtype, stack=None):
    if stack is not None:
        shape = (stack,) + tuple(shape)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _zeros(shape, dtype, stack=None):
    if stack is not None:
        shape = (stack,) + tuple(shape)
    return jnp.zeros(shape, dtype)


def init_attn_params(cfg: ModelConfig, key, *, cross: bool = False,
                     stack=None) -> Params:
    d, dt = cfg.d_model, cfg.dtype
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    if cfg.mla is not None and not cross:
        a = cfg.mla
        H = cfg.num_heads
        return {
            "wq_a": _dense_init(ks[0], (d, a.q_lora_rank), sc, dt, stack),
            "q_a_norm": _zeros((a.q_lora_rank,), jnp.float32, stack),
            "wq_b": _dense_init(ks[1], (a.q_lora_rank, H * (a.qk_nope_dim + a.qk_rope_dim)),
                                a.q_lora_rank ** -0.5, dt, stack),
            "wkv_a": _dense_init(ks[2], (d, a.kv_lora_rank + a.qk_rope_dim), sc, dt, stack),
            "kv_a_norm": _zeros((a.kv_lora_rank,), jnp.float32, stack),
            "wkv_b": _dense_init(ks[3], (a.kv_lora_rank, H * (a.qk_nope_dim + a.v_head_dim)),
                                 a.kv_lora_rank ** -0.5, dt, stack),
            "wo": _dense_init(ks[4], (H * a.v_head_dim, d),
                              (H * a.v_head_dim) ** -0.5, dt, stack),
        }
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    p: Params = {"wq": _dense_init(ks[0], (d, H * dh), sc, dt, stack)}
    if cfg.recalkv is not None:
        rt = cfg.recalkv
        s = max(1, min(rt.group_size, Hkv))
        G = Hkv // s
        p |= {
            "l_k": _dense_init(ks[1], (G, d, rt.rank_k), sc, dt, stack),
            "r_k": _dense_init(ks[2], (G, rt.rank_k, s * dh), rt.rank_k ** -0.5, dt, stack),
            "l_v": _dense_init(ks[3], (G, d, rt.rank_v), sc, dt, stack),
            "wo_fused": _dense_init(ks[4], (H, rt.rank_v, d), (H * rt.rank_v) ** -0.5,
                                    dt, stack),
        }
    else:
        p |= {
            "wk": _dense_init(ks[1], (d, Hkv * dh), sc, dt, stack),
            "wv": _dense_init(ks[2], (d, Hkv * dh), sc, dt, stack),
            "wo": _dense_init(ks[4], (H * dh, d), (H * dh) ** -0.5, dt, stack),
        }
    if cfg.qk_norm:
        p["q_norm"] = _zeros((dh,), jnp.float32, stack)
        p["k_norm"] = _zeros((dh,), jnp.float32, stack)
    return p


def init_ffn_params(cfg: ModelConfig, key, *, dense: bool, stack=None) -> Params:
    d, dt = cfg.d_model, cfg.dtype
    ks = jax.random.split(key, 7)
    if cfg.moe is None or dense:
        f = cfg.d_ff
        return {
            "wi": _dense_init(ks[0], (d, f), d ** -0.5, dt, stack),
            "wg": _dense_init(ks[1], (d, f), d ** -0.5, dt, stack),
            "wo": _dense_init(ks[2], (f, d), f ** -0.5, dt, stack),
        }
    m = cfg.moe
    E, f = m.num_experts, m.d_expert
    p = {
        "router": _dense_init(ks[0], (d, E), d ** -0.5, jnp.float32, stack),
        "wi": _dense_init(ks[1], (E, d, f), d ** -0.5, dt, stack),
        "wg": _dense_init(ks[2], (E, d, f), d ** -0.5, dt, stack),
        "wo": _dense_init(ks[3], (E, f, d), f ** -0.5, dt, stack),
    }
    if m.num_shared:
        fs = f * m.num_shared
        p["shared"] = {
            "wi": _dense_init(ks[4], (d, fs), d ** -0.5, dt, stack),
            "wg": _dense_init(ks[5], (d, fs), d ** -0.5, dt, stack),
            "wo": _dense_init(ks[6], (fs, d), fs ** -0.5, dt, stack),
        }
    return p


def init_mamba_params(cfg: ModelConfig, key, stack=None) -> Params:
    d, dt = cfg.d_model, cfg.dtype
    mc = cfg.mamba
    di, ds, dtr = cfg.mamba_d_inner, mc.d_state, cfg.mamba_dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    p = {
        "in_proj": _dense_init(ks[0], (d, 2 * di), d ** -0.5, dt, stack),
        "conv_w": _dense_init(ks[1], (mc.d_conv, di), mc.d_conv ** -0.5, dt, stack),
        "conv_b": _zeros((di,), dt, stack),
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * ds), di ** -0.5, dt, stack),
        "dt_proj": _dense_init(ks[3], (dtr, di), dtr ** -0.5, dt, stack),
        "dt_bias": _zeros((di,), jnp.float32, stack) - 4.0,
        "A_log": (jnp.log(A) if stack is None
                  else jnp.broadcast_to(jnp.log(A), (stack, di, ds))),
        "D": _zeros((di,), jnp.float32, stack) + 1.0,
        "out_proj": _dense_init(ks[4], (di, d), di ** -0.5, dt, stack),
    }
    return p


def init_rglru_params(cfg: ModelConfig, key, stack=None) -> Params:
    d, dt = cfg.d_model, cfg.dtype
    W = cfg.lru_width
    K = cfg.rglru.conv_width
    ks = jax.random.split(key, 6)
    return {
        "in_main": _dense_init(ks[0], (d, W), d ** -0.5, dt, stack),
        "in_gate": _dense_init(ks[1], (d, W), d ** -0.5, dt, stack),
        "conv_w": _dense_init(ks[2], (K, W), K ** -0.5, dt, stack),
        "conv_b": _zeros((W,), dt, stack),
        "w_a": _dense_init(ks[3], (W, W), W ** -0.5, dt, stack),
        "w_x": _dense_init(ks[4], (W, W), W ** -0.5, dt, stack),
        "a_param": _zeros((W,), jnp.float32, stack) + 0.65,
        "out_proj": _dense_init(ks[5], (W, d), W ** -0.5, dt, stack),
    }


def init_block_params(cfg: ModelConfig, kind: str, key, stack=None) -> Params:
    ks = jax.random.split(key, 4)
    norm = lambda: _zeros((cfg.d_model,), jnp.float32, stack)
    if kind == "mamba":
        return {"ln": norm(), "mixer": init_mamba_params(cfg, ks[0], stack)}
    if kind == "rglru":
        return {"ln1": norm(), "mixer": init_rglru_params(cfg, ks[0], stack),
                "ln2": norm(), "mlp": init_ffn_params(cfg, ks[1], dense=True, stack=stack)}
    if kind == "cross":
        return {"ln1": norm(), "cross": init_attn_params(cfg, ks[0], cross=True, stack=stack),
                "ln2": norm(), "mlp": init_ffn_params(cfg, ks[1], dense=True, stack=stack)}
    if kind == "attn_cross":
        return {"ln1": norm(), "attn": init_attn_params(cfg, ks[0], stack=stack),
                "lnx": norm(), "cross": init_attn_params(cfg, ks[1], cross=True, stack=stack),
                "ln2": norm(), "mlp": init_ffn_params(cfg, ks[2], dense=True, stack=stack)}
    dense = kind == "attn_dense"
    return {"ln1": norm(), "attn": init_attn_params(cfg, ks[0], stack=stack),
            "ln2": norm(), "mlp": init_ffn_params(cfg, ks[1], dense=dense, stack=stack)}


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": _dense_init(ks[0], (V, d), 0.02, cfg.dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[1], (d, V), d ** -0.5, cfg.dtype)

    if cfg.scan_layers:
        prefix, pattern, suffix = (cfg.prefix_pattern, cfg.layer_pattern,
                                   cfg.suffix_pattern)
        n_per = cfg.num_periods
    else:
        prefix, pattern, suffix, n_per = cfg.expanded_layers(), (), (), 0

    params["prefix"] = tuple(
        init_block_params(cfg, k, jax.random.fold_in(ks[2], i))
        for i, k in enumerate(prefix)
    )
    params["blocks"] = tuple(
        init_block_params(cfg, k, jax.random.fold_in(ks[3], i), stack=n_per)
        for i, k in enumerate(pattern)
    ) if n_per > 0 else ()
    params["suffix"] = tuple(
        init_block_params(cfg, k, jax.random.fold_in(ks[4], i))
        for i, k in enumerate(suffix)
    )
    if cfg.encoder_decoder:
        params["encoder"] = {
            "blocks": tuple(
                init_block_params(cfg, "attn_dense", jax.random.fold_in(ks[5], i))
                for i in range(cfg.num_encoder_layers)
            ),
            "final_norm": jnp.zeros((d,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# Block application — full sequence (train / prefill)
# ---------------------------------------------------------------------------

def _theta(cfg: ModelConfig, kind: str) -> float:
    if kind in ("attn", "attn_dense") and getattr(cfg, "rope_theta_global", None):
        return cfg.rope_theta_global
    return cfg.rope_theta


def block_full(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
               ctx: dict, want_cache: bool):
    """One block over a full (B, T, d) sequence.  Returns (x, cache, aux)."""
    aux = jnp.float32(0.0)
    cache = None
    pos = ctx["positions"]
    causal = ctx.get("causal", True)
    if kind in ("mamba", "rglru"):
        mixer = L.mamba_mixer if kind == "mamba" else L.rglru_mixer
        ln = p["ln"] if kind == "mamba" else p["ln1"]
        y, state = mixer(p["mixer"], L.rmsnorm(x, ln, cfg.norm_eps), cfg)
        x = x + y
        if kind == "rglru":
            h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, dense=True)
            x, aux = x + h, aux + a
        cache = state if want_cache else None
        return x, cache, aux

    if kind == "cross":
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if cfg.recalkv is not None:
            src = L.make_cross_source_latent(p["cross"], ctx["source"], cfg)
            y = L.cross_attention_latent(p["cross"], h, src, cfg)
            cache = {"cross": {"zk": src[0], "zv": src[1]}} if want_cache else None
        else:
            src = L.make_cross_source_dense(p["cross"], ctx["source"], cfg)
            y = L.cross_attention_dense(p["cross"], h, src, cfg)
            cache = {"cross": {"k": src[0], "v": src[1]}} if want_cache else None
        x = x + y
        h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, dense=True)
        return x + h, cache, aux + a

    # self-attention kinds.  The pallas backend applies to inference
    # passes only (want_cache=True, i.e. prefill): the kernels have no
    # autodiff rule, so the training forward keeps the einsum path.
    window = cfg.window_for(kind)
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    self_cache = None
    if cfg.mla is not None:
        y, kv = L.mla_attention(p["attn"], h, cfg, pos)
        if want_cache:
            self_cache = _prefill_self_cache(cfg, kind, ctx, {"ckv": kv[0], "krope": kv[1]})
    elif cfg.recalkv is not None:
        y, kv = L.self_attention_latent(p["attn"], h, cfg, pos, window,
                                        theta=_theta(cfg, kind),
                                        use_kernel=want_cache)
        if want_cache:
            self_cache = _prefill_self_cache(
                cfg, kind, ctx, KC.latent_cache_entry(cfg, kv[0], kv[1]))
    else:
        y, kv = L.self_attention_dense(p["attn"], h, cfg, pos, window,
                                       theta=_theta(cfg, kind), causal=causal,
                                       use_kernel=want_cache)
        if want_cache:
            self_cache = _prefill_self_cache(cfg, kind, ctx, {"k": kv[0], "v": kv[1]})
    x = x + y

    if kind == "attn_cross":
        hx = L.rmsnorm(x, p["lnx"], cfg.norm_eps)
        if cfg.recalkv is not None:
            src = L.make_cross_source_latent(p["cross"], ctx["source"], cfg)
            y = L.cross_attention_latent(p["cross"], hx, src, cfg)
            cross_cache = {"zk": src[0], "zv": src[1]}
        else:
            src = L.make_cross_source_dense(p["cross"], ctx["source"], cfg)
            y = L.cross_attention_dense(p["cross"], hx, src, cfg)
            cross_cache = {"k": src[0], "v": src[1]}
        x = x + y

    h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                 dense=(kind in ("attn_dense", "attn_cross")))
    x = x + h
    aux = aux + a
    if want_cache:
        cache = {"self": self_cache}
        if kind == "attn_cross":
            cache["cross"] = cross_cache
    return x, cache, aux


def _prefill_self_cache(cfg: ModelConfig, kind: str, ctx: dict,
                        values: Params) -> Params:
    """Scatter full-sequence K/V (or latents) into a fresh ring cache.

    Shapes come from the values themselves, so per-layer (Fisher-allocated)
    ranks need no config plumbing."""
    B, T = ctx["positions"].shape
    Lr = cfg.cache_len(kind, ctx["max_len"])
    out = {}
    for name, val in values.items():
        empty = jnp.zeros((B, Lr) + val.shape[2:], val.dtype)
        out[name] = KC.write_prefill(empty, val, ctx["lengths"])
    out["pos"] = KC.prefill_pos(ctx["lengths"], T, Lr)
    return out


# ---------------------------------------------------------------------------
# Block application — single decode step
# ---------------------------------------------------------------------------

def block_decode(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                 cache: Params, ctx: dict):
    """One block for a (B, 1, d) decode step.  Returns (x, updates, aux).

    ``updates`` are DEFERRED cache writes (slot entries / state
    replacements / None) merged once after the layer scan by
    kv_cache.apply_decode_writes — carrying full updated caches through
    the scan ys forced per-iteration rematerialization of the whole ring
    (EXPERIMENTS.md §Perf iteration 3)."""
    aux = jnp.float32(0.0)
    cur = ctx["cur"]
    if kind in ("mamba", "rglru"):
        mixer = L.mamba_mixer if kind == "mamba" else L.rglru_mixer
        ln = p["ln"] if kind == "mamba" else p["ln1"]
        y, state = mixer(p["mixer"], L.rmsnorm(x, ln, cfg.norm_eps), cfg, state=cache)
        x = x + y
        if kind == "rglru":
            h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, dense=True)
            x, aux = x + h, aux + a
        return x, state, aux

    if kind == "cross":
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        reader = (KC.decode_cross_latent if cfg.recalkv is not None
                  else KC.decode_cross_dense)
        y, _ = reader(p["cross"], h, cache["cross"], cfg)
        x = x + y
        h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, dense=True)
        return x + h, {"cross": None}, aux + a

    window = cfg.window_for(kind)
    pages = ctx.get("pages")
    mesh = ctx.get("mesh")
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        y, sc = KC.decode_attn_mla(p["attn"], h, cache["self"], cfg, cur,
                                   pages=pages)
    elif cfg.recalkv is not None:
        y, sc = KC.decode_attn_latent(p["attn"], h, cache["self"], cfg, cur, window,
                                      theta=_theta(cfg, kind), pages=pages,
                                      mesh=mesh)
    else:
        y, sc = KC.decode_attn_dense(p["attn"], h, cache["self"], cfg, cur, window,
                                     theta=_theta(cfg, kind), pages=pages,
                                     mesh=mesh)
    x = x + y
    updates = {"self": sc}

    if kind == "attn_cross":
        hx = L.rmsnorm(x, p["lnx"], cfg.norm_eps)
        reader = (KC.decode_cross_latent if cfg.recalkv is not None
                  else KC.decode_cross_dense)
        y, _ = reader(p["cross"], hx, cache["cross"], cfg)
        x = x + y
        updates["cross"] = None

    h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                 dense=(kind in ("attn_dense", "attn_cross")))
    return x + h, updates, aux + a


# ---------------------------------------------------------------------------
# Block application — multi-token verify step (speculative decoding)
# ---------------------------------------------------------------------------

VERIFY_KINDS = ("attn", "attn_dense", "local", "cross", "attn_cross")


def block_verify(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                 cache: Params, ctx: dict):
    """One block for a (B, S, d) verify step over S fed tokens at
    positions cur..cur+S-1.  Returns (x, updates, aux) with DEFERRED
    (B, S, ...) entry updates — the caller commits only the accepted
    prefix via ``kv_cache.apply_verify_writes``.

    Recurrent blocks (mamba / rglru) are unsupported: their state update
    is not position-addressed, so a rejected token could not be rolled
    back by masking the write."""
    if kind in ("mamba", "rglru"):
        raise NotImplementedError(
            f"speculative verify is unsupported for recurrent "
            f"{kind!r} blocks")
    aux = jnp.float32(0.0)
    cur, feed_mask = ctx["cur"], ctx["feed_mask"]
    if kind == "cross":
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        reader = (KC.decode_cross_latent if cfg.recalkv is not None
                  else KC.decode_cross_dense)
        y, _ = reader(p["cross"], h, cache["cross"], cfg)
        x = x + y
        h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                     dense=True)
        return x + h, {"cross": None}, aux + a

    window = cfg.window_for(kind)
    pages = ctx.get("pages")
    mesh = ctx.get("mesh")
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        y, sc = KC.verify_attn_mla(p["attn"], h, cache["self"], cfg, cur,
                                   feed_mask, pages=pages)
    elif cfg.recalkv is not None:
        y, sc = KC.verify_attn_latent(p["attn"], h, cache["self"], cfg, cur,
                                      feed_mask, window,
                                      theta=_theta(cfg, kind), pages=pages,
                                      mesh=mesh)
    else:
        y, sc = KC.verify_attn_dense(p["attn"], h, cache["self"], cfg, cur,
                                     feed_mask, window,
                                     theta=_theta(cfg, kind), pages=pages,
                                     mesh=mesh)
    x = x + y
    updates = {"self": sc}

    if kind == "attn_cross":
        hx = L.rmsnorm(x, p["lnx"], cfg.norm_eps)
        reader = (KC.decode_cross_latent if cfg.recalkv is not None
                  else KC.decode_cross_dense)
        y, _ = reader(p["cross"], hx, cache["cross"], cfg)
        x = x + y
        updates["cross"] = None

    h, a = L.ffn(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                 dense=(kind in ("attn_dense", "attn_cross")))
    return x + h, updates, aux + a


# ---------------------------------------------------------------------------
# Stack runner (prefix unrolled -> scanned periods -> suffix unrolled)
# ---------------------------------------------------------------------------

def _layer_layout(cfg: ModelConfig):
    if cfg.scan_layers:
        return cfg.prefix_pattern, cfg.layer_pattern, cfg.suffix_pattern, cfg.num_periods
    return cfg.expanded_layers(), (), (), 0


def run_stack(cfg: ModelConfig, params: Params, x: jax.Array, ctx: dict,
              caches: Params | None, *, decode: bool = False,
              verify: bool = False):
    """Apply the whole stack.  Returns (x, new_caches, aux)."""
    prefix, pattern, suffix, n_per = _layer_layout(cfg)
    if verify:
        apply_fn = block_verify
    elif decode:
        apply_fn = block_decode
    else:
        apply_fn = partial(block_full, want_cache=caches is not None)
    want_cache = caches is not None
    aux = jnp.float32(0.0)
    new_caches: Params = {"prefix": [], "blocks": None, "suffix": []}

    def run_one(kind, p, x, c):
        if decode:
            return apply_fn(cfg, kind, p, x, c, ctx)
        return apply_fn(cfg, kind, p, x, ctx)

    for i, kind in enumerate(prefix):
        c_in = caches["prefix"][i] if (decode and want_cache) else None
        x, c, a = run_one(kind, params["prefix"][i], x, c_in)
        aux = aux + a
        new_caches["prefix"].append(c)

    if n_per > 0:
        def body(carry, xs):
            x, aux = carry
            period_params = xs[0]
            period_caches = xs[1]
            outs = []
            for j, kind in enumerate(pattern):
                c_in = period_caches[j] if decode else None
                x, c, a = run_one(kind, period_params[j], x, c_in)
                aux = aux + a
                outs.append(c)
            return (x, aux), tuple(outs)

        if (not decode) and cfg.remat and not want_cache:
            body = jax.checkpoint(body)
        xs = (params["blocks"],
              caches["blocks"] if (decode and want_cache) else None)
        if xs[1] is None:
            xs = (params["blocks"], tuple(None for _ in pattern))
        (x, aux), scan_caches = jax.lax.scan(body, (x, aux), xs)
        new_caches["blocks"] = scan_caches if want_cache else None

    for i, kind in enumerate(suffix):
        c_in = caches["suffix"][i] if (decode and want_cache) else None
        x, c, a = run_one(kind, params["suffix"][i], x, c_in)
        aux = aux + a
        new_caches["suffix"].append(c)

    if want_cache:
        new_caches["prefix"] = tuple(new_caches["prefix"])
        new_caches["suffix"] = tuple(new_caches["suffix"])
        return x, new_caches, aux
    return x, None, aux


# ---------------------------------------------------------------------------
# Top-level model functions
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    return x


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """Encoder for enc-dec models.  frames: (B, S, d) stub embeddings."""
    enc = params["encoder"]
    B, S, _ = frames.shape
    ctx = {"positions": jnp.broadcast_to(jnp.arange(S), (B, S)),
           "causal": False, "lengths": jnp.full((B,), S), "max_len": S}
    x = frames.astype(cfg.dtype)
    for blk in enc["blocks"]:
        x, _, _ = block_full(cfg, "attn_dense", blk, x, ctx, want_cache=False)
    return L.rmsnorm(x, enc["final_norm"], cfg.norm_eps)


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   source: jax.Array | None = None):
    """Training forward: tokens (B, T) -> hidden (B, T, d), aux loss."""
    B, T = tokens.shape
    if cfg.encoder_decoder and source is not None:
        source = encode(cfg, params, source)
    ctx = {
        "positions": jnp.broadcast_to(jnp.arange(T), (B, T)),
        "lengths": jnp.full((B,), T, jnp.int32),
        "source": source, "max_len": T,
    }
    x = embed_tokens(cfg, params, tokens)
    x, _, aux = run_stack(cfg, params, x, ctx, caches=None)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def _lm_head_weight(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def logits_for(cfg: ModelConfig, params: Params, hidden: jax.Array) -> jax.Array:
    return (hidden @ _lm_head_weight(cfg, params)).astype(jnp.float32)


def chunked_xent(cfg: ModelConfig, params: Params, hidden: jax.Array,
                 labels: jax.Array, chunk: int = 512):
    """Cross-entropy without materializing (B, T, V) logits at once."""
    B, T, d = hidden.shape
    W = _lm_head_weight(cfg, params)

    def one(h_c, l_c):
        logits = (h_c @ W).astype(jnp.float32)
        mask = (l_c >= 0).astype(jnp.float32)
        safe = jnp.maximum(l_c, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    if T <= chunk or T % chunk:
        return one(hidden, labels)
    n = T // chunk
    hs = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)

    def body(acc, xs):
        s, c = one(*xs)
        return (acc[0] + s, acc[1] + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    return tot, cnt


def loss_fn(cfg: ModelConfig, params: Params, batch: dict):
    """Causal LM loss.  batch: tokens (B,T), labels (B,T) (-1 = pad),
    optional source (B,S,d) frontend embeddings."""
    hidden, aux = forward_hidden(cfg, params, batch["tokens"],
                                 batch.get("source"))
    tot, cnt = chunked_xent(cfg, params, hidden, batch["labels"])
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux, {"xent": loss, "aux": aux, "tokens": cnt}


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      pages: tuple[int, int] | None = None) -> Params:
    """Decode cache pool.  With ``pages`` = (n_pages, page_size) every
    block's ring is built page-major — leaves (n_pages, page_size, ...)
    shared across slots through a page table — instead of per-slot
    (batch, max_len, ...) rows.  Callers gate paged mode to full-length
    self-attention stacks (no recurrent/cross/sliding-window blocks);
    page 0 is the reserved null page (pos = -1, never written)."""
    prefix, pattern, suffix, n_per = _layer_layout(cfg)
    b, ml = (batch, max_len) if pages is None else pages
    def stack_cache(kind):
        one = KC.init_block_cache(cfg, kind, b, ml)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_per,) + a.shape), one)
    n_scanned = n_per * len(pattern)
    return {
        "prefix": tuple(
            KC.init_block_cache(cfg, k, b, ml, layer_idx=i)
            for i, k in enumerate(prefix)),
        "blocks": tuple(stack_cache(k) for k in pattern) if n_per else None,
        "suffix": tuple(
            KC.init_block_cache(cfg, k, b, ml,
                                layer_idx=len(prefix) + n_scanned + i)
            for i, k in enumerate(suffix)),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            lengths: jax.Array, max_len: int, source: jax.Array | None = None):
    """Aligned right-padded prefill.  Returns (last_logits (B,V), caches)."""
    B, T = tokens.shape
    if cfg.encoder_decoder and source is not None:
        source = encode(cfg, params, source)
    ctx = {
        "positions": jnp.broadcast_to(jnp.arange(T), (B, T)),
        "lengths": lengths, "source": source, "max_len": max_len,
    }
    x = embed_tokens(cfg, params, tokens)
    x, caches, _ = run_stack(cfg, params, x, ctx, caches={})
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, jnp.maximum(lengths - 1, 0)[:, None, None], axis=1)[:, 0]
    return logits_for(cfg, params, last[:, None, :])[:, 0], caches


def decode_step(cfg: ModelConfig, params: Params, caches: Params,
                tokens: jax.Array, cur: jax.Array,
                active: jax.Array | None = None, *,
                cache_shardings=None, pages=None, mesh=None):
    """One decode step.  tokens: (B,) int32, cur: (B,) absolute positions.
    ``active`` (B,) bool masks cache writes for idle batch rows (serving
    slots between requests).  ``cache_shardings`` (optional NamedSharding
    tree matching ``caches``) pins the updated cache's layout so a fused
    multi-step loop never reshards its carry mid-scan.  ``pages``
    (ptab (B, n_slot_pages) int32, page_size) switches reads and the
    deferred write to the page-major pool layout.  ``mesh`` (closure
    capture, never a traced argument) lets the pallas decode readers run
    under shard_map over the mesh's "model" axis.  Returns
    (logits (B, V), new caches)."""
    x = embed_tokens(cfg, params, tokens[:, None])
    ctx = {"cur": cur, "pages": pages, "mesh": mesh}
    x, updates, _ = run_stack(cfg, params, x, ctx, caches=caches, decode=True)
    caches = KC.apply_decode_writes(caches, updates, cur, active, pages=pages)
    caches = KC.constrain_caches(caches, cache_shardings)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_for(cfg, params, x)[:, 0], caches


def verify_step(cfg: ModelConfig, params: Params, caches: Params,
                tokens: jax.Array, cur: jax.Array, feed_mask: jax.Array,
                pages=None, mesh=None):
    """Speculative-decoding target verification: logits for S fed tokens
    in ONE pass (one weight/cache read amortized over S positions — the
    step-count lever low-rank caches leave on the table).

    tokens: (B, S) int32 — tokens[:, 0] is the slot's next sequential
    feed, columns 1.. are draft proposals.  cur: (B,) absolute position
    of column 0.  feed_mask: (B, S) bool marks candidate columns (masked
    columns contribute no K/V and their logits are garbage).

    Cache writes are NOT applied here: the deferred (B, S, ...) updates
    are returned so the caller can run accept/reject on the logits and
    commit only the accepted prefix via :func:`commit_verify_writes` —
    the ring then never sees a rejected token.  Returns
    (logits (B, S, V) float32, updates)."""
    x = embed_tokens(cfg, params, jnp.maximum(tokens, 0))
    ctx = {"cur": cur, "feed_mask": feed_mask, "pages": pages, "mesh": mesh}
    x, updates, _ = run_stack(cfg, params, x, ctx, caches=caches,
                              decode=True, verify=True)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return logits_for(cfg, params, x), updates


def commit_verify_writes(caches: Params, updates: Params, cur: jax.Array,
                         mask: jax.Array, *, cache_shardings=None,
                         pages=None) -> Params:
    """Apply a verify step's deferred writes for the accepted prefix
    (``mask`` (B, S) bool) and re-pin the cache layout (see
    :func:`decode_step`)."""
    caches = KC.apply_verify_writes(caches, updates, cur, mask, pages=pages)
    return KC.constrain_caches(caches, cache_shardings)


def swap_cache_slot(caches: Params, stage: Params, slot: jax.Array,
                    q: jax.Array) -> Params:
    """Install staged-row ``q`` of a stage cache (same tree as the ring
    pool, see :func:`init_decode_cache`) into serving-slot row ``slot``
    of the resident pool — the device side of a mid-window continuous-
    batching swap.  ``slot``/``q`` are traced scalars; passing a
    ``slot`` >= batch makes the scatter a no-op (``mode="drop"``), which
    is how the fused window expresses "no swap this iteration" without a
    branch.  Ring layout only: paged swaps go through the page table
    (the staged request's pages are scattered into the shared pool at
    stage time, so installing is just a carry-row copy)."""
    def leaf(axis):
        def f(pool, srow):
            idx = (slice(None),) * axis + (slot,)
            return pool.at[idx].set(jnp.take(srow, q, axis=axis),
                                    mode="drop")
        return f
    return {
        # prefix/suffix leaves are (B, ...); scanned blocks carry a
        # leading (n_per,) layer axis before the slot axis.
        "prefix": jax.tree.map(leaf(0), caches["prefix"], stage["prefix"]),
        "blocks": jax.tree.map(leaf(1), caches["blocks"], stage["blocks"]),
        "suffix": jax.tree.map(leaf(0), caches["suffix"], stage["suffix"]),
    }


def wipe_pages(caches: Params, pages: jax.Array) -> Params:
    """Reset the ``pos`` stamps of physical ``pages`` (1-D int32) of a
    page-major cache pool to -1 (empty).  Position masking is the pool's
    ONLY validity mechanism — a recycled page still holds its previous
    holder's pos values, which ``_decode_mask`` would read as valid for
    any new holder whose ``cur`` has passed them — so every page that is
    mapped into a slot WITHOUT being covered by a prefill scatter (lazy
    page reservation allocating ahead of ``cur``) must be wiped first.
    Content leaves are left as-is: garbage latents under pos = -1 are
    unreadable.  Padding ``pages`` with the null page 0 is harmless (its
    pos is already -1 and nothing ever reads it as non-empty)."""
    def one(path, leaf):
        if getattr(path[-1], "key", None) != "pos":
            return leaf
        # scanned blocks carry a leading (n_per,) layer axis before the
        # page axis; prefix/suffix leaves are page-major directly
        if getattr(path[0], "key", None) == "blocks":
            return leaf.at[:, pages].set(-1)
        return leaf.at[pages].set(-1)
    return jax.tree_util.tree_map_with_path(one, caches)


def preempt_slot(st: dict, slot: int) -> dict:
    """Evict serving-slot row ``slot`` from a fused-window carry: the
    slot goes inactive (no further decode steps, no cache writes) and,
    under continuous batching, its generation counter bumps so any
    in-flight host scatter or harvested status targeting the old
    occupant is redirected/stale-ified by the existing gen guards.  The
    evicted request's sampling state was snapshotted host-side before
    this call (see the engine's preemption path); everything else about
    the row is dead until a new occupant installs over it."""
    out = dict(st)
    out["act"] = st["act"].at[slot].set(False)
    if "gen" in st:
        out["gen"] = st["gen"].at[slot].add(1)
    return out


def decode_loop(cfg: ModelConfig, params: Params, caches: Params,
                tokens: jax.Array, cur: jax.Array, steps: int, *,
                active: jax.Array | None = None, rng: jax.Array | None = None,
                sample_fn=None, cache_shardings=None):
    """Fused multi-token decode: ``steps`` iterations of step -> sample ->
    feed under one ``lax.scan``, the sampled token living in device carry
    (no host round-trip per token — the caller syncs once per loop).

    tokens/cur: (B,) as in :func:`decode_step`.  ``sample_fn(logits, key)
    -> (B,) int32`` picks the next token (greedy argmax when None; ``rng``
    seeds the per-step key split, only used when sampling).
    ``cache_shardings`` accepts pre-sharded caches: the scan carry is
    pinned to that layout every iteration, so a mesh caller pays zero
    reshards inside the loop.  Returns (caches, last_tokens, cur,
    out_tokens (B, steps))."""
    key0 = rng if rng is not None else jax.random.PRNGKey(0)

    def body(carry, _):
        caches, tok, cur, key = carry
        logits, caches = decode_step(cfg, params, caches, tok, cur, active,
                                     cache_shardings=cache_shardings)
        if sample_fn is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            nxt = sample_fn(logits, sub)
        inc = 1 if active is None else active.astype(cur.dtype)
        return (caches, nxt, cur + inc, key), nxt

    (caches, tok, cur, _), toks = jax.lax.scan(
        body, (caches, tokens, cur, key0), None, length=steps)
    return caches, tok, cur, jnp.moveaxis(toks, 0, 1)
