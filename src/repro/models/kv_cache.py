"""KV-cache structures + single-step decode attention readers.

Cache variants (all per block, batch-major):
  dense attn   {"k","v": (B, L, Hkv, dh), "pos": (B, L) int32}   post-RoPE keys
  latent attn  {"zk": (B, L, G, r_k), "zv": (B, L, G, r_v), "pos"}  pre-RoPE
  MLA          {"ckv": (B, L, r_kv), "krope": (B, L, dr), "pos"}  shared heads
  mamba        {"h": (B, d_inner, d_state) f32, "conv": (B, K-1, d_inner)}
  rglru        {"h": (B, W) f32, "conv": (B, K-1, W)}
  cross        dense {"k","v": (B, S_src, Hkv, dh)} / latent {"zk","zv"}

L is the ring length: min(window, max_len) for sliding-window blocks, else
max_len.  ``pos`` stores the absolute position held in each slot (−1 =
empty); masking and RoPE reconstruction read it, so ring wraparound needs
no extra bookkeeping.  Writes go to slot ``cur_pos % L`` per sequence.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.quant import dequantize, quantize

Params = dict[str, Any]
NEG_INF = L.NEG_INF


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def init_self_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype=None, layer_idx: int | None = None) -> Params:
    dtype = dtype or cfg.dtype
    Lr = cfg.cache_len(kind, max_len)
    pos = jnp.full((batch, Lr), -1, jnp.int32)
    if cfg.mla is not None and kind in ("attn", "attn_dense"):
        a = cfg.mla
        return {
            "ckv": jnp.zeros((batch, Lr, a.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, Lr, a.qk_rope_dim), dtype),
            "pos": pos,
        }
    if cfg.recalkv is not None:
        rt = cfg.recalkv
        G = rt.num_groups(cfg.num_kv_heads)
        rk, rv = rt.ranks_for(layer_idx)
        if cfg.cache_quant_bits is not None:
            return {
                "zk_q": jnp.zeros((batch, Lr, G, rk), jnp.int8),
                "zk_s": jnp.zeros((batch, Lr, G), jnp.float32),
                "zv_q": jnp.zeros((batch, Lr, G, rv), jnp.int8),
                "zv_s": jnp.zeros((batch, Lr, G), jnp.float32),
                "pos": pos,
            }
        return {
            "zk": jnp.zeros((batch, Lr, G, rk), dtype),
            "zv": jnp.zeros((batch, Lr, G, rv), dtype),
            "pos": pos,
        }
    return {
        "k": jnp.zeros((batch, Lr, cfg.num_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, Lr, cfg.num_kv_heads, cfg.d_head), dtype),
        "pos": pos,
    }


def init_cross_cache(cfg: ModelConfig, batch: int, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    S = cfg.cross_source_len
    if cfg.recalkv is not None:
        rt = cfg.recalkv
        G = rt.num_groups(cfg.num_kv_heads)
        return {
            "zk": jnp.zeros((batch, S, G, rt.rank_k), dtype),
            "zv": jnp.zeros((batch, S, G, rt.rank_v), dtype),
        }
    return {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.d_head), dtype),
    }


def init_state_cache(cfg: ModelConfig, kind: str, batch: int) -> Params:
    if kind == "mamba":
        di = cfg.mamba_d_inner
        return {
            "h": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di), cfg.dtype),
        }
    if kind == "rglru":
        W = cfg.lru_width
        return {
            "h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, W), cfg.dtype),
        }
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     layer_idx: int | None = None) -> Params:
    if kind in ("mamba", "rglru"):
        return init_state_cache(cfg, kind, batch)
    if kind == "cross":
        return {"cross": init_cross_cache(cfg, batch)}
    if kind == "attn_cross":
        return {
            "self": init_self_cache(cfg, kind, batch, max_len,
                                    layer_idx=layer_idx),
            "cross": init_cross_cache(cfg, batch),
        }
    return {"self": init_self_cache(cfg, kind, batch, max_len,
                                    layer_idx=layer_idx)}


# ---------------------------------------------------------------------------
# Writes
# ---------------------------------------------------------------------------

def _ring_write(cache_arr: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write one entry per sequence.  new: (B, ...), slot: (B,) int32.

    Implemented as iota-compare + select rather than a batched scatter:
    per-batch dynamic scatter indices defeat the SPMD partitioner on the
    sequence-sharded ring (it falls back to full rematerialization —
    replicating the entire cache per device).  The select form is purely
    elementwise over (B, L, ...), so the cache stays sequence-sharded and
    the update costs one masked read-modify-write of the local shard
    (EXPERIMENTS.md §Perf iteration 1)."""
    B, L = cache_arr.shape[:2]
    hit = jnp.arange(L, dtype=slot.dtype)[None, :] == slot[:, None]  # (B, L)
    hit = hit.reshape((B, L) + (1,) * (cache_arr.ndim - 2))
    return jnp.where(hit, new.astype(cache_arr.dtype)[:, None], cache_arr)


def write_prefill(cache_arr: jax.Array, values: jax.Array,
                  lengths: jax.Array | None = None) -> jax.Array:
    """Bulk-write prefill values (B, T, ...) into ring slots (pos % L).

    T <= L is a plain aligned write.  T > L wraps per row, last write
    wins: each row keeps its own last min(length, L) positions.  Padded
    columns (index >= ``lengths``) never write, so a short prompt batched
    into a wave whose padded T exceeds its ring (e.g. any sliding-window
    block) is not clobbered by the long rows' wraparound."""
    B, T = values.shape[:2]
    Lr = cache_arr.shape[1]
    if T <= Lr:
        return cache_arr.at[:, jnp.arange(T)].set(values.astype(cache_arr.dtype))
    eff = (jnp.full((B,), T, jnp.int32) if lengths is None
           else jnp.minimum(lengths, T).astype(jnp.int32))
    s = jnp.arange(Lr, dtype=jnp.int32)[None, :]             # (1, Lr)
    wraps = (eff[:, None] - 1 - s) // Lr                     # (B, Lr)
    t_last = s + wraps * Lr            # last column landing on slot s
    valid = wraps >= 0                 # slot ever written by a real token
    shape = (B, Lr) + (1,) * (values.ndim - 2)
    gathered = jnp.take_along_axis(
        values, jnp.clip(t_last, 0, T - 1).reshape(shape), axis=1)
    return jnp.where(valid.reshape(shape), gathered.astype(cache_arr.dtype),
                     cache_arr)


def prefill_pos(lengths: jax.Array, T: int, Lr: int) -> jax.Array:
    """Position array after an aligned right-padded prefill of length T.
    Mirrors ``write_prefill``'s slot mapping exactly (ring wraparound)."""
    B = lengths.shape[0]
    idx = jnp.arange(T)
    vals = jnp.where(idx[None, :] < lengths[:, None], idx[None, :], -1)
    cache = jnp.full((B, Lr), -1, jnp.int32)
    return write_prefill(cache, vals.astype(jnp.int32), lengths)


def latent_cache_entry(cfg: ModelConfig, zk: jax.Array, zv: jax.Array) -> Params:
    """Ring-cache leaves for latent K/V at any leading shape (..., G, r):
    model-dtype latents, or int8 + per-token scale when
    ``cfg.cache_quant_bits`` is set."""
    if cfg.cache_quant_bits is None:
        return {"zk": zk, "zv": zv}
    zk_q, zk_s = quantize(zk, cfg.cache_quant_bits)
    zv_q, zv_s = quantize(zv, cfg.cache_quant_bits)
    return {"zk_q": zk_q, "zk_s": zk_s[..., 0],
            "zv_q": zv_q, "zv_s": zv_s[..., 0]}


def latent_cache_arrays(cache: Params, dtype) -> tuple[jax.Array, jax.Array]:
    """(zk, zv) from a float or int8 latent cache dict, dequantized."""
    if "zk_q" in cache:
        return (dequantize(cache["zk_q"], cache["zk_s"][..., None], dtype),
                dequantize(cache["zv_q"], cache["zv_s"][..., None], dtype))
    return cache["zk"].astype(dtype), cache["zv"].astype(dtype)


# ---------------------------------------------------------------------------
# Paged layout
# ---------------------------------------------------------------------------
#
# In the paged layout a block's ring leaves live page-major in a shared
# pool — (n_pages, page_size, ...) instead of (B, max_len, ...) — and a
# (B, n_slot_pages) int32 page table (carried through the decode window
# like any other slot state) maps slot-page index -> physical page.
# Physical page 0 is the reserved null page: its ``pos`` stays -1 and it
# is never written, so unmapped table entries read as empty ring.  The
# ``pages`` argument threaded through the readers/writers below is the
# tuple (ptab, page_size); None means ring layout.  int8 pages keep
# their quantization scales page-local: zk_s/zv_s are pool leaves
# (n_pages, page_size, G) gathered and written through the same table.


def paged_view(cache: Params, ptab: jax.Array, page_size: int) -> Params:
    """Slot-major view of a page-major cache dict.

    Each leaf's pages are gathered through the table and folded to a
    (B, n_slot_pages * page_size, ...) ring — exactly the arrays the ring
    layout would hold, so every einsum reader (and the quantized kernel
    path) runs unchanged and bitwise-identically on the view."""
    B, n_sp = ptab.shape
    flat = ptab.reshape(-1)

    def one(leaf):
        v = jnp.take(leaf, flat, axis=0)
        return v.reshape((B, n_sp * page_size) + leaf.shape[2:])

    return {k: one(v) for k, v in cache.items()}


def _paged_merge_leaf(pool, upd, ptab: jax.Array, page_size: int,
                      cur: jax.Array, stacked: bool,
                      active: jax.Array | None):
    """Paged form of ``_merge_leaf``: route each row's slot entry through
    the page table to (physical page, in-page offset) = (ptab[b, cur//ps],
    cur %% ps).  Still iota-compare + select — a (P, ps) hit mask over the
    pool — so the pool stays page x offset sharded under SPMD exactly as
    the ring stayed slot x sequence sharded.  The null page (0) is never
    written; allocation guarantees live pages have at most one writer, so
    ``argmax`` over the hit matrix picks THE writing row."""
    if upd is None:
        return pool
    b_ax = 1 if stacked else 0
    P = pool.shape[b_ax]
    B, n_sp = ptab.shape
    page_idx = jnp.clip((cur // page_size).astype(jnp.int32), 0, n_sp - 1)
    tgt = jnp.take_along_axis(ptab, page_idx[:, None], axis=1)[:, 0]  # (B,)
    act = jnp.ones((B,), bool) if active is None else active
    hit_pb = (jnp.arange(P, dtype=tgt.dtype)[:, None] == tgt[None, :]) \
        & act[None, :]                                               # (P, B)
    has = hit_pb.any(axis=1) & (jnp.arange(P) != 0)
    writer = jnp.argmax(hit_pb, axis=1)                              # (P,)
    off = (cur % page_size).astype(jnp.int32)
    hit = has[:, None] & (jnp.arange(page_size, dtype=jnp.int32)[None, :]
                          == jnp.take(off, writer)[:, None])         # (P, ps)
    val = jnp.take(upd, writer, axis=b_ax)       # one slot entry per page
    new = jnp.expand_dims(val, axis=b_ax + 1)
    shape = [1] * pool.ndim
    shape[b_ax], shape[b_ax + 1] = P, page_size
    return jnp.where(hit.reshape(shape), new.astype(pool.dtype), pool)


# ---------------------------------------------------------------------------
# Decode readers (single new token, x: (B, 1, d))
# ---------------------------------------------------------------------------

def _decode_mask(pos: jax.Array, cur: jax.Array, window: int | None) -> jax.Array:
    """(B, S) validity mask for cache slots at decode time."""
    m = (pos >= 0) & (pos <= cur[:, None])
    if window is not None:
        m &= pos > (cur[:, None] - window)
    return m


def _two_part_softmax(logits_c: jax.Array, logits_s: jax.Array):
    """Softmax over [cache columns | self column] WITHOUT concatenating.

    Concatenation would make the (sequence-sharded) column axis length
    S+1 — indivisible, so SPMD replicates the whole softmax.  The online
    merge keeps every reduction on the sharded S axis (§Perf iteration 4).
    logits_c: (..., S);  logits_s: (..., 1).  Returns (w_c, w_s) summing
    to 1 jointly."""
    m = jnp.maximum(jnp.max(logits_c, axis=-1, keepdims=True), logits_s)
    e_c = jnp.exp(logits_c - m)
    e_s = jnp.exp(logits_s - m)
    denom = jnp.sum(e_c, axis=-1, keepdims=True) + e_s
    return e_c / denom, e_s / denom


def pallas_fallback_kinds(cfg: ModelConfig) -> list[str]:
    """Layer kinds that take the einsum path even under
    ``attn_backend="pallas"``: absorbed-MLA readers score in the c_kv
    latent space (no kernel), and cross-attention reads a static encoder
    cache (einsum only; "attn_cross" layers fall back for their cross
    half).  Mixer kinds (mamba/rglru) have no attention and don't count.
    The engine warns once when this list is non-empty so a requested
    kernel backend never degrades silently."""
    kinds = sorted(set(cfg.expanded_layers()))
    attn = [k for k in kinds if k not in ("mamba", "rglru")]
    if cfg.mla is not None:
        return attn
    return [k for k in attn if k in ("cross", "attn_cross")]


def decode_attn_dense(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
                      cur: jax.Array, window: int | None,
                      theta: float | None = None,
                      pages: tuple | None = None, mesh=None):
    """Dense decode with DEFERRED cache writes (§Perf iteration 3).

    The new token's K/V enter the softmax as an explicit self column; the
    ring write happens once per step outside the layer scan
    (apply_decode_writes), so the scan carries only (B, Hkv, dh) updates.
    Masking stays correct: the slot being overwritten holds either an
    empty entry (pos=-1) or one that just fell out of the window."""
    if pages is not None and cfg.attn_backend != "pallas":
        cache = paged_view(cache, *pages)
    B = x.shape[0]
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    g = H // Hkv
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k_new = (x @ p["wk"]).reshape(B, Hkv, dh)
    v_new = (x @ p["wv"]).reshape(B, Hkv, dh)
    q = L.maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    k_new = L.maybe_head_norm(k_new, p.get("k_norm"), cfg.norm_eps)
    cos, sin = L.rope_tables(cur[:, None], dh, theta or cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k_new = L.apply_rope(k_new[:, None], cos, sin)[:, 0]

    scale = dh ** -0.5
    updates = {"k": k_new, "v": v_new, "pos": cur.astype(jnp.int32)}
    if cfg.attn_backend == "pallas":
        # Joint softmax over [ring | self] inside the kernel: the deferred
        # write becomes an extra appended ring column at position cur.  The
        # paged kernel gathers pages via a scalar-prefetched table instead
        # of materializing the slot-major view.
        if pages is not None:
            o = kops.dense_decode_paged(
                q[:, 0], cache, pages[0], cur, window=window, scale=scale,
                self_entry={"k": k_new, "v": v_new}, mesh=mesh)
        else:
            o = kops.dense_decode(q[:, 0], cache, cur, window=window,
                                  scale=scale, block_s=cfg.attn_block,
                                  self_entry={"k": k_new, "v": v_new},
                                  mesh=mesh)
        y = o.astype(x.dtype).reshape(B, 1, H * dh) @ p["wo"]
        return y, updates

    qr = q[:, 0].reshape(B, Hkv, g, dh)
    k_c = cache["k"].astype(x.dtype)
    logits_c = jnp.einsum("bkgd,bskd->bkgs", qr, k_c).astype(jnp.float32) * scale
    mask = _decode_mask(cache["pos"], cur, window)[:, None, None, :]
    logits_c = jnp.where(mask, logits_c, NEG_INF)
    logits_s = (jnp.einsum("bkgd,bkd->bkg", qr, k_new)
                .astype(jnp.float32) * scale)[..., None]
    w_c, w_s = _two_part_softmax(logits_c, logits_s)
    w_c, w_s = w_c.astype(x.dtype), w_s.astype(x.dtype)
    o = (jnp.einsum("bkgs,bskd->bkgd", w_c, cache["v"].astype(x.dtype))
         + w_s * v_new[:, :, None, :])
    y = o.reshape(B, 1, H * dh) @ p["wo"]
    return y, updates


def decode_attn_latent(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
                       cur: jax.Array, window: int | None,
                       theta: float | None = None,
                       pages: tuple | None = None, mesh=None):
    """ReCalKV decode: reconstruct keys from the latent ring, RoPE by stored
    positions, keep values latent, project through the fused W~_o.
    Deferred-write form (see decode_attn_dense)."""
    if pages is not None and not (cfg.attn_backend == "pallas"
                                  and cfg.cache_quant_bits is None):
        # Einsum and int8-kernel paths read the gathered slot-major view
        # (page-local scales dequantize exactly as ring-local ones did);
        # only the float-latent kernel gathers pages in-kernel.
        cache = paged_view(cache, *pages)
        pages = None
    theta = theta or cfg.rope_theta
    B = x.shape[0]
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    rt = cfg.recalkv
    s = max(1, min(rt.group_size, Hkv))
    G = Hkv // s
    g = H // Hkv
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    q = L.maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    cos_q, sin_q = L.rope_tables(cur[:, None], dh, theta)
    q = L.apply_rope(q, cos_q, sin_q)
    qr = q[:, 0].reshape(B, Hkv, g, dh)

    zk_new = jnp.einsum("bd,gdr->bgr", x[:, 0], p["l_k"]).astype(x.dtype)
    zv_new = jnp.einsum("bd,gdr->bgr", x[:, 0], p["l_v"]).astype(x.dtype)

    scale = dh ** -0.5
    entry = latent_cache_entry(cfg, zk_new, zv_new)
    updates = {**entry, "pos": cur.astype(jnp.int32)}
    if cfg.attn_backend == "pallas":
        # Kernel path: the deferred write becomes an extra appended ring
        # column at cur, so the kernel's online softmax covers the self
        # token; qk-norm is applied to reconstructed keys in-kernel.
        if pages is not None:
            o_lat = kops.latent_decode_paged(
                q[:, 0], cache, pages[0], p["r_k"], cur, theta=theta,
                window=window, scale=scale, self_entry=entry,
                k_norm=p.get("k_norm"), norm_eps=cfg.norm_eps, mesh=mesh)
        else:
            o_lat = kops.latent_decode(
                q[:, 0], cache, p["r_k"], cur, theta=theta, window=window,
                scale=scale, block_s=cfg.attn_block, self_entry=entry,
                k_norm=p.get("k_norm"), norm_eps=cfg.norm_eps, mesh=mesh)
        o_lat = o_lat.astype(x.dtype).reshape(B, 1, H, -1)
        y = jnp.einsum("bthr,hrd->btd", o_lat, p["wo_fused"])
        return y, updates

    # With an int8 ring, attention (and the self column) reads the
    # dequantized latents — the same values the kernel path sees.
    zk_c, zv_c = latent_cache_arrays(cache, x.dtype)
    zk_self, zv_self = latent_cache_arrays(entry, x.dtype)

    # Reconstruct cached keys (the paper's RoPE-forced reconstruction).
    k = L.reconstruct_keys(zk_c, p["r_k"], Hkv, dh)
    k = L.maybe_head_norm(k, p.get("k_norm"), cfg.norm_eps)
    cos_k, sin_k = L.rope_tables(jnp.maximum(cache["pos"], 0), dh, theta)
    k = L.apply_rope(k, cos_k, sin_k)
    # ... and the self key from the fresh latent.
    k_self = L.reconstruct_keys(zk_self[:, None], p["r_k"], Hkv, dh)
    k_self = L.maybe_head_norm(k_self, p.get("k_norm"), cfg.norm_eps)
    k_self = L.apply_rope(k_self, cos_q, sin_q)[:, 0]       # (B, Hkv, dh)

    logits_c = jnp.einsum("bkgd,bskd->bkgs", qr, k).astype(jnp.float32) * scale
    mask = _decode_mask(cache["pos"], cur, window)[:, None, None, :]
    logits_c = jnp.where(mask, logits_c, NEG_INF)
    logits_s = (jnp.einsum("bkgd,bkd->bkg", qr, k_self)
                .astype(jnp.float32) * scale)[..., None]
    w_c, w_s = _two_part_softmax(logits_c, logits_s)
    w_c = w_c.astype(x.dtype).reshape(B, G, s * g, -1)
    w_s = w_s.astype(x.dtype).reshape(B, G, s * g, 1)
    o_lat = (jnp.einsum("bGhs,bsGr->bGhr", w_c, zv_c)
             + w_s * zv_self[:, :, None, :])
    o_lat = o_lat.reshape(B, 1, H, -1)
    y = jnp.einsum("bthr,hrd->btd", o_lat, p["wo_fused"])
    return y, updates


def decode_attn_mla(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
                    cur: jax.Array, pages: tuple | None = None):
    """Absorbed MLA decode: scores/outputs computed in the c_kv latent space
    (never reconstructing per-head K/V) — the built-in analogue of OCMF.
    Deferred-write form (see decode_attn_dense)."""
    if pages is not None:
        cache = paged_view(cache, *pages)
    a = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = a.qk_nope_dim, a.qk_rope_dim, a.v_head_dim
    q_lat = L.rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, 1, H, dn + dr)
    cos, sin = L.rope_tables(cur[:, None], dr, cfg.rope_theta)
    q_pe = L.apply_rope(q[..., dn:], cos, sin)[:, 0]       # (B, H, dr)
    q_nope = q[..., :dn][:, 0]                             # (B, H, dn)

    kv_a = x[:, 0] @ p["wkv_a"]
    ckv_new = L.rmsnorm(kv_a[..., : a.kv_lora_rank], p["kv_a_norm"],
                        cfg.norm_eps).astype(x.dtype)
    kr_new = L.apply_rope(
        kv_a[..., a.kv_lora_rank:][:, None, None, :], cos, sin)[:, 0, 0]
    kr_new = kr_new.astype(x.dtype)

    wkv_b = p["wkv_b"].reshape(a.kv_lora_rank, H, dn + dv)
    w_k = wkv_b[..., :dn]                                  # (r, H, dn)
    w_v = wkv_b[..., dn:]                                  # (r, H, dv)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope, w_k)
    scale = (dn + dr) ** -0.5
    logits_c = (
        jnp.einsum("bhr,bsr->bhs", q_abs, cache["ckv"].astype(x.dtype))
        + jnp.einsum("bhd,bsd->bhs", q_pe, cache["krope"].astype(x.dtype))
    ).astype(jnp.float32) * scale
    mask = _decode_mask(cache["pos"], cur, None)[:, None, :]
    logits_c = jnp.where(mask, logits_c, NEG_INF)
    logits_s = ((jnp.einsum("bhr,br->bh", q_abs, ckv_new)
                 + jnp.einsum("bhd,bd->bh", q_pe, kr_new))
                .astype(jnp.float32) * scale)[..., None]
    w_c, w_s = _two_part_softmax(logits_c, logits_s)
    w_c, w_s = w_c.astype(x.dtype), w_s.astype(x.dtype)
    o_lat = (jnp.einsum("bhs,bsr->bhr", w_c, cache["ckv"].astype(x.dtype))
             + w_s * ckv_new[:, None, :])
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_v)
    y = o.reshape(B, 1, H * dv) @ p["wo"]
    return y, {"ckv": ckv_new, "krope": kr_new, "pos": cur.astype(jnp.int32)}


# ---------------------------------------------------------------------------
# Verify readers (speculative decoding: S fed tokens per step, x: (B, S, d))
# ---------------------------------------------------------------------------
#
# The verify step generalizes the single-token decode readers to S
# consecutive positions cur..cur+S-1 processed in ONE pass: query j
# attends the ring (entries with pos <= cur+j) plus a causal block over
# the S fresh K/V columns.  Ring writes stay DEFERRED one level further
# than decode: the (B, S, ...) entry updates are returned to the caller,
# which commits only the ACCEPTED prefix (apply_verify_writes) after the
# accept/reject pass — a rejected draft token never touches any ring, so
# the cache after a speculative round is identical to sequential decode.
# Masked self columns contribute exp(NEG_INF - m) == 0 exactly, keeping
# each valid query's softmax bitwise equal to its single-token form.


def _joint_softmax(logits_c: jax.Array, logits_s: jax.Array):
    """Softmax over [ring columns | S self columns] without concatenating
    (the multi-column generalization of ``_two_part_softmax``; for a
    single self column the two are bitwise identical).
    logits_c: (..., S_ring);  logits_s: (..., S_new)."""
    m = jnp.maximum(jnp.max(logits_c, axis=-1, keepdims=True),
                    jnp.max(logits_s, axis=-1, keepdims=True))
    e_c = jnp.exp(logits_c - m)
    e_s = jnp.exp(logits_s - m)
    denom = (jnp.sum(e_c, axis=-1, keepdims=True)
             + jnp.sum(e_s, axis=-1, keepdims=True))
    return e_c / denom, e_s / denom


def _verify_masks(cache_pos: jax.Array, cur: jax.Array, S: int,
                  feed_mask: jax.Array, window: int | None):
    """(ring, self) attention masks for an S-token verify step.

    ring: (B, S, L) — query j sees ring entries with 0 <= pos <= cur+j
    (window-limited); self: (B, S, S) — query j sees fresh columns n <= j
    that are actual feed candidates (``feed_mask``)."""
    pos_q = cur[:, None] + jnp.arange(S, dtype=cur.dtype)[None, :]
    ring = (cache_pos[:, None, :] >= 0) & (cache_pos[:, None, :]
                                           <= pos_q[:, :, None])
    j = jnp.arange(S)
    self_m = (j[None, :, None] >= j[None, None, :]) & feed_mask[:, None, :]
    if window is not None:
        ring &= cache_pos[:, None, :] > pos_q[:, :, None] - window
        self_m &= j[None, None, :] > j[None, :, None] - window
    return pos_q, ring, self_m


def verify_attn_dense(p: Params, x: jax.Array, cache: Params,
                      cfg: ModelConfig, cur: jax.Array,
                      feed_mask: jax.Array, window: int | None,
                      theta: float | None = None,
                      pages: tuple | None = None, mesh=None):
    """Dense S-token verify.  Returns (y (B, S, d), deferred updates with
    (B, S, ...) entry leaves — committed by the caller per accept mask)."""
    if pages is not None and cfg.attn_backend != "pallas":
        cache = paged_view(cache, *pages)
    B, S = x.shape[:2]
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    g = H // Hkv
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k_new = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v_new = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    q = L.maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    k_new = L.maybe_head_norm(k_new, p.get("k_norm"), cfg.norm_eps)
    pos_q = cur[:, None] + jnp.arange(S, dtype=cur.dtype)[None, :]
    cos, sin = L.rope_tables(pos_q, dh, theta or cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k_new = L.apply_rope(k_new, cos, sin)

    scale = dh ** -0.5
    updates = {"k": k_new, "v": v_new, "pos": pos_q.astype(jnp.int32)}
    if cfg.attn_backend == "pallas":
        # Multi-query kernel: all S verify queries score [ring | causal
        # self block] in one pass; q and k_new arrive post-RoPE at pos_q,
        # matching the identity-rotation dense kernel contract.
        entries = {"k": k_new, "v": v_new}
        if pages is not None:
            o = kops.dense_decode_mq_paged(
                q, cache, pages[0], cur, feed_mask, entries, window=window,
                scale=scale, mesh=mesh)
        else:
            o = kops.dense_decode_mq(
                q, cache, cur, feed_mask, entries, window=window,
                scale=scale, block_s=cfg.attn_block, mesh=mesh)
        y = o.astype(x.dtype).reshape(B, S, H * dh) @ p["wo"]
        return y, updates

    qr = q.reshape(B, S, Hkv, g, dh)
    _, ring_m, self_m = _verify_masks(cache["pos"], cur, S, feed_mask,
                                      window)
    k_c = cache["k"].astype(x.dtype)
    logits_c = (jnp.einsum("bjkgd,bskd->bkgjs", qr, k_c)
                .astype(jnp.float32) * scale)
    logits_c = jnp.where(ring_m[:, None, None], logits_c, NEG_INF)
    logits_s = (jnp.einsum("bjkgd,bnkd->bkgjn", qr, k_new)
                .astype(jnp.float32) * scale)
    logits_s = jnp.where(self_m[:, None, None], logits_s, NEG_INF)
    w_c, w_s = _joint_softmax(logits_c, logits_s)
    w_c, w_s = w_c.astype(x.dtype), w_s.astype(x.dtype)
    o = (jnp.einsum("bkgjs,bskd->bjkgd", w_c, cache["v"].astype(x.dtype))
         + jnp.einsum("bkgjn,bnkd->bjkgd", w_s, v_new))
    y = o.reshape(B, S, H * dh) @ p["wo"]
    return y, updates


def verify_attn_latent(p: Params, x: jax.Array, cache: Params,
                       cfg: ModelConfig, cur: jax.Array,
                       feed_mask: jax.Array, window: int | None,
                       theta: float | None = None,
                       pages: tuple | None = None, mesh=None):
    """ReCalKV S-token verify (see verify_attn_dense): cached keys are
    reconstructed and RoPE'd by stored position, fresh latents enter as a
    causal self block, values stay latent through the fused W~_o."""
    if pages is not None and not (cfg.attn_backend == "pallas"
                                  and cfg.cache_quant_bits is None):
        # Same gating as decode_attn_latent: einsum and int8-kernel paths
        # read the gathered slot-major view; only the float-latent kernel
        # gathers pages in-kernel.
        cache = paged_view(cache, *pages)
        pages = None
    theta = theta or cfg.rope_theta
    B, S = x.shape[:2]
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    rt = cfg.recalkv
    s = max(1, min(rt.group_size, Hkv))
    G = Hkv // s
    g = H // Hkv
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    q = L.maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    pos_q = cur[:, None] + jnp.arange(S, dtype=cur.dtype)[None, :]
    cos_q, sin_q = L.rope_tables(pos_q, dh, theta)
    q = L.apply_rope(q, cos_q, sin_q)

    zk_new = jnp.einsum("bjd,gdr->bjgr", x, p["l_k"]).astype(x.dtype)
    zv_new = jnp.einsum("bjd,gdr->bjgr", x, p["l_v"]).astype(x.dtype)
    entry = latent_cache_entry(cfg, zk_new, zv_new)
    scale = dh ** -0.5
    if cfg.attn_backend == "pallas":
        # Multi-query kernel: fresh latents ride as S appended self
        # columns (reconstructed + RoPE'd at pos_q in-kernel, including
        # the int8 quantize-then-dequantize round-trip).
        if pages is not None:
            o_lat = kops.latent_decode_mq_paged(
                q, cache, pages[0], p["r_k"], cur, feed_mask, entry,
                theta=theta, window=window, scale=scale,
                k_norm=p.get("k_norm"), norm_eps=cfg.norm_eps, mesh=mesh)
        else:
            o_lat = kops.latent_decode_mq(
                q, cache, p["r_k"], cur, feed_mask, entry, theta=theta,
                window=window, scale=scale, block_s=cfg.attn_block,
                k_norm=p.get("k_norm"), norm_eps=cfg.norm_eps, mesh=mesh)
        o_lat = o_lat.astype(x.dtype).reshape(B, S, H, -1)
        y = jnp.einsum("bjhr,hrd->bjd", o_lat, p["wo_fused"])
        return y, {**entry, "pos": pos_q.astype(jnp.int32)}

    qr = q.reshape(B, S, Hkv, g, dh)
    _, ring_m, self_m = _verify_masks(cache["pos"], cur, S, feed_mask,
                                      window)
    zk_c, zv_c = latent_cache_arrays(cache, x.dtype)
    zk_self, zv_self = latent_cache_arrays(entry, x.dtype)

    k = L.reconstruct_keys(zk_c, p["r_k"], Hkv, dh)
    k = L.maybe_head_norm(k, p.get("k_norm"), cfg.norm_eps)
    cos_k, sin_k = L.rope_tables(jnp.maximum(cache["pos"], 0), dh, theta)
    k = L.apply_rope(k, cos_k, sin_k)
    k_self = L.reconstruct_keys(zk_self, p["r_k"], Hkv, dh)
    k_self = L.maybe_head_norm(k_self, p.get("k_norm"), cfg.norm_eps)
    k_self = L.apply_rope(k_self, cos_q, sin_q)             # (B, S, Hkv, dh)

    logits_c = (jnp.einsum("bjkgd,bskd->bkgjs", qr, k)
                .astype(jnp.float32) * scale)
    logits_c = jnp.where(ring_m[:, None, None], logits_c, NEG_INF)
    logits_s = (jnp.einsum("bjkgd,bnkd->bkgjn", qr, k_self)
                .astype(jnp.float32) * scale)
    logits_s = jnp.where(self_m[:, None, None], logits_s, NEG_INF)
    w_c, w_s = _joint_softmax(logits_c, logits_s)
    Lr = zk_c.shape[1]
    w_cg = w_c.astype(x.dtype).reshape(B, G, s * g, S, Lr)
    w_sg = w_s.astype(x.dtype).reshape(B, G, s * g, S, S)
    o_lat = (jnp.einsum("bGhjs,bsGr->bjGhr", w_cg, zv_c)
             + jnp.einsum("bGhjn,bnGr->bjGhr", w_sg, zv_self))
    o_lat = o_lat.reshape(B, S, H, -1)
    y = jnp.einsum("bjhr,hrd->bjd", o_lat, p["wo_fused"])
    return y, {**entry, "pos": pos_q.astype(jnp.int32)}


def verify_attn_mla(p: Params, x: jax.Array, cache: Params,
                    cfg: ModelConfig, cur: jax.Array, feed_mask: jax.Array,
                    pages: tuple | None = None):
    """Absorbed-MLA S-token verify (see verify_attn_dense)."""
    if pages is not None:
        cache = paged_view(cache, *pages)
    a = cfg.mla
    B, S = x.shape[:2]
    H = cfg.num_heads
    dn, dr, dv = a.qk_nope_dim, a.qk_rope_dim, a.v_head_dim
    q_lat = L.rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(B, S, H, dn + dr)
    pos_q, ring_m, self_m = _verify_masks(cache["pos"], cur, S, feed_mask,
                                          None)
    cos, sin = L.rope_tables(pos_q, dr, cfg.rope_theta)
    q_pe = L.apply_rope(q[..., dn:], cos, sin)              # (B, S, H, dr)
    q_nope = q[..., :dn]

    kv_a = x @ p["wkv_a"]
    ckv_new = L.rmsnorm(kv_a[..., : a.kv_lora_rank], p["kv_a_norm"],
                        cfg.norm_eps).astype(x.dtype)
    kr_new = L.apply_rope(
        kv_a[..., a.kv_lora_rank:][:, :, None, :], cos, sin)[:, :, 0]
    kr_new = kr_new.astype(x.dtype)

    wkv_b = p["wkv_b"].reshape(a.kv_lora_rank, H, dn + dv)
    w_k, w_v = wkv_b[..., :dn], wkv_b[..., dn:]
    q_abs = jnp.einsum("bjhd,rhd->bjhr", q_nope, w_k)
    scale = (dn + dr) ** -0.5
    logits_c = (
        jnp.einsum("bjhr,bsr->bhjs", q_abs, cache["ckv"].astype(x.dtype))
        + jnp.einsum("bjhd,bsd->bhjs", q_pe, cache["krope"].astype(x.dtype))
    ).astype(jnp.float32) * scale
    logits_c = jnp.where(ring_m[:, None], logits_c, NEG_INF)
    logits_s = (jnp.einsum("bjhr,bnr->bhjn", q_abs, ckv_new)
                + jnp.einsum("bjhd,bnd->bhjn", q_pe, kr_new)
                ).astype(jnp.float32) * scale
    logits_s = jnp.where(self_m[:, None], logits_s, NEG_INF)
    w_c, w_s = _joint_softmax(logits_c, logits_s)
    w_c, w_s = w_c.astype(x.dtype), w_s.astype(x.dtype)
    o_lat = (jnp.einsum("bhjs,bsr->bjhr", w_c, cache["ckv"].astype(x.dtype))
             + jnp.einsum("bhjn,bnr->bjhr", w_s, ckv_new))
    o = jnp.einsum("bjhr,rhd->bjhd", o_lat, w_v)
    y = o.reshape(B, S, H * dv) @ p["wo"]
    return y, {"ckv": ckv_new, "krope": kr_new,
               "pos": pos_q.astype(jnp.int32)}


def _merge_leaf(cache_leaf, upd, cur: jax.Array, stacked: bool,
                active: jax.Array | None):
    if upd is None:
        return cache_leaf
    b_ax = 1 if stacked else 0
    B = cache_leaf.shape[b_ax]
    if upd.ndim == cache_leaf.ndim:                          # state replace
        if active is None:
            return upd.astype(cache_leaf.dtype)
        shape = [1] * cache_leaf.ndim
        shape[b_ax] = B
        return jnp.where(active.reshape(shape),
                         upd.astype(cache_leaf.dtype), cache_leaf)
    Lr = cache_leaf.shape[b_ax + 1]
    slot = (cur % Lr).astype(jnp.int32)                      # (B,)
    hit = jnp.arange(Lr, dtype=jnp.int32)[None, :] == slot[:, None]
    if active is not None:
        hit &= active[:, None]
    shape = [1] * cache_leaf.ndim
    shape[b_ax], shape[b_ax + 1] = B, Lr
    hit = hit.reshape(shape)
    new = jnp.expand_dims(upd, axis=b_ax + 1)                # slot axis
    return jnp.where(hit, new.astype(cache_leaf.dtype), cache_leaf)


def _merge(caches, updates, cur, stacked: bool, active, pages=None):
    if updates is None:
        return caches
    if isinstance(caches, dict):
        return {k: _merge(v, updates.get(k), cur, stacked, active, pages)
                for k, v in caches.items()}
    if isinstance(caches, (tuple, list)):
        return type(caches)(
            _merge(c, u, cur, stacked, active, pages)
            for c, u in zip(caches, updates))
    if pages is not None:
        return _paged_merge_leaf(caches, updates, pages[0], pages[1], cur,
                                 stacked, active)
    return _merge_leaf(caches, updates, cur, stacked, active)


def constrain_caches(caches: Params, shardings) -> Params:
    """Pin a cache pytree to ``shardings`` (a matching tree of
    NamedShardings, or None for a no-op).

    Called once per decode iteration, after ``apply_decode_writes``: the
    fused loop's scan carry then *stays* slot x sequence sharded instead
    of SPMD re-deriving the ring's layout from each iteration's mixed
    (head-sharded params x sequence-sharded cache) contractions — a
    layout flip inside the scan body would reshard the entire ring every
    step."""
    if shardings is None:
        return caches
    return jax.tree.map(jax.lax.with_sharding_constraint, caches, shardings)


def apply_decode_writes(caches: Params, updates: Params, cur: jax.Array,
                        active: jax.Array | None = None,
                        pages: tuple | None = None) -> Params:
    """Merge deferred per-layer decode updates into the caches (§Perf it. 3).

    One vectorized pass after the layer scan: update leaves are slot
    entries (one dim short of the cache leaf — ring-written at cur %% L),
    full replacements (recurrent states, equal ndim), or None (static
    cross caches, kept as-is).  ``active`` (B,) bool, when given, freezes
    the rows of inactive sequences entirely — a freed serving slot's ring
    and recurrent state stay inert until re-admission.  With ``pages``
    (ptab, page_size) the caches are page-major pools and each row's
    write resolves through the table (``_paged_merge_leaf``); the paged
    engine admits only full-length self-attention rings, so every leaf is
    a slot entry there."""
    return {
        "prefix": _merge(caches["prefix"], updates["prefix"], cur, False,
                         active, pages),
        "blocks": _merge(caches["blocks"], updates["blocks"], cur, True,
                         active, pages),
        "suffix": _merge(caches["suffix"], updates["suffix"], cur, False,
                         active, pages),
    }


def _slice_update_leaf(path, upd, j: int):
    """Column j of an S-position verify update leaf.  Leaves under the
    scanned "blocks" subtree carry a leading (n_periods,) stack axis."""
    if upd is None:
        return None
    key0 = getattr(path[0], "key", None)
    return upd[:, :, j] if key0 == "blocks" else upd[:, j]


def apply_verify_writes(caches: Params, updates: Params, cur: jax.Array,
                        mask: jax.Array,
                        pages: tuple | None = None) -> Params:
    """Commit an S-position verify step's deferred writes for the accepted
    prefix only.

    ``updates`` is the tree returned by ``transformer.verify_step`` (entry
    leaves (B, S, ...)); column j writes at position cur + j where
    ``mask[:, j]``.  Columns are applied in ascending j (last-wins exactly
    as S sequential decode writes would), so the ring after a speculative
    round is identical to sequential decode of the accepted tokens —
    rejected draft positions never write at all."""
    S = mask.shape[1]
    for j in range(S):
        upd_j = jax.tree_util.tree_map_with_path(
            lambda path, u: _slice_update_leaf(path, u, j), updates,
            is_leaf=lambda u: u is None)
        caches = apply_decode_writes(caches, upd_j, cur + j,
                                     active=mask[:, j], pages=pages)
    return caches


def invalidate_positions(caches: Params, cur: jax.Array,
                         mask: jax.Array) -> Params:
    """Mark ring entries at position ``cur`` as empty (pos = -1) for rows
    where ``mask``.  Used to retire a draft model's ring entries for
    rejected proposals: the draft writes as it proposes (each proposal
    attends the previous one), so rejected columns must be struck from
    the position index or they would shadow the slot until overwritten."""
    def one(path, leaf):
        names = _path_keys(path)
        if names[-1] != "pos":
            return leaf
        stacked = names[0] == "blocks"
        b_ax = 1 if stacked else 0
        Lr = leaf.shape[b_ax + 1]
        slot = (cur % Lr).astype(jnp.int32)
        hit = (jnp.arange(Lr, dtype=jnp.int32)[None, :] == slot[:, None])
        hit &= mask[:, None]
        if stacked:
            hit = hit[None]
        return jnp.where(hit, jnp.int32(-1), leaf)
    return jax.tree_util.tree_map_with_path(one, caches)


def _path_keys(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def decode_cross_dense(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig):
    """Cross-attention reader for decode (x: (B, 1, d)) and verify
    (x: (B, S, d)) steps — the source is static, so the token axis is
    just a query axis."""
    B, T = x.shape[:2]
    H, dh = cfg.num_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    q = L.maybe_head_norm(q, p.get("q_norm"), cfg.norm_eps)
    o = L._attend(q, cache["k"].astype(x.dtype), cache["v"].astype(x.dtype),
                  None, dh ** -0.5)
    return o.reshape(B, T, H * dh) @ p["wo"], cache


def decode_cross_latent(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig):
    y = L.cross_attention_latent(
        p, x, (cache["zk"].astype(x.dtype), cache["zv"].astype(x.dtype)), cfg)
    return y, cache
