"""Model zoo: one functional transformer covering all assigned families."""

from repro.models.config import (
    MLAConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    RGLRUConfig,
    ReCalKVRuntime,
)
from repro.models.transformer import (
    decode_step,
    forward_hidden,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "MLAConfig", "MambaConfig", "MoEConfig", "ModelConfig", "RGLRUConfig",
    "ReCalKVRuntime", "decode_step", "forward_hidden", "init_decode_cache",
    "init_params", "loss_fn", "prefill",
]
