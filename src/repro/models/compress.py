"""Model-level ReCalKV compression: dense checkpoint -> latent-KV model.

Operates on *unrolled* models (cfg.scan_layers=False), which is where the
Fisher-guided per-layer rank allocation lives (scanned production configs
use uniform ranks so the period params stack).

Flow (paper Algorithm 1, at model scope):
    stats  = capture_calibration(cfg, params, batches)     # X^T X per layer
    fk, fv = fisher_scores(cfg, params, batches)           # dL/dW_k|v squared
    cfg2, params2 = compress_model(cfg, params, stats, recal_cfg, fk, fv)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import pipeline as P
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig, ReCalKVRuntime

SELF_ATTN = ("attn", "attn_dense", "local", "attn_cross")


def _unrolled(cfg: ModelConfig) -> tuple[str, ...]:
    if cfg.scan_layers:
        raise ValueError("compression requires cfg.scan_layers=False")
    return cfg.expanded_layers()


def attn_layer_indices(cfg: ModelConfig) -> list[int]:
    """Indices (into the unrolled stack) of self-attention layers."""
    return [i for i, k in enumerate(_unrolled(cfg)) if k in SELF_ATTN]


def capture_calibration(cfg: ModelConfig, params, batches) -> list[P.CalibStats]:
    """Per-self-attention-layer input (post-ln1) second moments."""
    kinds = _unrolled(cfg)

    def hidden_taps(tokens, source=None):
        B, Tn = tokens.shape
        if cfg.encoder_decoder and source is not None:
            source = T.encode(cfg, params, source)
        ctx = {"positions": jnp.broadcast_to(jnp.arange(Tn), (B, Tn)),
               "lengths": jnp.full((B,), Tn, jnp.int32),
               "source": source, "max_len": Tn}
        x = T.embed_tokens(cfg, params, tokens)
        taps = []
        for i, kind in enumerate(kinds):
            p = params["prefix"][i]
            if kind in SELF_ATTN:
                taps.append(L.rmsnorm(x, p["ln1"], cfg.norm_eps))
            x, _, _ = T.block_full(cfg, kind, p, x, ctx, want_cache=False)
        return taps

    tap_fn = jax.jit(hidden_taps)
    stats: list[P.CalibStats] | None = None
    for batch in batches:
        taps = tap_fn(batch["tokens"], batch.get("source"))
        new = [P.collect_stats(t) for t in taps]
        stats = new if stats is None else [
            P.merge_stats(a, b) for a, b in zip(stats, new)
        ]
    return stats


def fisher_scores(cfg: ModelConfig, params, batches) -> tuple[list[float], list[float]]:
    """Summed squared gradients of the LM loss wrt each W_k / W_v."""
    idxs = attn_layer_indices(cfg)

    def loss(p, batch):
        val, _ = T.loss_fn(cfg, p, batch)
        return val

    grad_fn = jax.jit(jax.grad(loss))
    fk = [0.0] * len(idxs)
    fv = [0.0] * len(idxs)
    for batch in batches:
        g = grad_fn(params, batch)
        for j, i in enumerate(idxs):
            ga = g["prefix"][i]["attn"]
            fk[j] += float(jnp.sum(ga["wk"].astype(jnp.float32) ** 2))
            fv[j] += float(jnp.sum(ga["wv"].astype(jnp.float32) ** 2))
    return fk, fv


def _to_latent_params(attn_p: dict, ca: P.CompressedAttention, dtype) -> dict:
    out = {
        "wq": ca.W_q.astype(dtype),
        "l_k": ca.L_k.astype(dtype),
        "r_k": ca.R_k.astype(dtype),
        "l_v": ca.L_v.astype(dtype),
        "wo_fused": ca.W_o_fused.astype(dtype),
    }
    for extra in ("q_norm", "k_norm"):
        if extra in attn_p:
            out[extra] = attn_p[extra]
    return out


def compress_model(
    cfg: ModelConfig,
    params,
    stats: Sequence[P.CalibStats],
    recal_cfg: P.ReCalKVConfig,
    fisher_k: Sequence[float] | None = None,
    fisher_v: Sequence[float] | None = None,
):
    """Returns (compressed_cfg, compressed_params).

    Self-attention layers get HSR keys + OCMF values; cross-attention
    layers (if any) are compressed with identity stats (their K/V source
    is the frontend stub).  MLA / attention-free layers pass through
    untouched (DESIGN.md §Arch-applicability).
    """
    kinds = _unrolled(cfg)
    if cfg.mla is not None:
        raise ValueError("MLA models already cache latents; nothing to do")
    idxs = attn_layer_indices(cfg)
    if len(stats) != len(idxs):
        raise ValueError(f"need {len(idxs)} stats, got {len(stats)}")

    weights = []
    for i in idxs:
        a = params["prefix"][i]["attn"]
        weights.append(P.AttnWeights(
            W_q=a["wq"], W_k=a["wk"], W_v=a["wv"], W_o=a["wo"],
            num_q_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        ))
    compressed = P.compress_model_layers(
        weights, list(stats), recal_cfg, fisher_k, fisher_v
    )

    new_prefix = list(params["prefix"])
    for j, i in enumerate(idxs):
        blk = dict(new_prefix[i])
        blk["attn"] = _to_latent_params(blk["attn"], compressed[j], cfg.dtype)
        new_prefix[i] = blk

    # Cross-attention layers: same machinery, identity stats (stub source).
    d = cfg.d_model
    for i, kind in enumerate(kinds):
        if kind not in ("cross", "attn_cross"):
            continue
        blk = dict(new_prefix[i])
        a = blk["cross"]
        w = P.AttnWeights(
            W_q=a["wq"], W_k=a["wk"], W_v=a["wv"], W_o=a["wo"],
            num_q_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        )
        s = recal_cfg.effective_group_size(cfg.num_kv_heads)
        width = s * cfg.d_head
        rk = compressed[0].rank_k if compressed else recal_cfg.rank_for_width(width)
        ca = P.compress_attention_layer(
            w, P.CalibStats.identity(d), recal_cfg, rk, rk)
        blk["cross"] = _to_latent_params(a, ca, cfg.dtype)
        new_prefix[i] = blk

    new_params = dict(params)
    new_params["prefix"] = tuple(new_prefix)

    r_k = compressed[0].rank_k if compressed else 0
    r_v = compressed[0].rank_v if compressed else 0
    by_layer = [(0, 0)] * cfg.num_layers
    for j, i in enumerate(idxs):
        by_layer[i] = (compressed[j].rank_k, compressed[j].rank_v)
    new_cfg = dataclasses.replace(
        cfg,
        recalkv=ReCalKVRuntime(
            rank_k=r_k, rank_v=r_v,
            group_size=recal_cfg.effective_group_size(cfg.num_kv_heads),
            ranks_by_layer=tuple(by_layer),
        ),
    )
    return new_cfg, new_params
