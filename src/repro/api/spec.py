"""Declarative compression specs — the public configuration surface.

A compression run is described by a :class:`CompressionSpec`:

  method       registry key of a :class:`~repro.api.registry.KVCompressor`
  options      method-specific knobs (override the strategy's defaults)
  rank_policy  how latent ranks are chosen (shared by every SVD-family
               strategy; the old ``ReCalKVConfig`` rank fields live here)

``ReCalKVConfig`` is no longer part of the public API — it is the internal
options object of the SVD-family strategies (see ``repro/api/strategies.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.core import pipeline as P
from repro.core import svd as _svd


@dataclasses.dataclass(frozen=True)
class RankPolicy:
    """How per-layer latent ranks are allocated.

    ``keep_ratio`` is the *kept* fraction of KV-cache bytes (the paper's
    "50% compression" is ``keep_ratio=0.5``).  ``use_fisher`` enables the
    Fisher-guided water-filling allocation across layers; otherwise every
    layer gets the uniform rank for its group width.
    """

    keep_ratio: float = 0.5
    group_size: int = 4
    rank_multiple: int = 8
    min_rank: int = 8
    use_fisher: bool = False
    alpha: float = 0.5
    rho_min: float = 0.0625
    rho_max: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.keep_ratio <= 1.0:
            raise ValueError(f"keep_ratio must be in (0, 1], got {self.keep_ratio}")
        if self.group_size < 1:
            raise ValueError("group_size must be >= 1")

    def rank_for_width(self, width: int) -> int:
        """Uniform rank for a latent group of ``width`` columns."""
        return _svd.effective_rank_for_ratio(
            width, self.keep_ratio, self.rank_multiple, self.min_rank
        )


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """A complete, serializable description of one compression run.

    ``backend`` (when set) selects the attention backend of the produced
    model config — ``"einsum"`` reference or ``"pallas"`` kernels (see
    ``ModelConfig.attn_backend``); ``None`` keeps the source config's
    choice.  It is recorded in the artifact, so ``Engine.from_artifact``
    serves through the chosen backend without re-plumbing.
    """

    method: str = "recalkv"
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    rank_policy: RankPolicy = dataclasses.field(default_factory=RankPolicy)
    backend: str | None = None

    def to_dict(self) -> dict:
        return {
            "method": self.method,
            "options": dict(self.options),
            "rank_policy": dataclasses.asdict(self.rank_policy),
            "backend": self.backend,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CompressionSpec":
        return cls(
            method=d["method"],
            options=dict(d.get("options", {})),
            rank_policy=RankPolicy(**d.get("rank_policy", {})),
            backend=d.get("backend"),
        )


@dataclasses.dataclass(frozen=True)
class CalibrationData:
    """Captured calibration state, reusable across strategies.

    ``stats`` holds one second-moment summary per self-attention layer;
    ``fisher_k``/``fisher_v`` are optional per-layer Fisher scores for the
    rank allocator.  Capture once with :func:`repro.api.calibrate` and feed
    to any number of ``compress`` calls.
    """

    stats: Sequence[P.CalibStats] | None = None
    fisher_k: Sequence[float] | None = None
    fisher_v: Sequence[float] | None = None
    token_count: int = 0
