"""repro.api — the single public entry point for KV-cache compression.

    from repro.api import (CompressionSpec, RankPolicy, calibrate, compress,
                           save_artifact, load_artifact, list_strategies)

Strategies are pluggable (see ``register_strategy``); compressed models
are durable artifacts that round-trip across process boundaries and serve
via ``repro.serving.Engine.from_artifact``, which accepts the serving
knobs re-exported here (``SamplingParams``, ``sync_every``,
``prefill_chunk``).
"""

from repro.api.artifact import (
    CompressionArtifact,
    load_artifact,
    save_artifact,
)
from repro.api.facade import calibrate, compress, serve
from repro.api.registry import (
    KVCompressor,
    get_strategy,
    list_strategies,
    register_strategy,
    unregister_strategy,
)
from repro.api.spec import CalibrationData, CompressionSpec, RankPolicy
from repro.api import strategies as _builtin_strategies  # registers built-ins
from repro.serving.sampler import SamplingParams  # serving-knob re-export

__all__ = [
    "CalibrationData", "CompressionArtifact", "CompressionSpec",
    "KVCompressor", "RankPolicy", "SamplingParams", "calibrate", "compress",
    "get_strategy", "list_strategies", "load_artifact", "register_strategy",
    "save_artifact", "serve", "unregister_strategy",
]
