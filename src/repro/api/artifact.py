"""Durable compression artifacts: compress once, serve forever after.

An artifact bundles the compressed params, the rewritten ``ModelConfig``
(latent-cache runtime shapes included), and provenance (method, options,
rank policy, per-layer ranks, calibration token count).  On disk it reuses
``checkpoint/ckpt.py``'s atomic npz+meta layout:

    <path>/step_00000000/arrays.npz   # compressed params
    <path>/step_00000000/meta.json    # model config + provenance + keys

so a crashed writer never corrupts a loadable artifact, and the loader
needs no model code to reconstruct the param tree (generic unflatten).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.models.config import ModelConfig

ARTIFACT_KIND = "recalkv-compression-artifact"
ARTIFACT_VERSION = 1
_STEP = 0


@dataclasses.dataclass
class CompressionArtifact:
    """A compressed model plus everything needed to serve and audit it."""

    cfg: ModelConfig
    params: Any
    provenance: dict = dataclasses.field(default_factory=dict)

    @property
    def method(self) -> str:
        return self.provenance.get("method", "unknown")

    def save(self, path: str) -> None:
        save_artifact(self, path)

    @classmethod
    def load(cls, path: str) -> "CompressionArtifact":
        return load_artifact(path)


def save_artifact(artifact: CompressionArtifact, path: str) -> None:
    """Atomically persist an artifact under ``path``.

    ``path`` is an artifact directory, not a training-checkpoint directory:
    saving refuses to write next to non-artifact checkpoints (and never
    trims other steps), so it cannot destroy a checkpoint run.
    """
    existing = ckpt.latest_step(path)
    if existing is not None and (
            existing != _STEP
            or ckpt.read_meta(path, existing).get("kind") != ARTIFACT_KIND):
        raise ValueError(
            f"{path!r} already holds a non-artifact checkpoint (step "
            f"{existing}); refusing to overwrite a training-checkpoint "
            "directory")
    tree = {"params": artifact.params}
    ckpt.save(
        path, _STEP, tree, keep_last=0,
        extra_meta={
            "kind": ARTIFACT_KIND,
            "version": ARTIFACT_VERSION,
            "model_config": artifact.cfg.to_dict(),
            "provenance": artifact.provenance,
            "tuple_paths": ckpt.tuple_paths(tree),
        })


def load_artifact(path: str) -> CompressionArtifact:
    """Load an artifact saved by :func:`save_artifact` (any process)."""
    step = ckpt.latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no compression artifact under {path!r}")
    meta = ckpt.read_meta(path, step)
    if meta.get("kind") != ARTIFACT_KIND:
        raise ValueError(f"{path!r} is not a compression artifact "
                         f"(kind={meta.get('kind')!r})")
    cfg = ModelConfig.from_dict(meta["model_config"])
    tree = ckpt.unflatten(ckpt.load_flat(path, step),
                          seq_paths=meta.get("tuple_paths"))
    params = jax.tree.map(jnp.asarray, tree["params"])
    return CompressionArtifact(cfg=cfg, params=params,
                               provenance=meta.get("provenance", {}))
