"""String-keyed registry of pluggable KV-cache compression strategies.

A strategy is any object satisfying the :class:`KVCompressor` protocol —
it consumes a dense model plus calibration data and returns the compressed
``(ModelConfig, params, info)`` triple.  Built-in strategies register at
import time (``repro/api/strategies.py``); downstream code registers its
own with :func:`register_strategy`:

    @register_strategy
    class MyCompressor:
        name = "my-method"
        def compress(self, cfg, params, spec, calib): ...
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

from repro.api.spec import CalibrationData, CompressionSpec
from repro.models.config import ModelConfig


@runtime_checkable
class KVCompressor(Protocol):
    """Strategy protocol: dense checkpoint -> latent-cache model."""

    name: str

    def compress(
        self,
        cfg: ModelConfig,
        params: Any,
        spec: CompressionSpec,
        calib: CalibrationData,
    ) -> tuple[ModelConfig, Any, dict]:
        """Returns (compressed_cfg, compressed_params, info_dict)."""
        ...


_REGISTRY: dict[str, KVCompressor] = {}


def register_strategy(strategy=None, *, replace: bool = False):
    """Register a KVCompressor instance or class (usable as a decorator).

    Classes are instantiated with no arguments; instances are stored as-is.
    Registration keys on ``strategy.name``.
    """
    if strategy is None:
        return lambda s: register_strategy(s, replace=replace)
    inst = strategy() if isinstance(strategy, type) else strategy
    name = getattr(inst, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"strategy {inst!r} needs a non-empty string .name")
    if not callable(getattr(inst, "compress", None)):
        raise TypeError(f"strategy {name!r} has no compress() method")
    if name in _REGISTRY and not replace:
        raise ValueError(f"strategy {name!r} already registered "
                         "(pass replace=True to override)")
    _REGISTRY[name] = inst
    return strategy


def unregister_strategy(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_strategy(name: str) -> KVCompressor:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown compression strategy {name!r}; "
            f"registered: {list_strategies()}") from None


def list_strategies() -> list[str]:
    """Sorted names of every registered strategy."""
    return sorted(_REGISTRY)
