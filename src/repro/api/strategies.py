"""Built-in KV-compression strategies.

The SVD family is one parametric compressor; each registry entry is a
preconfigured instance, so baselines and ablations are first-class names
instead of hand-toggled booleans:

  recalkv        HSR head reordering + whitened SVD + offline calibration
                 (the paper's full Algorithm 1)
  recalkv-hsr    HSR only (paper Table 3 "HSR" row)
  recalkv-calib  offline calibration only (paper Table 3 "calib" row)
  whitened-svd   SVD-LLM-style whitening only (Palu G-LRD + whitening)
  grouped-svd    plain grouped SVD — no reordering, no data awareness

``quantized-latent`` composes: it runs any base strategy, then fake-
quantizes the latent factors via ``repro/quant`` (optionally after a
folded randomized-Hadamard rotation of the latent space).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

import repro.models.compress as C
from repro.api.registry import get_strategy, register_strategy
from repro.api.spec import CalibrationData, CompressionSpec
from repro.core import pipeline as P
from repro.models.config import ModelConfig
from repro.quant import fake_quant, hadamard_transform


def _merged_options(defaults: dict, spec: CompressionSpec, name: str) -> dict:
    opts = dict(defaults)
    unknown = set(spec.options) - set(defaults)
    if unknown:
        raise ValueError(f"{name}: unknown options {sorted(unknown)}; "
                         f"accepted: {sorted(defaults)}")
    opts.update(spec.options)
    return opts


@dataclasses.dataclass(frozen=True)
class SVDCompressor:
    """Grouped low-rank K/V factorization with optional HSR / whitening /
    calibration — covers the whole ReCalKV ablation family."""

    name: str
    use_hsr: bool
    use_calibration: bool
    use_whitening: bool
    calib_iters: int = 8

    def _option_defaults(self) -> dict:
        return {
            "use_hsr": self.use_hsr,
            "use_calibration": self.use_calibration,
            "use_whitening": self.use_whitening,
            "calib_iters": self.calib_iters,
        }

    def _recal_config(self, spec: CompressionSpec, opts: dict) -> P.ReCalKVConfig:
        pol = spec.rank_policy
        return P.ReCalKVConfig(
            keep_ratio=pol.keep_ratio,
            group_size=pol.group_size,
            use_hsr=opts["use_hsr"],
            use_calibration=opts["use_calibration"],
            use_whitening=opts["use_whitening"],
            use_fisher=pol.use_fisher,
            calib_iters=opts["calib_iters"],
            rank_multiple=pol.rank_multiple,
            min_rank=pol.min_rank,
            alpha=pol.alpha,
            rho_min=pol.rho_min,
            rho_max=pol.rho_max,
        )

    def compress(self, cfg: ModelConfig, params: Any, spec: CompressionSpec,
                 calib: CalibrationData) -> tuple[ModelConfig, Any, dict]:
        opts = _merged_options(self._option_defaults(), spec, self.name)
        rc = self._recal_config(spec, opts)
        if spec.rank_policy.use_fisher and calib.fisher_k is None:
            raise ValueError(
                f"{self.name}: rank_policy.use_fisher=True but the "
                "calibration data carries no Fisher scores — capture with "
                "calibrate(..., fisher=True)")
        stats = calib.stats
        data_aware = opts["use_whitening"] or opts["use_calibration"]
        if stats is None:
            if data_aware:
                raise ValueError(
                    f"{self.name}: whitening/calibration need calibration "
                    "data — pass calib batches (or use 'grouped-svd')")
            stats = [P.CalibStats.identity(cfg.d_model)
                     for _ in C.attn_layer_indices(cfg)]
        ccfg, cparams = C.compress_model(
            cfg, params, stats, rc, calib.fisher_k, calib.fisher_v)
        return ccfg, cparams, {"options": opts}


@dataclasses.dataclass(frozen=True)
class QuantizedLatentCompressor:
    """Composition wrapper: run ``base``, then fake-quantize the latent
    factors (L_k, R_k, L_v) at ``bits``; with ``hadamard=True`` a seeded
    randomized-Hadamard rotation of the latent space is folded into the
    factors first (and inverted through the fused output projection), so
    outlier channels are flattened before rounding — exactly the rotation a
    deployment would fuse offline (Table 4)."""

    name: str = "quantized-latent"

    def _option_defaults(self) -> dict:
        return {"base": "recalkv", "bits": 8, "hadamard": False,
                "base_options": {}}

    def compress(self, cfg: ModelConfig, params: Any, spec: CompressionSpec,
                 calib: CalibrationData) -> tuple[ModelConfig, Any, dict]:
        opts = _merged_options(self._option_defaults(), spec, self.name)
        if opts["base"] == self.name:
            raise ValueError("quantized-latent cannot wrap itself")
        base = get_strategy(opts["base"])
        base_spec = CompressionSpec(method=opts["base"],
                                    options=dict(opts["base_options"]),
                                    rank_policy=spec.rank_policy)
        ccfg, cparams, info = base.compress(cfg, params, base_spec, calib)
        cparams = _quantize_latent_factors(
            cparams, bits=opts["bits"], hadamard=opts["hadamard"])
        info = dict(info)
        info.update(base=opts["base"], bits=opts["bits"],
                    hadamard=opts["hadamard"])
        return ccfg, cparams, info


def _rotate_left_inverse(w):
    """Apply the inverse Hadamard rotation along axis -2 (the latent rank
    axis of R_k / W~_o), compensating a forward rotation of the latents."""
    return hadamard_transform(jnp.swapaxes(w, -1, -2)).swapaxes(-1, -2)


def _quantize_latent_factors(params, *, bits: int, hadamard: bool):
    """Fake-quantize the low-rank factors (L_k, R_k, L_v) of every latent
    block — weight-space PTQ of the factorization the compressor emitted,
    NOT runtime quantization of the cached activations z = x @ L.

    Latent blocks are recognized by their ``l_k`` key (self- and cross-
    attention alike).  ``wo_fused`` stays full precision — it is a fused
    dense projection, not a factor — but is rotated to undo the L_v
    rotation so the model stays consistent.
    """
    def one_block(p: dict) -> dict:
        p = dict(p)
        l_k, r_k, l_v = p["l_k"], p["r_k"], p["l_v"]
        if hadamard:
            l_k = hadamard_transform(l_k)
            r_k = _rotate_left_inverse(r_k)
            l_v = hadamard_transform(l_v)
            p["wo_fused"] = _rotate_left_inverse(p["wo_fused"])
        p["l_k"] = fake_quant(l_k, bits)
        p["r_k"] = fake_quant(r_k, bits)
        p["l_v"] = fake_quant(l_v, bits)
        return p

    new_prefix = []
    for blk in params["prefix"]:
        blk = dict(blk)
        for sub in ("attn", "cross"):
            if sub in blk and isinstance(blk[sub], dict) and "l_k" in blk[sub]:
                blk[sub] = one_block(blk[sub])
        new_prefix.append(blk)
    out = dict(params)
    out["prefix"] = tuple(new_prefix)
    return out


register_strategy(SVDCompressor(
    "recalkv", use_hsr=True, use_calibration=True, use_whitening=True))
register_strategy(SVDCompressor(
    "recalkv-hsr", use_hsr=True, use_calibration=False, use_whitening=True))
register_strategy(SVDCompressor(
    "recalkv-calib", use_hsr=False, use_calibration=True, use_whitening=True))
register_strategy(SVDCompressor(
    "whitened-svd", use_hsr=False, use_calibration=False, use_whitening=True))
register_strategy(SVDCompressor(
    "grouped-svd", use_hsr=False, use_calibration=False, use_whitening=False))
register_strategy(QuantizedLatentCompressor())
