"""The three public verbs: ``calibrate`` once, ``compress`` many times,
``serve`` anywhere.

    from repro.api import CompressionSpec, RankPolicy, calibrate, compress
    from repro.launch import make_production_mesh

    calib = calibrate(cfg, params, batches, fisher=True)
    art = compress(cfg, params,
                   CompressionSpec("recalkv",
                                   rank_policy=RankPolicy(keep_ratio=0.5)),
                   calib)
    art.save("experiments/qwen3_r50")      # later: serve(...)
    eng = serve(art, max_slots=128, max_len=32768,
                mesh=make_production_mesh())

``compress`` also accepts the raw calibration batches directly (it will
capture stats — and Fisher scores when the rank policy asks — itself) and
a bare method name instead of a full spec.  ``serve`` boots the
mesh-native continuous-batching engine from an artifact (in-memory or a
saved path) — the compress-offline / serve-forever workflow in one call.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import repro.models.compress as C
from repro.api.artifact import CompressionArtifact
from repro.api.registry import get_strategy
from repro.api.spec import CalibrationData, CompressionSpec
from repro.models.config import ModelConfig


def calibrate(cfg: ModelConfig, params: Any, batches: Sequence[dict], *,
              fisher: bool = False) -> CalibrationData:
    """Run the calibration forward passes once and summarize them.

    ``batches`` are dicts with "tokens" (and "labels" when ``fisher``,
    which additionally captures per-layer Fisher scores for the rank
    allocator).  The result is strategy-agnostic — capture once, reuse
    across every ``compress`` call.
    """
    batches = list(batches)
    stats = C.capture_calibration(cfg, params, batches)
    fk, fv = C.fisher_scores(cfg, params, batches) if fisher else (None, None)
    tokens = sum(int(b["tokens"].size) for b in batches)
    return CalibrationData(stats=stats, fisher_k=fk, fisher_v=fv,
                           token_count=tokens)


def _as_spec(spec) -> CompressionSpec:
    if isinstance(spec, CompressionSpec):
        return spec
    if isinstance(spec, str):
        return CompressionSpec(method=spec)
    raise TypeError(f"spec must be a CompressionSpec or method name, "
                    f"got {type(spec).__name__}")


def compress(cfg: ModelConfig, params: Any,
             spec: CompressionSpec | str = "recalkv",
             calib: CalibrationData | Sequence[dict] | None = None,
             ) -> CompressionArtifact:
    """Compress a dense checkpoint with a registered strategy.

    Returns a durable :class:`CompressionArtifact`; ``artifact.cfg`` /
    ``artifact.params`` plug into every forward/serving entry point, and
    ``save_artifact`` persists the bundle across process boundaries.
    """
    spec = _as_spec(spec)
    strategy = get_strategy(spec.method)
    if calib is None:
        calib = CalibrationData()
    elif not isinstance(calib, CalibrationData):
        calib = calibrate(cfg, params, calib,
                          fisher=spec.rank_policy.use_fisher)
    ccfg, cparams, info = strategy.compress(cfg, params, spec, calib)
    if spec.backend is not None:
        ccfg = dataclasses.replace(ccfg, attn_backend=spec.backend)
    provenance = {
        "method": spec.method,
        "spec": spec.to_dict(),
        "calib_tokens": calib.token_count,
        "fisher": calib.fisher_k is not None and spec.rank_policy.use_fisher,
        **info,
    }
    if ccfg.recalkv is not None:
        provenance["group_size"] = ccfg.recalkv.group_size
        provenance["ranks_by_layer"] = (
            None if ccfg.recalkv.ranks_by_layer is None
            else [list(r) for r in ccfg.recalkv.ranks_by_layer])
    return CompressionArtifact(cfg=ccfg, params=cparams,
                               provenance=provenance)


def serve(artifact: CompressionArtifact | str, *, max_slots: int,
          max_len: int, mesh=None, **engine_kw):
    """Boot a serving :class:`repro.serving.Engine` from a compression
    artifact — either the in-memory result of :func:`compress` or a path
    produced by ``save_artifact``.

    ``mesh`` (a ("data", "model") jax Mesh, see ``repro.launch.mesh``)
    makes the engine mesh-native: params placed by the sharding rules,
    the cache pool sharded slot x sequence, and the fused decode window
    jitted with explicit in/out shardings.  Omitted, the same code path
    runs on a degenerate single-device mesh.  Remaining ``engine_kw``
    (``sampling``, ``sync_every``, ``prefill_chunk``, ``backend``,
    ``source``, the speculative-decoding pair ``spec_depth`` /
    ``draft``, the paged-cache trio ``cache_layout`` / ``page_size`` /
    ``n_pages`` — ``cache_layout="paged"`` pools cache pages across
    slots with copy-on-write prompt-prefix sharing — the pipeline
    knobs ``overlap`` / ``aot`` / ``pipeline_depth`` / ``continuous`` /
    ``admission_thread`` (N-deep window pipeline, device-side mid-window
    slot swap, threaded admission prefill, AOT-compiled executables),
    the admission-policy trio ``policy`` / ``lazy_pages`` /
    ``staging_depth`` — ``policy`` picks the admission order ("fifo",
    "prefix-affinity", "reach-packing", or an ``AdmissionPolicy``
    instance), ``lazy_pages`` allocates cache pages as generation
    reaches them (preempting a policy-chosen victim on pool
    exhaustion), ``staging_depth`` bounds the admission worker's
    look-ahead — plus ``adaptive_spec``, ``pin_prefixes`` and
    ``profile``; token streams are invariant to all of these) pass
    through to the Engine."""
    from repro.serving.engine import Engine  # local: engine imports api too

    if isinstance(artifact, str):
        return Engine.from_artifact(artifact, max_slots=max_slots,
                                    max_len=max_len, mesh=mesh, **engine_kw)
    return Engine(artifact.cfg, artifact.params, max_slots=max_slots,
                  max_len=max_len, mesh=mesh, **engine_kw)
