"""Deterministic synthetic LM data — stateless, index-addressable.

Every (seed, split, index) maps to one sequence via counter-based RNG
(numpy Philox), so:
  * any rank can materialize any shard without replay (straggler
    re-assignment and elastic rescaling need no pipeline state);
  * restarts are exactly reproducible from the step counter alone.

The corpus mixes a learned-structure Markov chain with long-range COPY
spans (a random early segment is repeated verbatim later).  The copy task
makes held-out loss *sensitive to KV-cache fidelity* — exactly what the
paper's quality tables measure — while the Markov component gives the
model local statistics to learn.  WikiText-2 is unavailable offline; the
method is data-agnostic (DESIGN.md deviation #4).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    copy_frac: float = 0.35      # fraction of sequences carrying a copy span
    copy_len: int = 32
    markov_order: int = 1
    seed: int = 1234


SPLITS = {"train": 0, "valid": 1, "calib": 2}


def _rng(cfg: DataConfig, split: str, index: int) -> np.random.Generator:
    # Philox takes a 128-bit key (2 x uint64): mix (seed, split) | index.
    return np.random.Generator(np.random.Philox(
        key=[(cfg.seed << 8) ^ SPLITS[split], index]))


def _transition(cfg: DataConfig) -> np.ndarray:
    """Shared sparse-ish Markov transition matrix (same for all sequences)."""
    g = np.random.Generator(np.random.Philox(key=[cfg.seed, 77]))
    V = cfg.vocab_size
    logits = g.normal(size=(V, V)) * 2.0
    # sparsify: each token prefers ~16 successors
    keep = np.argsort(logits, axis=1)[:, -16:]
    mask = np.full((V, V), -1e9)
    np.put_along_axis(mask, keep, 0.0, axis=1)
    p = np.exp(logits + mask)
    return p / p.sum(axis=1, keepdims=True)


_TRANS_CACHE: dict[tuple, np.ndarray] = {}


def sequence(cfg: DataConfig, split: str, index: int) -> np.ndarray:
    key = (cfg.seed, cfg.vocab_size)
    if key not in _TRANS_CACHE:
        _TRANS_CACHE[key] = _transition(cfg)
    trans = _TRANS_CACHE[key]
    g = _rng(cfg, split, index)
    V, T = cfg.vocab_size, cfg.seq_len
    toks = np.empty(T, np.int64)
    toks[0] = g.integers(V)
    u = g.random(T)
    for t in range(1, T):
        toks[t] = np.searchsorted(np.cumsum(trans[toks[t - 1]]), u[t])
    toks = np.clip(toks, 0, V - 1)
    if g.random() < cfg.copy_frac and T >= 4 * cfg.copy_len:
        src = g.integers(0, T // 2 - cfg.copy_len)
        dst = g.integers(T // 2, T - cfg.copy_len)
        toks[dst : dst + cfg.copy_len] = toks[src : src + cfg.copy_len]
    return toks


def batch(cfg: DataConfig, split: str, step: int, batch_size: int,
          shard: int = 0, num_shards: int = 1) -> dict[str, np.ndarray]:
    """Global batch ``step``'s shard: (B_local, T) tokens + shifted labels."""
    if batch_size % num_shards:
        raise ValueError("batch not divisible by shards")
    local = batch_size // num_shards
    base = step * batch_size + shard * local
    toks = np.stack([sequence(cfg, split, base + i) for i in range(local)])
    labels = np.concatenate(
        [toks[:, 1:], np.full((local, 1), -1, np.int64)], axis=1)
    return {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32)}


def batches(cfg: DataConfig, split: str, num_steps: int, batch_size: int,
            start_step: int = 0):
    for s in range(start_step, start_step + num_steps):
        yield batch(cfg, split, s, batch_size)
