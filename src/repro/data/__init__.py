from repro.data.synthetic import DataConfig, batch, batches, sequence

__all__ = ["DataConfig", "batch", "batches", "sequence"]
