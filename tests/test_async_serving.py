"""Overlapped-pipeline parity and lifecycle.

The overlapped engine (``overlap=True``) keeps ``pipeline_depth``
decode windows in flight, admits concurrently with in-flight decode
(staging prefill on a worker thread), hands token harvesting to a
backlog worker thread, and — with ``continuous=True`` — installs staged
requests into freed slots INSIDE the fused scan — none of which may
change a single emitted token.  Every test here pins the async engine's streams
TOKEN-FOR-TOKEN to the blocking engine's across cache variants,
backends, layouts, speculation, and (in the `mesh` CI job) a forced
(2, 4) host mesh, and checks the structural contracts the pipeline adds:
one device sync per *trailing* window, flat trace counts under AOT, and
a backlog thread that drains and joins on ``close``.
"""

import dataclasses
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serving import Engine, Request, SamplingParams

KEY = jax.random.PRNGKey(0)

CASES = {
    "dense": {},
    "latent": {"recalkv_ratio": 0.5},
    "int8_latent": {"recalkv_ratio": 0.5, "cache_quant_bits": 8},
}

SAMPLED = SamplingParams(temperature=0.9, top_k=32, top_p=0.9, seed=11)

_MODELS = {}


def _model(case):
    """Config + params, cached per case — every test reuses one model."""
    if case not in _MODELS:
        extra = CASES[case]
        kw = {k: extra[k] for k in ("recalkv_ratio",) if k in extra}
        cfg = get_config("qwen3-4b", smoke=True, **kw)
        cfg = dataclasses.replace(
            cfg, dtype=jnp.float32,
            **{k: v for k, v in extra.items() if k == "cache_quant_bits"})
        _MODELS[case] = (cfg, T.init_params(cfg, KEY))
    return _MODELS[case]


def _prompts(cfg, n=6, seed=3):
    g = np.random.default_rng(seed)
    return [g.integers(0, cfg.vocab_size, 5 + 2 * i).astype(np.int32)
            for i in range(n)]


def _serve(cfg, params, prompts, *, sampling=None, max_new=6, **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=40, sampling=sampling,
                 **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
    done = eng.run()
    eng.close()
    return {r.uid: r.out_tokens for r in done}, eng


class TestAsyncStreamParity:
    """overlap=True must be stream-invariant: same tokens, same order,
    per request, as the blocking engine — greedy and sampled."""

    @pytest.mark.parametrize("case,backend", [
        ("dense", "einsum"), ("latent", "einsum"),
        ("int8_latent", "einsum"), ("latent", "pallas"),
    ])
    def test_greedy_streams_match_sync(self, case, backend):
        cfg, params = _model(case)
        cfg = dataclasses.replace(cfg, attn_backend=backend)
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True)
        assert eng.overlap
        assert got == ref, (case, backend)

    def test_sampled_mixed_load_matches_sync(self):
        """Mixed greedy/sampled requests through chunked prefill: the
        shared jitted admission sampler + per-slot key chains make the
        async first tokens (and everything after) bitwise equal."""
        cfg, params = _model("latent")
        g = np.random.default_rng(21)
        reqs = [(g.integers(0, cfg.vocab_size,
                            int(g.integers(3, 30))).astype(np.int32),
                 SAMPLED if i % 2 else None) for i in range(6)]

        def serve(overlap):
            eng = Engine(cfg, params, max_slots=4, max_len=40,
                         prefill_chunk=6, sync_every=4, overlap=overlap)
            for i, (pr, sp) in enumerate(reqs):
                eng.submit(Request(uid=i, prompt=pr.copy(),
                                   max_new_tokens=6, sampling=sp))
            done = eng.run()
            eng.close()
            return {r.uid: r.out_tokens for r in done}

        assert serve(True) == serve(False)

    @pytest.mark.parametrize("spec_depth", [0, 2])
    def test_paged_streams_match_sync_ring(self, spec_depth):
        """Paged + overlap (+ speculation) still equals the sync ring
        engine — layout, pipeline, and speculation are all invisible in
        the streams."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True,
                          cache_layout="paged", spec_depth=spec_depth,
                          draft="ngram" if spec_depth else None)
        assert got == ref, spec_depth
        if spec_depth:
            assert eng.metrics()["draft_proposed"] > 0

    def test_layer_draft_spec_matches_sync(self):
        """The self-draft (layers:K) speculative window under overlap:
        accept/residual bookkeeping rides the same packed-status harvest
        and must stay deterministic."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        for sp in (None, SAMPLED):
            ref, _ = _serve(cfg, params, prompts, sampling=sp)
            got, eng = _serve(cfg, params, prompts, sampling=sp,
                              overlap=True, spec_depth=2, draft="layers:2")
            assert got == ref
            assert eng.metrics()["draft_proposed"] > 0

    def test_one_sync_per_trailing_window(self):
        """The pipeline's structural contract: exactly one host sync per
        harvested (trailing) window plus one per admission wave — and the
        busy windows keep the 1-per-sync_every-token bound."""
        cfg, params = _model("latent")
        _, eng = _serve(cfg, params, _prompts(cfg), overlap=True,
                        max_new=16, sync_every=4)
        m = eng.metrics()
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m
        assert m["host_syncs"] < m["tokens"], m
        decode_tokens = round(m["windows"] / m["decode_syncs_per_token"])
        busy = (m["windows"] - m["windows_idle"]) / max(decode_tokens, 1)
        assert busy <= 1.0 / 4 + 1e-9, m

    def test_overlap_metrics_shape(self):
        cfg, params = _model("latent")
        _, eng = _serve(cfg, params, _prompts(cfg), overlap=True)
        m = eng.metrics()
        assert m["overlap"] is True
        assert 0.0 <= m["window_overlap"] <= 1.0
        assert m["ttft_s"] > 0.0
        assert m["windows_idle"] >= 0
        assert m["tokens_per_s"] > 0.0


class TestContinuousBatching:
    """continuous=True: staged requests install into freed slots INSIDE
    the fused scan (device-side mid-window slot swap), at any pipeline
    depth, with admission prefill staged on a worker thread — all of it
    stream-invariant against the blocking engine."""

    @pytest.mark.parametrize("depth,continuous", [
        (2, False), (3, False), (2, True), (3, True),
    ])
    def test_depth_and_swap_parity(self, depth, continuous):
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True,
                          pipeline_depth=depth, continuous=continuous)
        assert got == ref, (depth, continuous)
        m = eng.metrics()
        assert m["pipeline_depth"] == depth
        assert m["continuous"] is continuous
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m

    @pytest.mark.parametrize("case,backend", [
        ("dense", "einsum"), ("int8_latent", "einsum"),
        ("latent", "pallas"),
    ])
    def test_variant_backend_parity(self, case, backend):
        cfg, params = _model(case)
        cfg = dataclasses.replace(cfg, attn_backend=backend)
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, _ = _serve(cfg, params, prompts, overlap=True,
                        pipeline_depth=3, continuous=True)
        assert got == ref, (case, backend)

    @pytest.mark.parametrize("spec_depth", [0, 2])
    def test_paged_continuous_matches_sync_ring(self, spec_depth):
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True,
                          pipeline_depth=3, continuous=True,
                          cache_layout="paged", spec_depth=spec_depth,
                          draft="ngram" if spec_depth else None)
        assert got == ref, spec_depth
        assert eng.metrics()["slot_swaps"] > 0

    def test_chunked_mixed_lengths_match_sync(self):
        """Chunked prefill + staggered budgets: slots free and refill
        mid-window while other slots are still ingesting prompt chunks."""
        cfg, params = _model("latent")
        g = np.random.default_rng(23)
        reqs = [(g.integers(0, cfg.vocab_size,
                            int(g.integers(3, 30))).astype(np.int32),
                 4 + i % 5) for i in range(8)]

        def serve(**kw):
            eng = Engine(cfg, params, max_slots=4, max_len=40,
                         prefill_chunk=6, sync_every=4, **kw)
            for i, (pr, mn) in enumerate(reqs):
                eng.submit(Request(uid=i, prompt=pr.copy(),
                                   max_new_tokens=mn))
            done = eng.run()
            eng.close()
            return {r.uid: r.out_tokens for r in done}

        assert serve(overlap=True, pipeline_depth=3,
                     continuous=True) == serve()

    def test_inline_admission_matches_threaded(self):
        """admission_thread=False stages on the dispatch loop instead of
        the worker — ordering (and therefore streams) cannot differ."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=8)
        ref, et = _serve(cfg, params, prompts, overlap=True,
                         pipeline_depth=3, continuous=True)
        got, ei = _serve(cfg, params, prompts, overlap=True,
                         pipeline_depth=3, continuous=True,
                         admission_thread=False)
        assert got == ref
        assert et.metrics()["admission_thread"] is True
        assert ei.metrics()["admission_thread"] is False
        for eng in (et, ei):
            m = eng.metrics()
            assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m

    def test_saturating_load_swaps_in_scan(self):
        """More requests than slots: continuation requests install via
        the device-side staging queue, not boundary placement — the swap
        counter and the sampled-stream parity prove the install path."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=10)
        ref, _ = _serve(cfg, params, prompts, sampling=SAMPLED)
        got, eng = _serve(cfg, params, prompts, sampling=SAMPLED,
                          overlap=True, pipeline_depth=3, continuous=True)
        assert got == ref
        m = eng.metrics()
        assert m["slot_swaps"] > 0
        assert m["occupancy_device_mean"] > 0.0
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m

    def test_profile_records_stage_timeline(self):
        cfg, params = _model("latent")
        _, eng = _serve(cfg, params, _prompts(cfg), overlap=True,
                        pipeline_depth=3, continuous=True, profile=True)
        prof = eng.metrics()["profile"]
        for stage in ("dispatch", "harvest", "bookkeep",
                      "admission_stage", "backlog_drain",
                      "admission_worker"):
            assert stage in prof["seconds"], prof
            assert prof["seconds"][stage] >= 0.0
        assert sum(prof["shares"].values()) == pytest.approx(1.0)
        assert eng._prof_events                   # profile=True: timeline on
        assert all(set(e) == {"stage", "t", "dur"}
                   for e in eng._prof_events)
        # always-on aggregate, opt-in timeline: no profile, no events
        _, eng2 = _serve(cfg, params, _prompts(cfg, n=2), overlap=True)
        assert eng2.metrics()["profile"]["seconds"]["dispatch"] >= 0.0
        assert not eng2._prof_events

    def test_bad_configs_rejected(self):
        cfg, params = _model("latent")
        with pytest.raises(ValueError, match="pipeline_depth"):
            Engine(cfg, params, max_slots=2, max_len=40, overlap=True,
                   pipeline_depth=0)
        with pytest.raises(ValueError):
            Engine(cfg, params, max_slots=2, max_len=40,
                   pipeline_depth=3)            # depth needs overlap
        with pytest.raises(ValueError):
            Engine(cfg, params, max_slots=2, max_len=40, continuous=True)
        with pytest.raises(ValueError, match="layer"):
            Engine(cfg, params, max_slots=2, max_len=40, overlap=True,
                   continuous=True, spec_depth=2, draft="layers:2")


class TestAdaptiveSpec:
    """adaptive_spec=True: a slot whose draft acceptance stays under the
    floor after enough proposals is degraded to plain decode at a window
    boundary — output streams are invariant (verification would have
    rejected those drafts anyway)."""

    def test_streams_invariant_and_degrades_cold_drafts(self):
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, max_new=16)
        # layers:2 over random-init weights: acceptance ~0, so every slot
        # crosses ADAPTIVE_MIN_PROPOSED with a sub-floor accept rate
        got, eng = _serve(cfg, params, prompts, max_new=16, spec_depth=2,
                          draft="layers:2", adaptive_spec=True)
        assert got == ref
        m = eng.metrics()
        assert m["adaptive_spec"] is True
        assert m["spec_degraded"] > 0, m

    def test_overlap_continuous_parity_and_metric(self):
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=8)
        ref, _ = _serve(cfg, params, prompts, max_new=12)
        got, eng = _serve(cfg, params, prompts, max_new=12, overlap=True,
                          pipeline_depth=3, continuous=True, spec_depth=2,
                          draft="ngram", adaptive_spec=True)
        assert got == ref
        assert eng.metrics()["spec_degraded"] >= 0

    def test_requires_speculation(self):
        cfg, params = _model("latent")
        with pytest.raises(ValueError, match="spec_depth"):
            Engine(cfg, params, max_slots=2, max_len=40,
                   adaptive_spec=True)


class TestAOT:
    def test_aot_no_retrace_and_stream_parity(self):
        """AOT compiles the window exactly once and every prefill bucket
        at construction; serving must not trace anything new (the
        trace-count hook is the first-token-latency regression guard),
        and the streams still equal the sync engine's."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        eng = Engine(cfg, params, max_slots=4, max_len=40, overlap=True,
                     aot=True)
        compiled = dict(eng.trace_counts)
        assert compiled["window"] == 1
        assert compiled["prefill"] > 0
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=6))
        done = eng.run()
        eng.close()
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.trace_counts == compiled, "serving retraced an executable"

    def test_aot_continuous_depth3_no_retrace(self):
        """The continuous window (carry + staging-queue signature) AOT-
        compiles once; a full depth-3 continuous serve — staging
        scatters, in-scan installs, gen-guarded refills — must not trace
        anything new."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=8)
        ref, _ = _serve(cfg, params, prompts)
        eng = Engine(cfg, params, max_slots=4, max_len=40, overlap=True,
                     aot=True, pipeline_depth=3, continuous=True)
        compiled = dict(eng.trace_counts)
        assert compiled["window"] == 1
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=6))
        done = eng.run()
        eng.close()
        assert {r.uid: r.out_tokens for r in done} == ref
        assert eng.trace_counts == compiled, "serving retraced an executable"
        assert eng.metrics()["slot_swaps"] > 0

    def test_aot_sync_engine_matches(self):
        """aot is orthogonal to overlap: the blocking engine driven off
        AOT executables emits identical streams too."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=4)
        ref, _ = _serve(cfg, params, prompts)
        got, _ = _serve(cfg, params, prompts, aot=True)
        assert got == ref


class TestLifecycle:
    def test_backlog_thread_drains_and_joins_on_close(self):
        cfg, params = _model("latent")
        eng = Engine(cfg, params, max_slots=4, max_len=40, overlap=True)
        for i, pr in enumerate(_prompts(cfg, n=4)):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=6))
        done = eng.run()
        assert eng._backlog.started          # pipeline actually used it
        eng.close()
        assert not eng._backlog.alive
        assert not [t for t in threading.enumerate()
                    if t.name == "token-backlog"]
        assert all(r.out_tokens for r in done)
        eng.close()                          # idempotent

    def test_context_manager_closes(self):
        cfg, params = _model("latent")
        with Engine(cfg, params, max_slots=4, max_len=40,
                    overlap=True) as eng:
            eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                               max_new_tokens=4))
            eng.run()
        assert not eng._backlog.alive

    def test_run_timeout_counts_completed_windows_and_flushes(self):
        """run(max_steps) under overlap: the bound ticks on HARVESTED
        windows (not dispatches), the warning reports completed windows,
        and the backlog is flushed so the partial streams are whole."""
        cfg, params = _model("latent")
        eng = Engine(cfg, params, max_slots=2, max_len=40, overlap=True,
                     sync_every=2)
        for i in range(4):
            eng.submit(Request(uid=i, prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=30))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng.run(max_steps=1)
        msgs = [str(x.message) for x in w
                if issubclass(x.category, RuntimeWarning)]
        assert any("completed windows" in m and "max_steps=1" in m
                   for m in msgs), msgs
        assert not eng._inflight              # flushed on timeout
        assert eng.windows >= 1
        u = eng.unfinished
        assert u["queued"] + u["in_flight"] > 0
        # the streams that did come out are settled (backlog drained)
        emitted = sum(len(r.out_tokens) for r in eng.scheduler.slot_req
                      if r is not None)
        assert emitted == eng.metrics()["tokens"]
        eng.close()

    def test_on_token_streaming_order(self):
        """Request.on_token fires once per token, in stream order, on the
        backlog worker — the callback view equals out_tokens."""
        cfg, params = _model("latent")
        seen = {}

        def serve(overlap):
            seen.clear()
            eng = Engine(cfg, params, max_slots=4, max_len=40,
                         overlap=overlap)
            for i, pr in enumerate(_prompts(cfg, n=4)):
                eng.submit(Request(
                    uid=i, prompt=pr.copy(), max_new_tokens=6,
                    on_token=lambda r, t: seen.setdefault(r.uid,
                                                          []).append(t)))
            done = eng.run()
            eng.close()
            assert seen == {r.uid: r.out_tokens for r in done}
            return dict(seen)

        assert serve(True) == serve(False)

    def test_prefix_resurrection_across_generations(self):
        """Paged engine: after every holder of a shared prompt prefix
        retires, its pages sit refcount-0 on the LRU free list with their
        registry keys intact — a later request with the same prefix
        revives them instead of re-prefilling fresh pages."""
        cfg, params = _model("latent")
        g = np.random.default_rng(7)
        sysp = g.integers(0, cfg.vocab_size, 16).astype(np.int32)

        def load(uids):
            return [Request(uid=u, prompt=np.concatenate(
                [sysp, g.integers(0, cfg.vocab_size, 3).astype(np.int32)]),
                max_new_tokens=4) for u in uids]

        eng = Engine(cfg, params, max_slots=4, max_len=40,
                     cache_layout="paged", page_size=8, overlap=True)
        for r in load(range(2)):
            eng.submit(r)
        eng.run()                    # first generation retires fully
        for r in load(range(2, 4)):
            eng.submit(r)
        eng.run()
        eng.close()
        m = eng.metrics()
        assert m["prefix_resurrections"] > 0, m
        assert m["pages_shared"] > 0, m


class TestTokenBacklog:
    """The backlog primitive itself (repro.serving.pipeline)."""

    def test_fifo_order_and_lazy_start(self):
        from repro.serving.pipeline import TokenBacklog
        bl = TokenBacklog()
        assert not bl.started                # sync engines never spawn it
        out = []
        for i in range(100):
            bl.put(lambda i=i: out.append(i))
        bl.flush()
        assert out == list(range(100))       # strict put() order
        bl.close()
        assert not bl.alive

    def test_worker_error_reraises_on_main_thread(self):
        from repro.serving.pipeline import TokenBacklog
        bl = TokenBacklog(name="bl-err")
        bl.put(lambda: 1 / 0)
        with pytest.raises(RuntimeError, match="bl-err"):
            bl.flush()
        bl.close()

    def test_close_is_idempotent_and_put_after_close_raises(self):
        from repro.serving.pipeline import TokenBacklog
        bl = TokenBacklog()
        bl.put(lambda: None)
        bl.close()
        bl.close()
        with pytest.raises(RuntimeError, match="closed"):
            bl.put(lambda: None)

    def test_worker_error_reraises_on_put(self):
        """A crash surfaces on the NEXT put too, not only flush/close —
        the dispatch loop must fail fast instead of queueing into a dead
        worker forever."""
        from repro.serving.pipeline import TokenBacklog
        bl = TokenBacklog(name="bl-put-err")
        bl.put(lambda: 1 / 0)
        bl._q.join()                         # item processed, error latched
        with pytest.raises(RuntimeError, match="bl-put-err"):
            bl.put(lambda: None)
        bl.close()

    def test_worker_error_reraises_on_close(self):
        from repro.serving.pipeline import TokenBacklog
        bl = TokenBacklog(name="bl-close-err")
        bl.put(lambda: 1 / 0)
        with pytest.raises(RuntimeError, match="bl-close-err"):
            bl.close()
        bl.close()                           # still idempotent after raise

    def test_error_skips_rest_but_preserves_liveness(self):
        """Items queued after a crash are not executed (the drain guard),
        and the worker still joins cleanly."""
        from repro.serving.pipeline import TokenBacklog
        ran = []
        bl = TokenBacklog(name="bl-skip")
        bl.put(lambda: ran.append(0))
        bl.put(lambda: 1 / 0)
        bl.put(lambda: ran.append(1))        # enqueued before error latched
        with pytest.raises(RuntimeError, match="bl-skip"):
            bl.flush()
        assert ran == [0]                    # post-crash item skipped
        bl.close()
        assert not bl.alive

    def test_close_during_flush_from_another_thread(self):
        """flush() on one thread + close() on another: both return, every
        item runs exactly once, the worker joins."""
        import time
        from repro.serving.pipeline import TokenBacklog
        ran = []
        bl = TokenBacklog(name="bl-race")
        for i in range(20):
            bl.put(lambda i=i: (time.sleep(0.005), ran.append(i)))
        flusher = threading.Thread(target=bl.flush)
        flusher.start()
        bl.close()
        flusher.join(timeout=10)
        assert not flusher.is_alive()
        assert ran == list(range(20))
        assert not bl.alive

    def test_fifo_under_slow_consumers(self):
        """Strict put() order even when item durations vary wildly — the
        single-worker FIFO is what keeps overlapped streams identical to
        sync streams."""
        import time
        from repro.serving.pipeline import TokenBacklog
        g = np.random.default_rng(5)
        delays = g.uniform(0.0, 0.004, 50)
        out = []
        bl = TokenBacklog()
        for i, d in enumerate(delays):
            bl.put(lambda i=i, d=d: (time.sleep(d), out.append(i)))
        bl.flush()
        assert out == list(range(50))
        bl.close()


class TestAdmissionWorker:
    """The admission-prefill worker primitive (repro.serving.pipeline):
    capacity-gated take/prepare off-thread, crash re-raise on poll."""

    def test_prepares_waves_up_to_capacity(self):
        import collections
        from repro.serving.pipeline import AdmissionWorker
        queue = collections.deque(range(10))

        def take(n):
            return [queue.popleft() for _ in range(min(n, len(queue)))]

        w = AdmissionWorker(take, lambda reqs: ("wave", list(reqs)),
                            name="adm-test")
        w.kick(3)
        assert w.wait(timeout=5.0)
        waves = w.poll()
        assert waves == [("wave", [0, 1, 2])]
        assert len(queue) == 7               # capacity bounded the take
        w.close()

    def test_crash_reraises_on_poll_once(self):
        from repro.serving.pipeline import AdmissionWorker

        def boom(reqs):
            raise RuntimeError("prefill exploded")

        w = AdmissionWorker(lambda n: [1], boom, name="adm-crash")
        w.kick(1)
        assert w.wait(timeout=5.0)           # crash counts as "ready"
        with pytest.raises(RuntimeError, match="adm-crash"):
            w.poll()
        assert w.poll() == []                # raised once, then drained
        w.close()

    def test_wait_times_out_when_nothing_upstream(self):
        import time
        from repro.serving.pipeline import AdmissionWorker
        w = AdmissionWorker(lambda n: [], lambda reqs: reqs,
                            name="adm-idle")
        w.kick(4)
        t0 = time.perf_counter()
        assert not w.wait(timeout=0.2)       # empty take: no wave, no hang
        assert time.perf_counter() - t0 < 5.0
        w.close()


class TestAsyncMesh:
    """The overlapped pipeline over a (2, 4) mesh (runs in the `mesh` CI
    job under forced host devices; skips otherwise)."""

    @pytest.fixture(scope="class")
    def mesh24(self):
        return make_test_mesh(2, 4, skip=True)

    def test_greedy_streams_match_single_device_sync(self, mesh24):
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True, mesh=mesh24)
        assert eng.mesh_str == "2x4"
        assert got == ref
        m = eng.metrics()
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m

    def test_sampled_spec_streams_match_single_device_sync(self, mesh24):
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, sampling=SAMPLED)
        got, _ = _serve(cfg, params, prompts, sampling=SAMPLED,
                        overlap=True, mesh=mesh24, spec_depth=2,
                        draft="ngram")
        assert got == ref

    def test_aot_overlap_on_mesh(self, mesh24):
        cfg, params = _model("latent")
        prompts = _prompts(cfg, n=4)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True, aot=True,
                          mesh=mesh24)
        assert got == ref
        assert eng.trace_counts["window"] == 1

    def test_continuous_depth3_on_mesh(self, mesh24):
        """The staging queue + in-scan install under shard_map: stage
        rows shard with the cache pool, the swap scatter stays mode="drop"
        dataflow — streams still equal the single-device sync engine."""
        cfg, params = _model("latent")
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts)
        got, eng = _serve(cfg, params, prompts, overlap=True, mesh=mesh24,
                          pipeline_depth=3, continuous=True)
        assert got == ref
        m = eng.metrics()
        assert m["slot_swaps"] > 0
        assert m["host_syncs"] == m["windows"] + m["admission_syncs"], m
