"""Sampler determinism suite: greedy parity, exact top-k masking, minimal
top-p nucleus, and token-for-token PRNG reproducibility through the
engine's fused decode loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, Request, SamplingParams
from repro.serving.sampler import NEG_INF, filtered_logits, sample_tokens

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    cfg = get_config("qwen3-4b", smoke=True, **kw)
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _keys(n):
    return jnp.stack([jax.random.PRNGKey(100 + i) for i in range(n)])


class TestSampleTokens:
    def test_temperature_zero_is_exact_argmax(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
        out = sample_tokens(logits, jnp.zeros(5), jnp.zeros(5, jnp.int32),
                            jnp.ones(5), _keys(5))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_temperature_to_zero_converges_to_greedy(self):
        """As T -> 0 the sampled distribution collapses onto argmax."""
        rng = np.random.default_rng(1)
        logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
        out = sample_tokens(logits, jnp.full(4, 1e-4),
                            jnp.zeros(4, jnp.int32), jnp.ones(4), _keys(4))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_tiny_temperature_routes_to_greedy_no_nan(self):
        """Regression: temperature=1e-8 was clamped to _TEMP_EPS and the
        scaled logits could overflow float32 to inf, turning the top-p
        softmax — and every sampled token in the row — into NaN garbage.
        Sub-floor temperatures are semantically greedy and must return
        exact argmax."""
        rng = np.random.default_rng(21)
        # large-magnitude logits make the overflow concrete: 3e3 / 1e-6
        # is comfortably finite, but the old path scaled by 1e6 with no
        # clamp and mixed rows could push the filter into inf territory
        logits = jnp.asarray(rng.normal(size=(4, 64)) * 3e3, jnp.float32)
        temps = jnp.asarray([1e-8, 1e-7, 0.0, 1e-9], jnp.float32)
        out = sample_tokens(logits, temps, jnp.zeros(4, jnp.int32),
                            jnp.full(4, 0.9), _keys(4))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_tiny_temperature_mixed_with_sampled_rows(self):
        """The greedy routing is per-row: a sub-floor row in a batch with
        genuinely sampled rows returns argmax while the sampled rows stay
        finite and in-vocab (no NaN poisoning through the shared filter)."""
        rng = np.random.default_rng(22)
        logits = jnp.asarray(rng.normal(size=(3, 32)) * 1e4, jnp.float32)
        temps = jnp.asarray([1e-8, 0.9, 1e-7], jnp.float32)
        out = np.asarray(sample_tokens(
            logits, temps, jnp.full(3, 8, jnp.int32), jnp.full(3, 0.9),
            _keys(3)))
        greedy = np.asarray(jnp.argmax(logits, -1))
        assert out[0] == greedy[0] and out[2] == greedy[2]
        assert 0 <= out[1] < 32

    def test_huge_logits_with_small_temperature_stay_finite(self):
        """Scaled logits are clamped before filtering: even logits near
        the float32 edge divided by a small temperature must produce an
        in-vocab token, not a NaN-driven index.  (Values that overflow
        before the clamp tie at the bound, so either max-tier token is
        acceptable — the contract is finiteness, not ordering at 1e39.)"""
        logits = jnp.asarray([[1e35, 2e35, -1e35, 0.0]], jnp.float32)
        out = np.asarray(sample_tokens(
            logits, jnp.asarray([1e-4]), jnp.zeros(1, jnp.int32),
            jnp.asarray([0.5]), _keys(1)))
        assert out[0] in (0, 1)     # a max-tier token, never NaN garbage

    def test_per_row_mixed_policies_one_call(self):
        """Greedy and sampled rows coexist in one batched call (one trace
        serves any request mix)."""
        rng = np.random.default_rng(2)
        logits = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
        temp = jnp.asarray([0.0, 5.0, 0.0])
        greedy = np.asarray(jnp.argmax(logits, -1))
        out = np.asarray(sample_tokens(logits, temp, jnp.zeros(3, jnp.int32),
                                       jnp.ones(3), _keys(3)))
        assert out[0] == greedy[0] and out[2] == greedy[2]


class TestTopK:
    def test_masks_exactly_k(self):
        rng = np.random.default_rng(3)
        logits = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
        for k in (1, 7, 32, 128):
            out = filtered_logits(logits, jnp.full(4, k, jnp.int32),
                                  jnp.ones(4))
            kept = np.asarray(out > NEG_INF / 2).sum(axis=-1)
            np.testing.assert_array_equal(kept, np.full(4, k))

    def test_keeps_the_k_largest(self):
        logits = jnp.asarray([[0.1, 3.0, 2.0, -1.0, 2.5]], jnp.float32)
        out = np.asarray(filtered_logits(
            logits, jnp.asarray([3], jnp.int32), jnp.ones(1)))[0]
        assert set(np.nonzero(out > -1e29)[0]) == {1, 2, 4}

    def test_zero_disables(self):
        logits = jnp.asarray(np.random.default_rng(4).normal(size=(2, 16)),
                             jnp.float32)
        out = filtered_logits(logits, jnp.zeros(2, jnp.int32), jnp.ones(2))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(logits))


class TestTopP:
    def test_minimal_nucleus(self):
        """probs (0.5, 0.3, 0.15, 0.05): top_p=0.75 must keep exactly
        {0.5, 0.3} — the smallest prefix reaching 0.75."""
        probs = np.asarray([0.5, 0.3, 0.15, 0.05])
        logits = jnp.asarray(np.log(probs)[None, :], jnp.float32)
        out = np.asarray(filtered_logits(
            logits, jnp.zeros(1, jnp.int32), jnp.asarray([0.75])))[0]
        assert set(np.nonzero(out > -1e29)[0]) == {0, 1}

    def test_crossing_token_is_kept(self):
        """top_p=0.79: cumulative 0.5, 0.8 — token 1 crosses and is kept."""
        probs = np.asarray([0.5, 0.3, 0.15, 0.05])
        logits = jnp.asarray(np.log(probs)[None, :], jnp.float32)
        out = np.asarray(filtered_logits(
            logits, jnp.zeros(1, jnp.int32), jnp.asarray([0.79])))[0]
        assert set(np.nonzero(out > -1e29)[0]) == {0, 1}

    def test_top1_always_survives(self):
        logits = jnp.asarray([[0.0, 5.0, 1.0]], jnp.float32)
        out = np.asarray(filtered_logits(
            logits, jnp.zeros(1, jnp.int32), jnp.asarray([1e-6])))[0]
        assert set(np.nonzero(out > -1e29)[0]) == {1}

    def test_one_disables(self):
        logits = jnp.asarray(np.random.default_rng(5).normal(size=(2, 16)),
                             jnp.float32)
        out = filtered_logits(logits, jnp.zeros(2, jnp.int32), jnp.ones(2))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(logits))


class TestSamplingParamsValidation:
    @pytest.mark.parametrize("bad", [
        {"temperature": -0.1}, {"top_k": -1}, {"top_p": 0.0},
        {"top_p": 1.5},
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


class TestEngineSampling:
    """The determinism contracts through the full fused decode loop."""

    def _serve(self, cfg, params, prompts, sampling, *, slots=2,
               max_new=6, sync_every=8):
        eng = Engine(cfg, params, max_slots=slots, max_len=37,
                     sampling=sampling, sync_every=sync_every)
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
        return {r.uid: r.out_tokens for r in eng.run()}

    def test_temperature_zero_matches_seed_greedy_loop(self):
        """temperature=0 through the fused loop == the seed engine's
        prefill + per-token argmax decode, token for token."""
        cfg = _cfg(recalkv_ratio=0.5)
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(6)
        prompts = [g.integers(0, cfg.vocab_size, 5 + i).astype(np.int32)
                   for i in range(3)]
        got = self._serve(cfg, params, prompts,
                          SamplingParams(temperature=0.0), slots=3)
        for i, pr in enumerate(prompts):
            toks = jnp.asarray(pr[None, :])
            lens = jnp.asarray([len(pr)], jnp.int32)
            logits, caches = T.prefill(cfg, params, toks, lens, max_len=37)
            ref = [int(np.asarray(jnp.argmax(logits, -1))[0])]
            cur = lens.astype(jnp.int32)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            while len(ref) < 6:
                logits, caches = T.decode_step(cfg, params, caches, tok, cur)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                ref.append(int(np.asarray(tok)[0]))
                cur = cur + 1
            assert got[i] == ref, f"uid={i}"

    def test_fixed_key_reproduces_token_for_token(self):
        cfg = _cfg()
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(7)
        prompts = [g.integers(0, cfg.vocab_size, 6).astype(np.int32)
                   for _ in range(2)]
        sp = SamplingParams(temperature=0.9, top_k=64, top_p=0.95, seed=11)
        a = self._serve(cfg, params, prompts, sp)
        b = self._serve(cfg, params, prompts, sp)
        assert a == b

    def test_sampled_stream_is_batch_invariant(self):
        """Per-slot keys advance per *emitted* token, so a request's
        sampled stream must not depend on its batch-mates or on window
        size."""
        cfg = _cfg()
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(8)
        prompt = g.integers(0, cfg.vocab_size, 7).astype(np.int32)
        sp = SamplingParams(temperature=0.8, seed=3)
        solo = self._serve(cfg, params, [prompt], sp, slots=1, sync_every=4)
        noise = [g.integers(0, cfg.vocab_size, 4 + i).astype(np.int32)
                 for i in range(2)]
        crowded = self._serve(cfg, params, [prompt] + noise, sp, slots=3,
                              sync_every=8)
        assert solo[0] == crowded[0]

    def test_seed_changes_the_stream(self):
        cfg = _cfg()
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(9)
        prompts = [g.integers(0, cfg.vocab_size, 6).astype(np.int32)]
        a = self._serve(cfg, params, prompts,
                        SamplingParams(temperature=1.5, seed=0), max_new=12)
        b = self._serve(cfg, params, prompts,
                        SamplingParams(temperature=1.5, seed=1), max_new=12)
        assert a[0] != b[0]

    def test_per_request_sampling_overrides_engine_default(self):
        cfg = _cfg()
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(10)
        prompt = g.integers(0, cfg.vocab_size, 6).astype(np.int32)
        eng = Engine(cfg, params, max_slots=2, max_len=37,
                     sampling=SamplingParams(temperature=1.2, seed=5))
        eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=6,
                           sampling=SamplingParams(temperature=0.0)))
        eng.submit(Request(uid=1, prompt=prompt.copy(), max_new_tokens=6))
        done = {r.uid: r.out_tokens for r in eng.run()}
        greedy = self._serve(cfg, params, [prompt],
                             SamplingParams(temperature=0.0), slots=1)
        assert done[0] == greedy[0]          # override -> greedy
        assert done[1] != done[0]            # default stays sampled
