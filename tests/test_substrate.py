"""Substrate tests: quant/hadamard, checkpoint, data, optim, schedules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import DataConfig, batch, sequence
from repro.optim import (
    AdamWConfig, apply_updates, compressed_psum, init_state, quantize_leaf,
    with_error_feedback,
)
from repro.optim.schedule import cosine, wsd
from repro.quant import fake_quant, fwht, hadamard_inverse, hadamard_transform, quantize


class TestHadamard:
    @pytest.mark.parametrize("dim", [8, 64, 96, 160, 320])
    def test_orthonormal(self, dim):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(5, dim)), jnp.float32)
        y = hadamard_transform(x)
        np.testing.assert_allclose(
            float(jnp.linalg.norm(y)), float(jnp.linalg.norm(x)), rtol=1e-4)
        back = hadamard_inverse(y)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   rtol=1e-4, atol=1e-5)

    def test_fwht_involution(self):
        x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 64)),
                        jnp.float32)
        np.testing.assert_allclose(np.asarray(fwht(fwht(x))), np.asarray(x),
                                   rtol=1e-4, atol=1e-5)

    def test_flattens_outliers(self):
        """The reason it's used: post-transform per-token quant error drops
        for outlier-heavy latents."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(64, 128)).astype(np.float32)
        x[:, 3] *= 50.0  # channel outlier
        xj = jnp.asarray(x)
        direct = float(jnp.mean((fake_quant(xj, 4) - xj) ** 2))
        h = hadamard_transform(xj)
        via_h = float(jnp.mean((hadamard_inverse(fake_quant(h, 4)) - xj) ** 2))
        assert via_h < direct


class TestIntQuant:
    @pytest.mark.parametrize("bits,tol", [(8, 0.01), (4, 0.12), (3, 0.25)])
    def test_roundtrip_error(self, bits, tol):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
        err = float(jnp.sqrt(jnp.mean((fake_quant(x, bits) - x) ** 2)))
        assert err < tol

    def test_quantize_range(self):
        x = jnp.asarray(np.random.default_rng(4).normal(size=(8, 16)) * 100,
                        jnp.float32)
        q, s = quantize(x, 4)
        assert int(jnp.max(jnp.abs(q))) <= 7
        assert q.dtype == jnp.int8


class TestCheckpoint:
    def _tree(self, seed=0):
        g = np.random.default_rng(seed)
        return {"params": {"w": jnp.asarray(g.normal(size=(4, 4)), jnp.float32),
                           "blocks": (jnp.ones((2, 3)), jnp.zeros((5,)))},
                "opt": {"step": jnp.asarray(7)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 10, tree)
        assert ckpt.latest_step(str(tmp_path)) == 10
        out = ckpt.restore(str(tmp_path), 10, tree)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_async_and_keep_last(self, tmp_path):
        tree = self._tree()
        threads = [ckpt.save(str(tmp_path), s, tree, keep_last=2, async_=True)
                   for s in (1, 2, 3)]
        for t in threads:
            t.join()
        ckpt.save(str(tmp_path), 4, tree, keep_last=2)
        kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert len(kept) <= 2 and "step_00000004" in kept

    def test_restore_reshard_hook_called(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 1, tree)
        seen = []

        def shard_fn(key, arr):
            seen.append(key)
            return None
        ckpt.restore(str(tmp_path), 1, tree, sharding_for=shard_fn)
        assert len(seen) == len(jax.tree.leaves(tree))

    def test_missing_leaf_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            ckpt.restore(str(tmp_path), 1, {"b": jnp.ones(3)})


class TestData:
    def test_deterministic(self):
        dc = DataConfig(vocab_size=64, seq_len=128)
        a = sequence(dc, "train", 5)
        b = sequence(dc, "train", 5)
        np.testing.assert_array_equal(a, b)

    def test_splits_and_indices_differ(self):
        dc = DataConfig(vocab_size=64, seq_len=128)
        assert not np.array_equal(sequence(dc, "train", 1),
                                  sequence(dc, "valid", 1))
        assert not np.array_equal(sequence(dc, "train", 1),
                                  sequence(dc, "train", 2))

    def test_shards_partition_global_batch(self):
        dc = DataConfig(vocab_size=64, seq_len=32)
        full = batch(dc, "train", 3, 8)
        parts = [batch(dc, "train", 3, 8, shard=s, num_shards=4)
                 for s in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([p["tokens"] for p in parts]), full["tokens"])

    def test_labels_are_shifted(self):
        dc = DataConfig(vocab_size=64, seq_len=32)
        b = batch(dc, "train", 0, 2)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert (b["labels"][:, -1] == -1).all()

    def test_copy_spans_present(self):
        dc = DataConfig(vocab_size=512, seq_len=256, copy_frac=1.0)
        toks = sequence(dc, "train", 0)
        # somewhere a 32-token span repeats verbatim
        found = any(
            np.array_equal(toks[i:i + 32], toks[j:j + 32])
            for i in range(0, 96, 8) for j in range(128, 220, 4) if j > i + 32)
        assert found


class TestOptim:
    def test_adamw_optimizes_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        state = init_state(params, cfg)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, state, _ = apply_updates(params, g, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=0.05)

    def test_bf16_moments_still_converge(self):
        target = jnp.asarray([0.5, -0.5])
        params = {"w": jnp.zeros(2)}
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=jnp.bfloat16)
        state = init_state(params, cfg)
        for _ in range(300):
            g = {"w": 2 * (params["w"] - target)}
            params, state, _ = apply_updates(params, g, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=0.1)
        assert state["mu"]["w"].dtype == jnp.bfloat16

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros(4)}
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        state = init_state(params, cfg)
        g = {"w": jnp.full(4, 1e6)}
        p2, _, m = apply_updates(params, g, state, cfg)
        assert float(jnp.max(jnp.abs(p2["w"]))) < 10.0
        assert float(m["grad_norm"]) > 1e5

    def test_schedules(self):
        import jax.numpy as jnp
        s0 = float(cosine(jnp.asarray(0), warmup=10, total=100))
        s_w = float(cosine(jnp.asarray(10), warmup=10, total=100))
        s_end = float(cosine(jnp.asarray(100), warmup=10, total=100))
        assert s0 == pytest.approx(0.0, abs=1e-6)
        assert s_w == pytest.approx(1.0, abs=1e-2)
        assert s_end == pytest.approx(0.1, abs=1e-2)
        w_mid = float(wsd(jnp.asarray(500), warmup=10, total=1000))
        w_end = float(wsd(jnp.asarray(1000), warmup=10, total=1000))
        assert w_mid == pytest.approx(1.0)
        assert w_end <= 0.05

    def test_error_feedback_preserves_signal(self):
        """Sum of decompressed grads over steps ~= sum of true grads."""
        g_true = {"w": jnp.asarray(np.random.default_rng(5).normal(size=256) *
                                   0.01, jnp.float32)}
        residual = None
        acc = jnp.zeros(256)
        for _ in range(30):
            deq, residual = with_error_feedback(g_true, residual)
            acc = acc + deq["w"]
        np.testing.assert_allclose(np.asarray(acc) / 30,
                                   np.asarray(g_true["w"]), atol=2e-4)

    def test_quantize_leaf_roundtrip(self):
        g = jnp.asarray(np.random.default_rng(6).normal(size=128), jnp.float32)
        q, s = quantize_leaf(g)
        rel = float(jnp.linalg.norm(q.astype(jnp.float32) * s - g)
                    / jnp.linalg.norm(g))
        assert rel < 0.02
