"""Speculative decoding: the multi-token verify step must reproduce
sequential decode, and the engine's token streams must be INVARIANT to
``spec_depth`` and draft choice — greedy and sampled, every cache
variant, full and chunked prefill — while the 1-sync-per-window contract
holds and accepted draft tokens are real (accept-rate bookkeeping)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, Request, SamplingParams
from repro.serving.draft import DraftSpec, make_layer_draft, ngram_propose

KEY = jax.random.PRNGKey(0)

CASES = {
    "dense": {},
    "latent": {"recalkv_ratio": 0.5},
    "int8_latent": {"recalkv_ratio": 0.5, "cache_quant_bits": 8},
}

SAMPLED = SamplingParams(temperature=0.9, top_k=32, top_p=0.9, seed=11)


def _model(case="latent", arch="qwen3-4b"):
    extra = dict(CASES[case])
    kw = {k: extra.pop(k) for k in ("recalkv_ratio",) if k in extra}
    cfg = get_config(arch, smoke=True, **kw)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, **extra)
    return cfg, T.init_params(cfg, KEY)


@pytest.fixture(scope="module")
def models():
    return {case: _model(case) for case in CASES}


def _prompts(cfg, n=5, seed=3):
    g = np.random.default_rng(seed)
    return [g.integers(0, cfg.vocab_size, 5 + 2 * i).astype(np.int32)
            for i in range(n)]


def _serve(cfg, params, prompts, sampling=None, max_new=6, **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=40, sampling=sampling,
                 **kw)
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr.copy(), max_new_tokens=max_new))
    eng.run()
    return {r.uid: r.out_tokens for r in eng.finished}, eng


class TestVerifyStep:
    """T.verify_step == S sequential T.decode_step calls: same logits,
    and committing the full prefix leaves the same ring."""

    @pytest.mark.parametrize("case", ["dense", "latent", "int8_latent"])
    def test_logits_match_sequential(self, models, case):
        cfg, params = models[case]
        rng = np.random.default_rng(7)
        B, P, S = 2, 6, 3
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)),
                           jnp.int32)
        lens = jnp.asarray([P, 4], jnp.int32)
        _, caches = T.prefill(cfg, params, toks, lens, 37)
        cur = lens.astype(jnp.int32)
        fed = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        seq = []
        c, u = caches, cur
        for j in range(S):
            lg, c = T.decode_step(cfg, params, c, fed[:, j], u)
            seq.append(lg)
            u = u + 1
        seq = jnp.stack(seq, axis=1)
        got, updates = T.verify_step(cfg, params, caches, fed, cur,
                                     jnp.ones((B, S), bool))
        np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                                   rtol=1e-4, atol=1e-5)
        # committing all S columns == the sequential ring, up to fp noise
        # in the stored entries: a subsequent step sees the same logits
        committed = T.commit_verify_writes(caches, updates, cur,
                                           jnp.ones((B, S), bool))
        nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
        lg_seq, _ = T.decode_step(cfg, params, c, nxt, cur + S)
        lg_ver, _ = T.decode_step(cfg, params, committed, nxt, cur + S)
        np.testing.assert_allclose(np.asarray(lg_ver), np.asarray(lg_seq),
                                   rtol=1e-4, atol=1e-5)

    def test_partial_commit_equals_shorter_sequential(self, models):
        """Committing only an accepted prefix must leave the ring exactly
        as if just those tokens had been decoded — a rejected draft token
        never touches the cache."""
        cfg, params = models["latent"]
        rng = np.random.default_rng(8)
        B, S = 2, 4
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 5)),
                           jnp.int32)
        lens = jnp.asarray([5, 5], jnp.int32)
        _, caches = T.prefill(cfg, params, toks, lens, 37)
        cur = lens.astype(jnp.int32)
        fed = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        _, updates = T.verify_step(cfg, params, caches, fed, cur,
                                   jnp.ones((B, S), bool))
        keep = 2
        mask = jnp.asarray([[True] * keep + [False] * (S - keep)] * B)
        committed = T.commit_verify_writes(caches, updates, cur, mask)
        c = caches
        for j in range(keep):
            _, c = T.decode_step(cfg, params, c, fed[:, j], cur + j)
        nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32)
        lg_seq, _ = T.decode_step(cfg, params, c, nxt, cur + keep)
        lg_ver, _ = T.decode_step(cfg, params, committed, nxt, cur + keep)
        np.testing.assert_allclose(np.asarray(lg_ver), np.asarray(lg_seq),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("arch", ["deepseek-v3-671b", "h2o-danube-1.8b"])
    def test_mla_and_sliding_window_verify_match(self, arch):
        """The MLA (absorbed-latent) and sliding-window verify readers:
        multi-query logits against the ring must match sequential decode."""
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  dtype=jnp.float32)
        params = T.init_params(cfg, KEY)
        rng = np.random.default_rng(5)
        B, S = 2, 3
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 6)),
                           jnp.int32)
        lens = jnp.asarray([6, 4], jnp.int32)
        _, caches = T.prefill(cfg, params, toks, lens, 37)
        cur = lens.astype(jnp.int32)
        fed = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        seq = []
        c, u = caches, cur
        for j in range(S):
            lg, c = T.decode_step(cfg, params, c, fed[:, j], u)
            seq.append(lg)
            u = u + 1
        got, _ = T.verify_step(cfg, params, caches, fed, cur,
                               jnp.ones((B, S), bool))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jnp.stack(seq, axis=1)),
                                   rtol=1e-4, atol=1e-5)

    def test_mla_engine_streams_invariant(self):
        """End-to-end MLA (deepseek smoke) speculation: per-head widths
        differ from d_head, the cache is the (ckv, krope) latent pair."""
        cfg = dataclasses.replace(get_config("deepseek-v3-671b", smoke=True),
                                  dtype=jnp.float32)
        params = T.init_params(cfg, KEY)
        prompts = _prompts(cfg, n=3)
        for sp in (None, SAMPLED):
            ref, _ = _serve(cfg, params, prompts, sp, max_new=5)
            got, _ = _serve(cfg, params, prompts, sp, max_new=5,
                            spec_depth=2, draft="ngram")
            assert got == ref

    def test_recurrent_blocks_rejected(self):
        cfg = dataclasses.replace(get_config("falcon-mamba-7b", smoke=True),
                                  dtype=jnp.float32)
        params = T.init_params(cfg, KEY)
        with pytest.raises(ValueError, match="recurrent"):
            Engine(cfg, params, max_slots=1, max_len=16, spec_depth=2)


class TestDepthInvariance:
    """The acceptance bar: for every (policy, cache variant) the token
    streams at spec_depth in {2, 4} equal spec_depth=0 exactly."""

    @pytest.mark.parametrize("case", sorted(CASES))
    @pytest.mark.parametrize("policy", ["greedy", "sampled"])
    def test_streams_invariant_to_spec_depth(self, models, case, policy):
        cfg, params = models[case]
        sp = None if policy == "greedy" else SAMPLED
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, sp)
        for depth in (2, 4):
            got, eng = _serve(cfg, params, prompts, sp, spec_depth=depth,
                              draft="ngram")
            assert got == ref, (case, policy, depth)
            m = eng.metrics()
            # speculation must not break the structural sync contract
            assert m["host_syncs"] == m["windows"] + m["admission_syncs"]
            assert m["spec_depth"] == depth and m["draft"] == "ngram"

    @pytest.mark.parametrize("policy", ["greedy", "sampled"])
    def test_layer_draft_streams_match(self, models, policy):
        cfg, params = models["latent"]
        sp = None if policy == "greedy" else SAMPLED
        prompts = _prompts(cfg)
        ref, _ = _serve(cfg, params, prompts, sp)
        got, eng = _serve(cfg, params, prompts, sp, spec_depth=2,
                          draft="layers:2")
        assert got == ref
        m = eng.metrics()
        assert m["draft"] == "layers:2"
        assert m["draft_proposed"] > 0
        if policy == "greedy":
            # a self-draft of 2/3 of the target's layers agrees often
            # enough to be a real lever, not a no-op
            assert m["draft_accepted"] > 0
            assert m["accept_rate"] == pytest.approx(
                m["draft_accepted"] / m["draft_proposed"])

    def test_chunked_cap_length_prompt_invariant(self, models):
        """Chunked-prefill ingest and speculation share the window; a
        cap-length prompt fed in chunks must still be depth-invariant."""
        cfg, params = models["latent"]
        g = np.random.default_rng(9)
        cap = g.integers(0, cfg.vocab_size, 39).astype(np.int32)

        def serve(**kw):
            eng = Engine(cfg, params, max_slots=4, max_len=40,
                         sampling=SAMPLED, **kw)
            eng.submit(Request(uid=0, prompt=cap.copy(), max_new_tokens=5))
            return eng.run()[0].out_tokens

        ref = serve()
        assert serve(prefill_chunk=7, spec_depth=2, draft="ngram",
                     sync_every=3) == ref
        assert serve(prefill_chunk=5, spec_depth=3, draft="layers:2") == ref

    def test_eos_stop_invariant_mid_round(self, models):
        """An EOS accepted in the middle of a speculative round must stop
        the stream at exactly the sequential point."""
        cfg, params = models["latent"]
        g = np.random.default_rng(12)
        pr = g.integers(0, cfg.vocab_size, 6).astype(np.int32)
        full, _ = _serve(cfg, params, [pr], None, max_new=10)
        eos = int(full[0][3])            # 4th emitted token becomes EOS

        def serve(**kw):
            eng = Engine(cfg, params, max_slots=2, max_len=40, **kw)
            eng.submit(Request(uid=0, prompt=pr.copy(), max_new_tokens=10,
                               eos_id=eos))
            return eng.run()[0].out_tokens

        ref = serve()
        assert ref[-1] == eos or len(ref) == 10
        assert serve(spec_depth=3, draft="layers:2") == ref
        assert serve(spec_depth=4, draft="ngram") == ref

    def test_pallas_backend_streams_invariant(self, models):
        """With the pallas kernels serving BOTH paths — single-query
        decode and the multi-query verify kernel — streams must still be
        depth-invariant within the backend (einsum-vs-pallas parity per
        depth lives in tests/test_verify_kernel.py)."""
        cfg, params = models["latent"]
        cfg = dataclasses.replace(cfg, attn_backend="pallas")
        prompts = _prompts(cfg, n=3)
        ref, _ = _serve(cfg, params, prompts, SAMPLED)
        got, _ = _serve(cfg, params, prompts, SAMPLED, spec_depth=2,
                        draft="ngram")
        assert got == ref

    def test_repetitive_prompt_ngram_proposes_real_tokens(self, models):
        """Prompt-lookup on a constant-token prompt: the trailing bigram
        always has an earlier occurrence, so the draft makes REAL
        (non-placeholder) proposals — which count toward draft_proposed
        under the placeholders-don't-count rule — and the stream stays
        invariant whether or not the model's continuation accepts them."""
        cfg, params = models["latent"]
        prompt = np.full(16, 5, np.int32)
        ref, _ = _serve(cfg, params, [prompt], None, max_new=8)
        got, eng = _serve(cfg, params, [prompt], None, max_new=8,
                          spec_depth=3, draft="ngram")
        assert got == ref
        assert eng.metrics()["draft_proposed"] > 0

    def test_repetitive_text_accept_rate_positive(self, models):
        """Regression: the bigram-only matcher always picked the MOST
        RECENT occurrence, which on periodic text is the one flush
        against the tail — its continuation is entirely stale positions,
        so every proposal was -1 and accept_rate pinned at 0.0.  The
        longest-available-suffix matcher (3->2->1-gram fallback) requires
        a match to have at least one real following token, so repetitive
        continuations must now accept free tokens."""
        cfg, params = models["latent"]
        prompt = np.full(16, 5, np.int32)
        eng = Engine(cfg, params, max_slots=4, max_len=64, spec_depth=3,
                     draft="ngram")
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=16))
        eng.run()
        m = eng.metrics()
        assert m["draft_proposed"] > 0
        assert m["accept_rate"] > 0.0


class TestDraftModule:
    def test_parse(self):
        assert DraftSpec.parse(None) is None
        assert DraftSpec.parse("none") is None
        assert DraftSpec.parse("ngram") == DraftSpec("ngram")
        assert DraftSpec.parse("layers:2") == DraftSpec("layers", 2)
        assert DraftSpec.parse("layers=3") == DraftSpec("layers", 3)
        with pytest.raises(ValueError, match="draft spec"):
            DraftSpec.parse("bogus")

    def test_make_layer_draft_shares_leaves(self):
        cfg, params = _model("latent")
        dcfg, dparams = make_layer_draft(cfg, params, 2)
        assert dcfg.num_layers == 2
        assert dcfg.expanded_layers() == cfg.expanded_layers()[:2]
        assert dparams["embed"] is params["embed"]
        # truncated stack must run standalone
        toks = jnp.asarray([[1, 2, 3]], jnp.int32)
        logits, _ = T.prefill(dcfg, dparams, toks,
                              jnp.asarray([3], jnp.int32), 16)
        assert logits.shape == (1, cfg.vocab_size)

    def test_make_layer_draft_bounds(self):
        cfg, params = _model("latent")
        with pytest.raises(ValueError, match="layers draft"):
            make_layer_draft(cfg, params, 0)
        with pytest.raises(ValueError, match="layers draft"):
            make_layer_draft(cfg, params, cfg.num_layers + 1)

    def test_ngram_propose_prompt_lookup(self):
        # fed history (positions 0..4): [5, 6, 7, 8, 5]; feeding 6 at
        # cur=5 -> bigram (hist[4], 6) = (5, 6) matches positions (0, 1)
        # -> proposes the continuation hist[2:5] = [7, 8, 5] (all three
        # positions are already-fed, hence known, tokens)
        hist = jnp.asarray([[5, 6, 7, 8, 5, 0, 0, 0]], jnp.int32)
        out = ngram_propose(hist, jnp.asarray([5]), jnp.asarray([6]), 3)
        np.testing.assert_array_equal(np.asarray(out)[0], [7, 8, 5])
        # depth reaching past the fed history pads with -1
        out4 = ngram_propose(hist, jnp.asarray([5]), jnp.asarray([6]), 4)
        np.testing.assert_array_equal(np.asarray(out4)[0], [7, 8, 5, -1])

    def test_ngram_propose_no_match(self):
        hist = jnp.asarray([[5, 6, 7, 8, 0, 0]], jnp.int32)
        out = ngram_propose(hist, jnp.asarray([4]), jnp.asarray([9]), 2)
        np.testing.assert_array_equal(np.asarray(out)[0], [-1, -1])


class TestSpecMetrics:
    def test_defaults_off(self, models):
        cfg, params = models["latent"]
        _, eng = _serve(cfg, params, _prompts(cfg, n=1), None)
        m = eng.metrics()
        assert m["spec_depth"] == 0 and m["draft"] is None
        assert m["accept_rate"] == 0.0

    def test_invalid_depth_rejected(self, models):
        cfg, params = models["latent"]
        with pytest.raises(ValueError, match="spec_depth"):
            Engine(cfg, params, max_slots=1, max_len=16, spec_depth=-1)

    def test_draft_without_depth_rejected(self, models):
        """A draft spec with spec_depth=0 would be silently ignored —
        an operator benchmarking a draft but forgetting --spec-depth must
        hear about it (and typos must hit DraftSpec.parse validation)."""
        cfg, params = models["latent"]
        with pytest.raises(ValueError, match="spec_depth"):
            Engine(cfg, params, max_slots=1, max_len=16, draft="layers:2")
        with pytest.raises(ValueError, match="draft spec"):
            Engine(cfg, params, max_slots=1, max_len=16, spec_depth=2,
                   draft="layrs:2")

    def test_ngram_accept_rate_counts_only_real_proposals(self, models):
        """The n-gram draft pads unknown positions with -1 (guaranteed
        rejects); those must not inflate the denominator — on a fresh
        non-repetitive prompt the draft proposes nothing, so proposed
        stays 0 rather than depth * steps."""
        cfg, params = models["latent"]
        g = np.random.default_rng(31)
        # distinct tokens -> no bigram ever repeats -> no real proposals
        prompt = np.arange(1, 9, dtype=np.int32)
        _, eng = _serve(cfg, params, [prompt], None, max_new=4,
                        spec_depth=3, draft="ngram")
        m = eng.metrics()
        assert m["draft_accepted"] == 0
        # the stream of a random smoke model may coincidentally repeat a
        # bigram; the bound is that placeholders never count
        assert m["draft_proposed"] <= 2 * m["tokens"]

    def test_accepted_tokens_reduce_windows(self, models):
        """With a perfectly predictable (periodic) greedy stream the
        layer draft accepts enough that the same budget drains in fewer
        decode windows than sequential decoding."""
        cfg, params = models["latent"]
        pat = np.tile(np.asarray([3, 1, 4, 1, 5], np.int32), 5)
        _, eng0 = _serve(cfg, params, [pat], None, max_new=12,
                         sync_every=2)
        _, eng2 = _serve(cfg, params, [pat], None, max_new=12,
                         sync_every=2, spec_depth=3, draft="layers:2")
        if eng2.metrics()["draft_accepted"] > 0:
            assert eng2.metrics()["windows"] < eng0.metrics()["windows"]
