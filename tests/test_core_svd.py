import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import svd


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def low_rank_matrix(rng, m, n, r, noise=0.0):
    A = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))
    return jnp.asarray(A + noise * rng.normal(size=(m, n)), jnp.float32)


class TestTruncatedSVD:
    def test_exact_at_true_rank(self, rng):
        W = low_rank_matrix(rng, 32, 48, 5)
        f = svd.truncated_svd(W, 5)
        np.testing.assert_allclose(np.asarray(f.reconstruct()), np.asarray(W),
                                   atol=1e-4)

    def test_error_monotone_in_rank(self, rng):
        W = jnp.asarray(rng.normal(size=(40, 40)), jnp.float32)
        errs = [float(svd.frobenius_error(W, svd.truncated_svd(W, r)))
                for r in (4, 8, 16, 32, 40)]
        assert all(a >= b - 1e-5 for a, b in zip(errs, errs[1:]))
        assert errs[-1] < 1e-6  # full rank is exact

    def test_eckart_young_optimality(self, rng):
        """Truncated SVD beats a random rank-r factorization."""
        W = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
        f = svd.truncated_svd(W, 8)
        best = float(svd.frobenius_error(W, f))
        for _ in range(5):
            L = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
            R = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
            rand = float(jnp.sum((L @ R - W) ** 2))
            assert best <= rand

    def test_factor_shapes(self, rng):
        W = jnp.asarray(rng.normal(size=(24, 36)), jnp.float32)
        f = svd.truncated_svd(W, 6)
        assert f.L.shape == (24, 6) and f.R.shape == (6, 36)
        assert f.rank == 6


class TestWhitenedSVD:
    def test_identity_cov_matches_plain(self, rng):
        W = jnp.asarray(rng.normal(size=(20, 30)), jnp.float32)
        cov = jnp.eye(20)
        fw = svd.whitened_svd(W, cov, 7)
        fp = svd.truncated_svd(W, 7)
        np.testing.assert_allclose(
            float(svd.frobenius_error(W, fw)),
            float(svd.frobenius_error(W, fp)), rtol=1e-3, atol=1e-4)

    def test_beats_plain_on_anisotropic_data(self, rng):
        m, n, N = 24, 32, 4000
        W = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        # activations concentrated in a low-dim subspace
        basis = rng.normal(size=(6, m))
        X = jnp.asarray(rng.normal(size=(N, 6)) @ basis
                        + 0.05 * rng.normal(size=(N, m)), jnp.float32)
        cov = X.T @ X
        fw = svd.whitened_svd(W, cov, 8)
        fp = svd.truncated_svd(W, 8)
        ew = float(jnp.sum((X @ fw.reconstruct() - X @ W) ** 2))
        ep = float(jnp.sum((X @ fp.reconstruct() - X @ W) ** 2))
        assert ew < ep

    def test_data_weighted_error_identity(self, rng):
        W = jnp.asarray(rng.normal(size=(16, 20)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(100, 16)), jnp.float32)
        f = svd.truncated_svd(W, 4)
        direct = float(jnp.sum((X @ f.reconstruct() - X @ W) ** 2))
        via_cov = float(svd.data_weighted_error(W, f, X.T @ X))
        np.testing.assert_allclose(direct, via_cov, rtol=1e-3)


class TestGroupedSVD:
    def test_grouping_shapes_and_stacking(self, rng):
        H, dh, m = 8, 8, 32
        W = jnp.asarray(rng.normal(size=(m, H * dh)), jnp.float32)
        groups = [[0, 1], [2, 3], [4, 5], [6, 7]]
        fs = svd.grouped_svd(W, groups, [6] * 4, H)
        L, R = svd.stack_group_factors(fs)
        assert L.shape == (4, m, 6) and R.shape == (4, 6, 2 * dh)

    def test_full_rank_groups_exact(self, rng):
        H, dh, m = 4, 6, 20
        W = jnp.asarray(rng.normal(size=(m, H * dh)), jnp.float32)
        groups = [[0, 2], [1, 3]]
        fs = svd.grouped_svd(W, groups, [12] * 2, H)
        per_head = svd.head_columns(W, H)
        for g, f in zip(groups, fs):
            Wg = jnp.concatenate([per_head[h] for h in g], axis=1)
            np.testing.assert_allclose(np.asarray(f.reconstruct()),
                                       np.asarray(Wg), atol=1e-4)

    def test_mixed_rank_stack_raises(self, rng):
        W = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
        fs = svd.grouped_svd(W, [[0, 1], [2, 3]], [4, 6], 4)
        with pytest.raises(ValueError):
            svd.stack_group_factors(fs)


def test_effective_rank_rounding():
    assert svd.effective_rank_for_ratio(512, 0.5) == 256
    assert svd.effective_rank_for_ratio(320, 0.5) == 160
    assert svd.effective_rank_for_ratio(256, 0.3, multiple=8) == 80
    assert svd.effective_rank_for_ratio(64, 0.01) == 8       # min_rank floor
    assert svd.effective_rank_for_ratio(64, 1.0) == 64
