"""Sharding rules + a subprocess mini dry-run (8 placeholder devices).

The full 512-device dry-run lives in launch/dryrun.py and runs as its own
process (results in experiments/dryrun.jsonl); here we verify the rule
machinery on every arch and actually lower train+decode on a small mesh.
"""

import functools
import json
import math
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.sharding import rules

KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)


def fake_mesh(shape, axes):
    """AbstractMesh stands in for a device mesh in pure spec computations."""
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible_full_configs(arch):
    """Every assigned FULL config gets valid (divisible) specs on 16x16."""
    cfg = get_config(arch)
    mesh = fake_mesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg), KEY)
    specs = rules.param_specs(shapes, mesh)

    def check(path, leaf, spec):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            n = math.prod(mesh.shape[a] for a in axes)
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)

    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for (path, leaf), spec in zip(flat_shapes, flat_specs):
        check(path, leaf, spec)


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b",
                                  "falcon-mamba-7b", "gemma3-12b"])
def test_cache_specs_shard_sequence(arch):
    from repro.configs import RECALKV_APPLICABLE
    kw = {"recalkv_ratio": 0.5} if RECALKV_APPLICABLE[arch] else {}
    cfg = get_config(arch, **kw)
    mesh = fake_mesh((16, 16), ("data", "model"))
    caches = jax.eval_shape(
        functools.partial(T.init_decode_cache, cfg, 128, 32768))
    specs = rules.cache_specs(caches, mesh)
    found_seq_shard = False
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        if "model" in tuple(spec):
            found_seq_shard = True
    assert found_seq_shard, f"{arch}: no cache leaf sequence-sharded"


def test_moe_experts_sharded():
    cfg = get_config("qwen3-moe-235b-a22b")
    mesh = fake_mesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg), KEY)
    specs = rules.param_specs(shapes, mesh)
    wi_spec = specs["blocks"][0]["mlp"]["wi"]
    # leading dim is the scan stack; then (E, d, f): E->model, d->data (fsdp)
    assert tuple(wi_spec) [1] == "model"
    assert tuple(wi_spec)[2] == "data"


def test_zero3_spans_pods_for_giant_leaves():
    cfg = get_config("deepseek-v3-671b")
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg), KEY)
    specs = rules.param_specs(shapes, mesh)
    wi_spec = tuple(specs["blocks"][0]["mlp"]["wi"])
    assert wi_spec[1] == "model"
    assert wi_spec[2] == ("data", "pod")  # ZeRO-3 across pods


def test_batch_specs_use_pod_and_data():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    b = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    spec = rules.batch_specs(b, mesh)["tokens"]
    assert tuple(spec)[0] == ("pod", "data")


def test_make_test_mesh_guards_device_count():
    """The shared mesh helper must not silently hand out an unbuildable
    mesh: raise by default with the XLA_FLAGS hint, shrink toward (1, 1)
    with degrade=True.  (On hosts with >= the requested devices the
    request is honored as-is — both branches still hold.)"""
    from repro.launch.mesh import make_test_mesh
    have = len(jax.devices())
    big = 2 * have
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_test_mesh(big, big)
    mesh = make_test_mesh(big, big, degrade=True)
    assert tuple(mesh.axis_names) == ("data", "model")
    assert math.prod(mesh.shape.values()) <= have


def test_carry_specs_shard_slot_axis():
    """The serving engine's device carry shards dim 0 (the slot axis)
    over the batch axes when divisible, else replicates."""
    mesh = fake_mesh((2, 4), ("data", "model"))
    st = {
        "tok": jax.ShapeDtypeStruct((8,), jnp.int32),
        "keys": jax.ShapeDtypeStruct((8, 2), jnp.uint32),
        "buf": jax.ShapeDtypeStruct((8, 16), jnp.int32),
    }
    specs = rules.carry_specs(st, mesh)
    assert tuple(specs["tok"]) == ("data",)
    assert tuple(specs["keys"])[0] == "data"
    assert tuple(specs["buf"])[0] == "data"
    odd = rules.carry_specs({"tok": jax.ShapeDtypeStruct((7,), jnp.int32)},
                            mesh)
    assert all(a is None for a in tuple(odd["tok"]))


def test_slot_stacked_spec():
    mesh = fake_mesh((2, 4), ("data", "model"))
    assert tuple(rules.slot_stacked_spec(8, mesh)) == (None, "data")
    assert tuple(rules.slot_stacked_spec(7, mesh)) == ()


def test_param_specs_head_grain():
    """With grains given, attention projections never shard inside a
    head: Hkv*dh = 16 over model=4 would tile 4-wide across dh=8."""
    mesh = fake_mesh((2, 4), ("data", "model"))
    grains = {"wk": 8, "wq": 8}
    wk = {"wk": jax.ShapeDtypeStruct((64, 16), jnp.float32)}
    free = rules.param_specs(wk, mesh)["wk"]
    assert tuple(free)[-1] == "model"            # shape-only rule shards it
    grained = rules.param_specs(wk, mesh, grains=grains)["wk"]
    assert "model" not in tuple(grained)          # head grain forbids it
    wq = {"wq": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    assert tuple(rules.param_specs(wq, mesh, grains=grains)["wq"])[-1] == "model"


def test_head_grains_cover_mla_projections():
    """MLA's per-head widths differ from d_head, and wkv_a's whole
    latent ‖ rope output is one grain (rmsnorm + rope operate on it as a
    unit) — TP must never split any of them."""
    cfg = get_config("deepseek-v3-671b", smoke=True)
    grains = rules.head_grains(cfg)
    a = cfg.mla
    assert grains == {"wq_b": a.qk_nope_dim + a.qk_rope_dim,
                      "wkv_a": a.kv_lora_rank + a.qk_rope_dim,
                      "wkv_b": a.qk_nope_dim + a.v_head_dim}
    dense = get_config("qwen3-4b", smoke=True)
    assert rules.head_grains(dense) == {
        "wq": dense.d_head, "wk": dense.d_head, "wv": dense.d_head}


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools, json
    import jax, jax.numpy as jnp
    from repro.configs import get_config, RECALKV_APPLICABLE
    from repro.models import transformer as T
    from repro.sharding import rules
    from repro.optim import AdamWConfig, init_state
    from repro.runtime import TrainConfig, make_train_step
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(2, 4)
    named = lambda t: rules.to_named(t, mesh)
    KEY = jax.ShapeDtypeStruct((2,), jnp.uint32)
    out = {}
    for arch in ("qwen3-4b", "deepseek-v3-671b", "recurrentgemma-9b"):
        cfg = get_config(arch, smoke=True)
        p = jax.eval_shape(functools.partial(T.init_params, cfg), KEY)
        opt_cfg = AdamWConfig()
        o = jax.eval_shape(functools.partial(init_state, cfg=opt_cfg), p)
        b = {"tokens": jax.ShapeDtypeStruct((16, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((16, 32), jnp.int32)}
        fn = make_train_step(cfg, opt_cfg, TrainConfig(microbatches=2))
        with mesh:
            comp = jax.jit(fn, in_shardings=(
                named(rules.param_specs(p, mesh)),
                named(rules.opt_specs(o, None, mesh)),
                named(rules.batch_specs(b, mesh))),
                donate_argnums=(0, 1)).lower(p, o, b).compile()
        st = H.collective_stats(comp.as_text())
        out[arch] = {"train_collective_bytes": st.total_bytes,
                     "flops": H.cost_report(comp)["flops"]}
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """End-to-end pjit lowering on 8 placeholder devices (own process so
    the forced device count cannot leak into other tests)."""
    res = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"}, cwd="/root/repo", timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][0]
    data = json.loads(line[len("RESULT "):])
    assert set(data) == {"qwen3-4b", "deepseek-v3-671b", "recurrentgemma-9b"}
    for arch, rec in data.items():
        assert rec["flops"] > 0, arch
