"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fisher, svd
from repro.models import layers as L
from repro.models import kv_cache as KC
from repro.quant import fake_quant, hadamard_inverse, hadamard_transform

COMMON = dict(deadline=None, max_examples=25)


@settings(**COMMON)
@given(m=st.integers(4, 24), n=st.integers(4, 24),
       seed=st.integers(0, 2**16))
def test_svd_error_decreases_with_rank(m, n, seed):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    rmax = min(m, n)
    e_lo = float(svd.frobenius_error(W, svd.truncated_svd(W, max(1, rmax // 2))))
    e_hi = float(svd.frobenius_error(W, svd.truncated_svd(W, rmax)))
    assert e_hi <= e_lo + 1e-4
    assert e_hi < 1e-4 * m * n  # full rank ~ exact


@settings(**COMMON)
@given(n=st.integers(2, 32), target=st.floats(0.07, 1.0),
       seed=st.integers(0, 2**16))
def test_fisher_allocation_budget_and_bounds(n, target, seed):
    rng = np.random.default_rng(seed)
    scores = (rng.random(n) + 1e-3).tolist()
    ratios = fisher.allocate_ratios(scores, target)
    assert len(ratios) == n
    assert all(0.0625 - 1e-9 <= r <= 1.0 + 1e-9 for r in ratios)
    # budget met whenever it's inside the clip box
    if 0.0625 <= target <= 1.0:
        assert abs(float(np.mean(ratios)) - target) < 1e-3


@settings(**COMMON)
@given(bits=st.sampled_from([4, 8]), rows=st.integers(1, 8),
       cols=st.integers(4, 64), seed=st.integers(0, 2**16))
def test_quantization_error_bounded(bits, rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    err = jnp.abs(fake_quant(x, bits) - x)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    bound = amax / {8: 127, 4: 7}[bits]  # half-step would be /2; be loose
    assert bool(jnp.all(err <= bound + 1e-6))


@settings(**COMMON)
@given(dim_pow=st.integers(2, 7), rows=st.integers(1, 6),
       seed=st.integers(0, 2**16))
def test_hadamard_is_isometry(dim_pow, rows, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, 2 ** dim_pow)), jnp.float32)
    y = hadamard_transform(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hadamard_inverse(y)),
                               np.asarray(x), rtol=1e-3, atol=1e-5)


@settings(**COMMON)
@given(dh_half=st.sampled_from([4, 8, 16]), pos=st.integers(0, 10000),
       seed=st.integers(0, 2**16))
def test_rope_preserves_norm_and_relative_angles(dh_half, pos, seed):
    rng = np.random.default_rng(seed)
    dh = 2 * dh_half
    x = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    cos, sin = L.rope_tables(jnp.asarray([[pos]]), dh, 1e4)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)
    # relative property: <rope(q,p1), rope(k,p2)> depends only on p1-p2
    q = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, dh)), jnp.float32)
    for delta in (0, 3):
        dots = []
        for base in (0, 7):
            cq = L.rope_tables(jnp.asarray([[base + delta]]), dh, 1e4)
            ck = L.rope_tables(jnp.asarray([[base]]), dh, 1e4)
            dots.append(float(jnp.sum(L.apply_rope(q, *cq)
                                      * L.apply_rope(k, *ck))))
        assert abs(dots[0] - dots[1]) < 1e-3 * max(1.0, abs(dots[0]))


@settings(**COMMON)
@given(T=st.integers(1, 40), Lr=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**16))
def test_ring_buffer_prefill_semantics(T, Lr, seed):
    """write_prefill + prefill_pos keep exactly the last min(T, Lr)
    positions, and slot assignment is pos % Lr."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(1, T, 2)), jnp.float32)
    cache = jnp.zeros((1, Lr, 2), jnp.float32)
    out = KC.write_prefill(cache, vals)
    pos = KC.prefill_pos(jnp.asarray([T]), T, Lr)
    kept = 0
    for slot in range(Lr):
        p = int(pos[0, slot])
        if p >= 0:
            kept += 1
            assert p % Lr == slot
            np.testing.assert_allclose(np.asarray(out[0, slot]),
                                       np.asarray(vals[0, p]))
    assert kept == min(T, Lr)


@settings(**COMMON)
@given(keep=st.floats(0.1, 1.0), width=st.sampled_from([64, 256, 320]))
def test_rank_for_ratio_bounds(keep, width):
    r = svd.effective_rank_for_ratio(width, keep)
    assert 8 <= r <= width
    assert r % 8 == 0 or r == width


@settings(**COMMON)
@given(Hq=st.sampled_from([4, 8]), s=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**16))
def test_fused_projection_matches_dense_path(Hq, s, seed):
    """Property form of the OCMF fusion identity across head layouts."""
    from repro.core import fusion
    rng = np.random.default_rng(seed)
    Hkv = Hq  # MHA case exercises all group layouts
    if Hkv % s:
        return
    dh, d, r, S = 4, 16, 6, 12
    G = Hkv // s
    R_v = jnp.asarray(rng.normal(size=(G, r, s * dh)), jnp.float32)
    W_o = jnp.asarray(rng.normal(size=(Hq * dh, d)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(S, G, r)), jnp.float32)
    A = jax.nn.softmax(jnp.asarray(rng.normal(size=(Hq, S)), jnp.float32), -1)
    v = jnp.einsum("sgr,grn->sgn", z, R_v).reshape(S, Hkv, dh)
    ref = jnp.stack([A[h] @ v[:, h] for h in range(Hq)]).reshape(
        1, Hq * dh) @ W_o
    W_f = fusion.fuse_output_projection(R_v, W_o, Hq, Hkv)
    o_lat = jnp.stack([A[h] @ z[:, h // s] for h in range(Hq)])
    out = fusion.fused_output_apply(o_lat[None], W_f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
