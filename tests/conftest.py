"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device;
only launch/dryrun.py (separate process) forces 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
