"""Algorithm-1 pipeline tests: exactness, compression accounting, and the
paper's qualitative claims (HSR helps; calibration helps) at unit scale."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AttnWeights, CalibStats, ReCalKVConfig, collect_stats,
    compress_attention_layer, compress_model_layers,
)


@pytest.fixture
def rng():
    return np.random.default_rng(5)


def make_weights(rng, d=48, Hq=8, Hkv=8, dh=8, structured=False):
    def mat(m, n):
        return jnp.asarray(rng.normal(size=(m, n)) * m ** -0.5, jnp.float32)
    if structured:
        # kv heads come in similar pairs (scattered), so HSR has signal
        base = [rng.normal(size=(d, dh)) for _ in range(Hkv // 2)]
        order = rng.permutation(Hkv)
        cols = [None] * Hkv
        for i, b in enumerate(base):
            for j, pos in enumerate(order[2 * i: 2 * i + 2]):
                cols[pos] = b + 0.15 * rng.normal(size=(d, dh))
        Wk = jnp.asarray(np.concatenate(cols, 1) * d ** -0.5, jnp.float32)
    else:
        Wk = mat(d, Hkv * dh)
    return AttnWeights(W_q=mat(d, Hq * dh), W_k=Wk, W_v=mat(d, Hkv * dh),
                       W_o=mat(Hq * dh, d), num_q_heads=Hq, num_kv_heads=Hkv)


def attn_out(w_or_ca, x, Hq, Hkv, dh, compressed=False, s=1):
    if not compressed:
        w = w_or_ca
        q = (x @ w.W_q).reshape(-1, Hq, dh)
        k = (x @ w.W_k).reshape(-1, Hkv, dh)
        v = (x @ w.W_v).reshape(-1, Hkv, dh)
        sc = jnp.einsum("qhd,khd->hqk", q, k) / dh ** .5
        a = jax.nn.softmax(sc, -1)
        o = jnp.einsum("hqk,khd->qhd", a, v)
        return o.reshape(-1, Hq * dh) @ w.W_o
    ca = w_or_ca
    G = ca.num_groups
    q = (x @ ca.W_q).reshape(-1, Hq, dh)
    zk = jnp.einsum("td,gdr->tgr", x, ca.L_k)
    k = jnp.einsum("tgr,grn->tgn", zk, ca.R_k).reshape(-1, Hkv, dh)
    zv = jnp.einsum("td,gdr->tgr", x, ca.L_v)
    sc = jnp.einsum("qhd,khd->hqk", q, k) / dh ** .5
    a = jax.nn.softmax(sc, -1)
    qpk = Hq // Hkv
    o = jnp.stack([jnp.einsum("qk,kr->qr", a[h], zv[:, (h // qpk) // s])
                   for h in range(Hq)], 1)
    return jnp.einsum("qhr,hrd->qd", o, ca.W_o_fused)


class TestLayerCompression:
    def test_full_rank_exact(self, rng):
        w = make_weights(rng)
        X = jnp.asarray(rng.normal(size=(256, 48)), jnp.float32)
        ca = compress_attention_layer(
            w, collect_stats(X), ReCalKVConfig(group_size=4), 32, 32)
        Y = jnp.asarray(rng.normal(size=(8, 48)), jnp.float32)
        ref = attn_out(w, Y, 8, 8, 8)
        out = attn_out(ca, Y, 8, 8, 8, compressed=True, s=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_cache_bytes_accounting(self, rng):
        w = make_weights(rng)
        X = jnp.asarray(rng.normal(size=(128, 48)), jnp.float32)
        ca = compress_attention_layer(
            w, collect_stats(X), ReCalKVConfig(group_size=4), 16, 16)
        # dense: 2 * 8 heads * 8 dh * 2B = 256; latent: 2 groups * 32 * 2B = 128
        assert ca.dense_cache_bytes_per_token() == 256
        assert ca.cache_bytes_per_token() == 128

    def test_hsr_reduces_reconstruction_error(self, rng):
        """Paper Table 3 row 2: HSR grouping beats contiguous grouping."""
        errs = {}
        for use_hsr in (True, False):
            e_tot = 0.0
            for trial in range(4):
                trng = np.random.default_rng(100 + trial)
                w = make_weights(trng, structured=True)
                X = jnp.asarray(trng.normal(size=(512, 48)), jnp.float32)
                cfg = ReCalKVConfig(group_size=2, use_hsr=use_hsr,
                                    use_whitening=False, use_calibration=False)
                ca = compress_attention_layer(w, collect_stats(X), cfg, 8, 8)
                k_ref = (X @ w.W_k)
                # undo the fold: compare in the permuted basis
                perm = np.asarray(ca.perm)
                k_ref_p = k_ref.reshape(-1, 8, 8)[:, perm].reshape(-1, 64)
                zk = jnp.einsum("td,gdr->tgr", X, ca.L_k)
                k_hat = jnp.einsum("tgr,grn->tgn", zk, ca.R_k).reshape(-1, 64)
                e_tot += float(jnp.mean((k_hat - k_ref_p) ** 2))
            errs[use_hsr] = e_tot
        assert errs[True] < errs[False]

    def test_calibration_reduces_value_error(self, rng):
        """Paper Table 3 row 3: offline calibration beats plain SVD."""
        w = make_weights(rng)
        basis = rng.normal(size=(8, 48))
        X = jnp.asarray(rng.normal(size=(600, 8)) @ basis
                        + 0.05 * rng.normal(size=(600, 48)), jnp.float32)
        outs = {}
        for use_cal in (True, False):
            cfg = ReCalKVConfig(group_size=4, use_hsr=False,
                                use_whitening=False, use_calibration=use_cal)
            ca = compress_attention_layer(w, collect_stats(X), cfg, 12, 12)
            zv = jnp.einsum("td,gdr->tgr", X, ca.L_v)
            # value-path output error through the fused projection
            qpk = 1
            o = jnp.stack([zv[:, (h // qpk) // 4] for h in range(8)], 1)
            approx = jnp.einsum("thr,hrd->td", o, ca.W_o_fused)
            v_ref = (X @ w.W_v).reshape(-1, 8, 8)
            perm = np.asarray(ca.perm)
            ref = v_ref[:, perm].reshape(-1, 64) @ np.asarray(
                jnp.concatenate([w.W_o[h * 8:(h + 1) * 8] for h in perm]))
            outs[use_cal] = float(jnp.mean((approx - ref) ** 2))
        assert outs[True] < outs[False]


class TestModelPipeline:
    def test_multi_layer_with_fisher(self, rng):
        layers = [make_weights(rng) for _ in range(3)]
        stats = [CalibStats.identity(48)] * 3
        cfg = ReCalKVConfig(keep_ratio=0.5, group_size=4, min_rank=8)
        out = compress_model_layers(layers, stats, cfg,
                                    fisher_k=[1.0, 5.0, 1.0],
                                    fisher_v=[1.0, 1.0, 5.0])
        assert len(out) == 3
        assert out[1].rank_k >= out[0].rank_k   # fisher gives layer 1 more K rank
        assert out[2].rank_v >= out[0].rank_v

    def test_uniform_without_fisher(self, rng):
        layers = [make_weights(rng) for _ in range(2)]
        stats = [CalibStats.identity(48)] * 2
        cfg = ReCalKVConfig(keep_ratio=0.5, group_size=4, use_fisher=False)
        out = compress_model_layers(layers, stats, cfg)
        assert out[0].rank_k == out[1].rank_k == 16  # 0.5 * 32
