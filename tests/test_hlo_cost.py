"""Unit tests for the trip-count-aware HLO cost model (launch/hlo_cost.py).

The roofline terms all flow through this parser, so we pin its behavior on
small compiled programs with hand-computable costs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def analyze_fn(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(compiled.as_text())


class TestDotFlops:
    def test_single_matmul(self):
        a = jnp.ones((64, 128), jnp.float32)
        b = jnp.ones((128, 32), jnp.float32)
        res = analyze_fn(lambda x, y: x @ y, a, b)
        # 2 * M * N * K
        assert res.flops == pytest.approx(2 * 64 * 32 * 128, rel=0.01)

    def test_batched_dot(self):
        a = jnp.ones((4, 16, 32), jnp.float32)
        b = jnp.ones((4, 32, 8), jnp.float32)
        res = analyze_fn(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert res.flops == pytest.approx(2 * 4 * 16 * 8 * 32, rel=0.01)

    def test_elementwise_has_no_flops(self):
        a = jnp.ones((256, 256), jnp.float32)
        res = analyze_fn(lambda x: jnp.tanh(x) + x * 2, a)
        assert res.flops == 0.0
        assert res.bytes > 0  # but it does move bytes


class TestLoopTripCounts:
    def test_scan_multiplies_body_cost(self):
        """An N-iteration scan must cost ~N x the body (XLA's own
        cost_analysis counts it once — the bug this module exists for)."""
        w = jnp.ones((64, 64), jnp.float32)
        x = jnp.ones((8, 64), jnp.float32)

        def step(carry, _):
            return carry @ w, None

        def fn(x):
            out, _ = jax.lax.scan(step, x, None, length=10)
            return out

        res = analyze_fn(fn, x)
        one_dot = 2 * 8 * 64 * 64
        assert res.flops == pytest.approx(10 * one_dot, rel=0.05)

    def test_nested_scans_multiply(self):
        w = jnp.ones((32, 32), jnp.float32)

        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None

        def fn(x):
            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out

        res = analyze_fn(fn, jnp.ones((8, 32), jnp.float32))
        one_dot = 2 * 8 * 32 * 32
        assert res.flops == pytest.approx(12 * one_dot, rel=0.05)

    def test_fori_loop_trip_count(self):
        w = jnp.ones((16, 16), jnp.float32)

        def fn(x):
            return jax.lax.fori_loop(0, 7, lambda i, c: c @ w, x)

        res = analyze_fn(fn, jnp.ones((4, 16), jnp.float32))
        assert res.flops == pytest.approx(7 * 2 * 4 * 16 * 16, rel=0.05)


class TestBytesModel:
    def test_bytes_scale_with_tensor_size(self):
        small = analyze_fn(lambda x: x + 1.0, jnp.ones((64, 64), jnp.float32))
        big = analyze_fn(lambda x: x + 1.0, jnp.ones((256, 256), jnp.float32))
        assert big.bytes > 10 * small.bytes

    def test_top_costs_attribution(self):
        a = jnp.ones((64, 64), jnp.float32)

        def fn(x):
            return (x @ x) @ x

        res = analyze_fn(fn, a)
        assert res.top_flops, "dot attribution missing"
        total_attr = sum(v for _, v in res.top_flops)
        assert total_attr == pytest.approx(res.flops, rel=0.01)


class TestParserRobustness:
    def test_tuple_typed_ops_parse(self):
        """while loops carry tuple types with /*index=N*/ comments."""
        def fn(x):
            def body(c, _):
                return (c[0] * 2.0, c[1] + 1), None
            (a, b), _ = jax.lax.scan(body, (x, x), None, length=5)
            return a + b

        res = analyze_fn(fn, jnp.ones((32, 32), jnp.float32))
        assert np.isfinite(res.bytes) and res.bytes > 0

    def test_empty_program(self):
        res = hlo_cost.analyze("HloModule empty\n")
        assert res.flops == 0.0
