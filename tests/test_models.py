"""Per-architecture smoke tests (reduced configs) + decode equivalence.

Every assigned arch: one train step (loss finite, shapes right) and one
prefill+decode step on CPU.  Decode==forward equivalence is checked for
representative families (dense ring, latent ring, MLA absorbed, SSM state,
hybrid, enc-dec).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RECALKV_APPLICABLE, get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, Tn=16, seed=1):
    g = np.random.default_rng(seed)
    toks = jnp.asarray(g.integers(0, cfg.vocab_size, (B, Tn)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.cross_source_len:
        batch["source"] = jnp.asarray(
            g.normal(size=(B, cfg.cross_source_len, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert jnp.isfinite(loss), arch
    hidden, _ = T.forward_hidden(cfg, params, batch["tokens"],
                                 batch.get("source"))
    assert hidden.shape == (2, 16, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    # one SGD-flavored step moves the loss
    grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    B, Tn = batch["tokens"].shape
    logits, cache = T.prefill(cfg, params, batch["tokens"],
                              jnp.full((B,), Tn), max_len=32,
                              source=batch.get("source"))
    assert logits.shape == (B, cfg.vocab_size)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = T.decode_step(cfg, params, cache, nxt, jnp.full((B,), Tn))
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


DECODE_EQUIV_ARCHS = [
    "qwen3-4b",            # dense GQA + qk-norm
    "h2o-danube-1.8b",     # sliding-window ring buffer
    "gemma3-12b",          # local:global mix, dual theta
    "falcon-mamba-7b",     # pure state
    "recurrentgemma-9b",   # hybrid rglru + local (MQA)
    "deepseek-v3-671b",    # absorbed MLA + MoE
    "whisper-small",       # enc-dec with cross cache
]


@pytest.mark.parametrize("arch", DECODE_EQUIV_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill-then-decode must reproduce the full forward logits.

    MoE archs get a drop-free capacity factor: capacity-based token drops
    legitimately differ between batch shapes, which is routing semantics,
    not a cache bug (see test_moe_capacity_drops_are_shape_dependent)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32,
                              scan_layers=False)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = T.init_params(cfg, KEY)
    B, Tn, Lp = 2, 12, 8
    batch = make_batch(cfg, B=B, Tn=Tn)
    hidden, _ = T.forward_hidden(cfg, params, batch["tokens"],
                                 batch.get("source"))
    full = T.logits_for(cfg, params, hidden)
    lg, cache = T.prefill(cfg, params, batch["tokens"][:, :Lp],
                          jnp.full((B,), Lp), max_len=Tn + 4,
                          source=batch.get("source"))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Lp - 1]),
                               rtol=1e-3, atol=1e-3)
    for t in range(Lp, Tn):
        lg, cache = T.decode_step(cfg, params, cache, batch["tokens"][:, t],
                                  jnp.full((B,), t))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} step {t}")


def test_recalkv_decode_matches_forward():
    """Latent-cache decode == latent forward (compressed model path)."""
    cfg = dataclasses.replace(
        get_config("qwen3-4b", smoke=True, recalkv_ratio=0.5),
        dtype=jnp.float32, scan_layers=False)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    B, Tn, Lp = 2, 16, 10
    hidden, _ = T.forward_hidden(cfg, params, batch["tokens"])
    full = T.logits_for(cfg, params, hidden)
    lg, cache = T.prefill(cfg, params, batch["tokens"][:, :Lp],
                          jnp.full((B,), Lp), max_len=Tn)
    for t in range(Lp, Tn):
        lg, cache = T.decode_step(cfg, params, cache, batch["tokens"][:, t],
                                  jnp.full((B,), t))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_shape_dependent():
    """Documents the capacity semantics: with a tight capacity factor the
    same prefix CAN route differently under different batch shapes (GShard
    position-in-expert depends on every token in the batch)."""
    cfg = dataclasses.replace(get_config("deepseek-v3-671b", smoke=True),
                              dtype=jnp.float32, scan_layers=False)
    assert cfg.moe.capacity_factor < 4  # tight by default
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg, Tn=12)
    h12, _ = T.forward_hidden(cfg, params, batch["tokens"])
    h8, _ = T.forward_hidden(cfg, params, batch["tokens"][:, :8])
    # prefix outputs need not match exactly (drops differ) but stay close
    diff = float(jnp.max(jnp.abs(h12[:, :8] - h8)))
    assert np.isfinite(diff)


def test_ragged_prefill_lengths():
    """Right-padded prefill: each sequence's logits at its own last token."""
    cfg = dataclasses.replace(get_config("qwen3-4b", smoke=True),
                              dtype=jnp.float32)
    params = T.init_params(cfg, KEY)
    g = np.random.default_rng(3)
    toks = jnp.asarray(g.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    lengths = jnp.asarray([10, 6], jnp.int32)
    lg, cache = T.prefill(cfg, params, toks, lengths, max_len=16)
    # sequence 1 padded: its logits must equal an unpadded length-6 prefill
    lg6, _ = T.prefill(cfg, params, toks[1:, :6], jnp.asarray([6]), max_len=16)
    np.testing.assert_allclose(np.asarray(lg[1]), np.asarray(lg6[0]),
                               rtol=1e-3, atol=1e-3)


def test_scan_matches_unrolled():
    """scan-over-periods and the unrolled stack compute the same function."""
    base = get_config("gemma3-12b", smoke=True)
    cfg_s = dataclasses.replace(base, dtype=jnp.float32, scan_layers=True)
    cfg_u = dataclasses.replace(base, dtype=jnp.float32, scan_layers=False)
    params_s = T.init_params(cfg_s, KEY)
    # re-layout scanned params into the unrolled structure
    prefix = []
    n_per = cfg_s.num_periods
    for per in range(n_per):
        for j in range(cfg_s.period):
            prefix.append(jax.tree.map(lambda a: a[per], params_s["blocks"][j]))
    params_u = dict(params_s)
    params_u["prefix"] = tuple(prefix)
    params_u["blocks"] = ()
    batch = make_batch(cfg_s)
    h_s, _ = T.forward_hidden(cfg_s, params_s, batch["tokens"])
    h_u, _ = T.forward_hidden(cfg_u, params_u, batch["tokens"])
    np.testing.assert_allclose(np.asarray(h_s), np.asarray(h_u),
                               rtol=1e-4, atol=1e-4)
