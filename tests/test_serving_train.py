"""Integration: serving engine + training loop + checkpoint restart."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, batch as data_batch
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, make_train_step, train_loop
from repro.serving import Engine, Request

KEY = jax.random.PRNGKey(0)


def tiny_cfg(**kw):
    cfg = get_config("qwen3-4b", smoke=True, **kw)
    return dataclasses.replace(cfg, dtype=jnp.float32)


class TestEngine:
    def test_end_to_end_batching(self):
        cfg = tiny_cfg()
        params = T.init_params(cfg, KEY)
        eng = Engine(cfg, params, max_slots=3, max_len=48)
        g = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=g.integers(0, cfg.vocab_size, 5 + i).astype(np.int32),
                        max_new_tokens=6)
                for i in range(5)]
        for r in reqs:
            eng.submit(r)
        finished = eng.run()
        assert len(finished) == 5
        assert all(len(r.out_tokens) == 6 for r in finished)

    def test_batching_invariance(self):
        """A request's output must not depend on its batch-mates."""
        cfg = tiny_cfg()
        params = T.init_params(cfg, KEY)
        g = np.random.default_rng(1)
        prompt = g.integers(0, cfg.vocab_size, 7).astype(np.int32)

        def run(n_noise, slots):
            eng = Engine(cfg, params, max_slots=slots, max_len=48)
            eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=5))
            for i in range(n_noise):
                eng.submit(Request(
                    uid=100 + i,
                    prompt=g.integers(0, cfg.vocab_size, 4 + i).astype(np.int32),
                    max_new_tokens=5))
            done = eng.run()
            return next(r for r in done if r.uid == 0).out_tokens

        solo = run(0, 1)
        crowded = run(3, 4)
        assert solo == crowded

    def test_latent_cache_is_smaller(self):
        dense = tiny_cfg()
        comp = tiny_cfg(recalkv_ratio=0.5)
        p_d = T.init_params(dense, KEY)
        p_c = T.init_params(comp, KEY)
        size = lambda cfg, p: sum(
            l.size * l.dtype.itemsize
            for l in jax.tree.leaves(T.init_decode_cache(cfg, 4, 64)))
        assert size(comp, p_c) < 0.62 * size(dense, p_d)


class TestTrainLoop:
    def _setup(self, tmp_path=None, steps=12):
        cfg = dataclasses.replace(tiny_cfg(), num_layers=2, remat=False)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32)
        tc = TrainConfig(
            microbatches=2, warmup_steps=2, total_steps=steps,
            ckpt_dir=str(tmp_path) if tmp_path else None, ckpt_every=5,
            step_deadline_s=600)
        opt = AdamWConfig(lr=1e-3)

        def batch_fn(step):
            return {k: jnp.asarray(v)
                    for k, v in data_batch(dc, "train", step, 8).items()}
        return cfg, opt, tc, batch_fn

    def test_loss_decreases(self):
        cfg, opt, tc, batch_fn = self._setup(steps=20)
        out = train_loop(cfg, opt, tc, batch_fn, logger=lambda *_: None)
        first = np.mean(out["losses"][:4])
        last = np.mean(out["losses"][-4:])
        assert last < first

    def test_restart_from_checkpoint(self, tmp_path):
        cfg, opt, tc, batch_fn = self._setup(tmp_path, steps=10)
        out1 = train_loop(cfg, opt, tc, batch_fn, logger=lambda *_: None)
        # "crash" and restart: loop must resume from step 10's checkpoint
        tc2 = dataclasses.replace(tc, total_steps=14)
        out2 = train_loop(cfg, opt, tc2, batch_fn, logger=lambda *_: None)
        assert len(out2["losses"]) == 4  # only steps 10..13 re-run
        assert int(out2["opt_state"]["step"]) == 14

    def test_grad_compress_path_trains(self):
        cfg, opt, tc, batch_fn = self._setup(steps=8)
        tc = dataclasses.replace(tc, grad_compress=True)
        out = train_loop(cfg, opt, tc, batch_fn, logger=lambda *_: None)
        assert np.isfinite(out["losses"]).all()
        assert "residual" in out["opt_state"]

    def test_watchdog_fires_on_hang(self):
        from repro.runtime import Watchdog, WatchdogTimeout
        import time
        wd = Watchdog(0.05)
        wd.arm("hang")
        time.sleep(0.15)
        with pytest.raises(WatchdogTimeout):
            wd.disarm()


class TestCompressionQualityIntegration:
    @pytest.mark.slow
    def test_recalkv_beats_plain_svd_after_training(self, tmp_path):
        """Train a tiny model on copy-heavy data, compress with (a) plain
        grouped SVD (Palu baseline) and (b) ReCalKV — both as registry
        strategies; ReCalKV must give lower held-out loss — the paper's
        Table-1 ordering at unit scale."""
        from repro.api import CompressionSpec, RankPolicy, calibrate, compress

        cfg = dataclasses.replace(
            tiny_cfg(), num_layers=2, scan_layers=False, remat=False)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, copy_frac=0.8)
        tc = TrainConfig(microbatches=1, warmup_steps=5, total_steps=60)
        opt = AdamWConfig(lr=2e-3)

        def batch_fn(step):
            return {k: jnp.asarray(v)
                    for k, v in data_batch(dc, "train", step, 8).items()}
        out = train_loop(cfg, opt, tc, batch_fn, logger=lambda *_: None)
        params = out["params"]

        batches = [
            {k: jnp.asarray(v) for k, v in data_batch(dc, "calib", s, 4).items()}
            for s in range(4)]
        calib = calibrate(cfg, params, batches)

        def eval_loss(cfg2, params2):
            tot = 0.0
            for s in range(4):
                b = {k: jnp.asarray(v)
                     for k, v in data_batch(dc, "valid", s, 8).items()}
                tot += float(T.loss_fn(cfg2, params2, b)[0])
            return tot / 4

        losses = {}
        policy = RankPolicy(keep_ratio=0.4, group_size=2)
        for name, method in {"palu": "grouped-svd",
                             "recalkv": "recalkv"}.items():
            art = compress(cfg, params,
                           CompressionSpec(method, rank_policy=policy), calib)
            losses[name] = eval_loss(art.cfg, art.params)
        base = eval_loss(cfg, params)
        assert losses["recalkv"] <= losses["palu"] + 1e-4
        assert losses["recalkv"] < base + 1.0  # sane degradation
