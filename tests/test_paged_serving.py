"""Paged-cache serving: token-for-token parity with the ring engine,
prefix sharing, and page-budget admission.

The paged engine must be a pure LAYOUT change: same tokens, greedy and
sampled, on the einsum and pallas decode paths, single-device and
mesh-sharded.  The einsum path gathers a slot-major view (identical
arrays -> identical logits); the pallas path's page-per-tile walk is
bitwise-identical to the ring kernel tiled at ``attn_block=page_size``
(the paged Engine pins ``attn_block`` itself, and ring references here
pin the same value so both engines run the same tiling).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.serving.engine import Engine, Request
from repro.serving.sampler import SamplingParams

CASES = {
    "dense": {},
    "latent": {"recalkv_ratio": 0.5},
    "int8_latent": {"recalkv_ratio": 0.5, "cache_quant_bits": 8},
}
SAMPLED = SamplingParams(temperature=0.9, top_k=32, top_p=0.9, seed=11)
MAX_LEN = 40
PS = 8                # the default page size a max_len=40 engine picks

_MODELS: dict = {}


def _model(case: str):
    if case not in _MODELS:
        kw = dict(CASES[case])
        qbits = kw.pop("cache_quant_bits", None)
        cfg = get_config("qwen3-4b", smoke=True, **kw)
        cfg = dataclasses.replace(cfg, dtype=jnp.float32,
                                  cache_quant_bits=qbits)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        _MODELS[case] = (cfg, params)
    return _MODELS[case]


def _prompts(cfg, n=5, seed=3):
    r = np.random.RandomState(seed)
    return [r.randint(1, cfg.vocab_size, size=(5 + 2 * i,)).astype(np.int32)
            for i in range(n)]


def _serve(cfg, params, prompts, max_new=8, max_len=MAX_LEN, **kw):
    eng = Engine(cfg, params, max_slots=4, max_len=max_len, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    done = eng.run(300)
    assert not eng.scheduler.has_work
    return {r.uid: r.out_tokens for r in done}, eng


# -- einsum parity ------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_paged_matches_ring_einsum_greedy(case):
    cfg, params = _model(case)
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts)
    paged, eng = _serve(cfg, params, prompts, cache_layout="paged")
    assert ring == paged
    m = eng.metrics()
    assert m["cache_layout"] == "paged" and m["page_size"] == PS
    assert m["pages_free"] == m["pages_total"] - 1   # all retired, null apart


def test_paged_matches_ring_einsum_sampled():
    cfg, params = _model("latent")
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts, sampling=SAMPLED)
    paged, _ = _serve(cfg, params, prompts, sampling=SAMPLED,
                      cache_layout="paged")
    assert ring == paged


def test_paged_matches_ring_chunked_prefill():
    cfg, params = _model("latent")
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts, prefill_chunk=3)
    paged, _ = _serve(cfg, params, prompts, prefill_chunk=3,
                      cache_layout="paged")
    assert ring == paged


# -- pallas parity ------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_paged_matches_ring_pallas(case):
    cfg, params = _model(case)
    prompts = _prompts(cfg)
    # the paged engine pins attn_block = page_size; the ring reference
    # must run the same tiling for bitwise-identical flash accumulation
    ring, _ = _serve(dataclasses.replace(cfg, attn_block=PS), params,
                     prompts, backend="pallas")
    paged, _ = _serve(cfg, params, prompts, backend="pallas",
                      cache_layout="paged")
    assert ring == paged


# -- speculative decoding over the paged cache --------------------------------

def test_paged_matches_ring_speculative():
    cfg, params = _model("latent")
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts, spec_depth=2)
    paged, _ = _serve(cfg, params, prompts, spec_depth=2,
                      cache_layout="paged")
    assert ring == paged


def test_paged_matches_ring_layer_draft():
    cfg, params = _model("latent")
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts, spec_depth=2, draft="layers:1")
    paged, _ = _serve(cfg, params, prompts, spec_depth=2, draft="layers:1",
                      cache_layout="paged")
    assert ring == paged


# -- mesh parity --------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh24():
    return make_test_mesh(2, 4, skip=True)


def test_paged_matches_ring_on_mesh(mesh24):
    cfg, params = _model("latent")
    prompts = _prompts(cfg)
    ring, _ = _serve(cfg, params, prompts, mesh=mesh24)
    paged, _ = _serve(cfg, params, prompts, mesh=mesh24,
                      cache_layout="paged")
    assert ring == paged
    single, _ = _serve(cfg, params, prompts, cache_layout="paged")
    assert single == paged


# -- prefix sharing -----------------------------------------------------------

def test_shared_system_prompt_shares_pages():
    cfg, params = _model("latent")
    sysp = np.random.RandomState(7).randint(
        1, cfg.vocab_size, size=(24,)).astype(np.int32)
    tails = [np.random.RandomState(100 + i).randint(
        1, cfg.vocab_size, size=(4,)).astype(np.int32) for i in range(4)]
    prompts = [np.concatenate([sysp, t]) for t in tails]
    ring, _ = _serve(cfg, params, prompts, max_new=4, max_len=48)
    paged, eng = _serve(cfg, params, prompts, max_new=4, max_len=48,
                        cache_layout="paged", page_size=8)
    assert ring == paged                      # sharing never changes tokens
    m = eng.metrics()
    # 24-token system prompt = 3 whole pages of 8, shared by requests 2-4
    assert m["pages_shared"] == 9             # 3 pages x 3 sharers
    assert m["cow_forks"] == 3                # each sharer forks page 3
    unshared = 4 * (-(-min(28 + 4, 48) // 8))
    assert m["pages_peak"] < unshared


def test_pin_prefixes_survive_pool_churn():
    """pin_prefixes=K: the hottest registered prefix pages park at
    refcount 0 instead of joining the free list, so a flood of disjoint
    prompts cannot recycle them — a later request with the same system
    prompt resurrects the pinned pages instead of re-prefilling."""
    cfg, params = _model("latent")
    r = np.random.RandomState(11)
    sysp = r.randint(1, cfg.vocab_size, size=(16,)).astype(np.int32)

    def shared_load(uids):
        return [Request(uid=u, prompt=np.concatenate(
            [sysp, r.randint(1, cfg.vocab_size, size=(3,)).astype(np.int32)]),
            max_new_tokens=4) for u in uids]

    eng = Engine(cfg, params, max_slots=4, max_len=48, cache_layout="paged",
                 page_size=8, pin_prefixes=2)
    for q in shared_load(range(2)):          # register + hit -> pinned
        eng.submit(q)
    eng.run()
    m = eng.metrics()
    assert m["pin_prefixes"] == 2
    assert m["pages_pinned"] == 2            # the 16-token prefix = 2 pages
    # flood with disjoint prompts sized to churn the whole free list
    flood = [Request(uid=100 + i,
                     prompt=r.randint(1, cfg.vocab_size,
                                      size=(20,)).astype(np.int32),
                     max_new_tokens=4) for i in range(8)]
    for q in flood:
        eng.submit(q)
    eng.run()
    res_before = eng.metrics()["prefix_resurrections"]
    for q in shared_load(range(200, 202)):   # prefix still resident
        eng.submit(q)
    eng.run()
    m = eng.metrics()
    assert m["prefix_resurrections"] > res_before, m
    assert m["pages_pinned"] == 2

    # token parity: pinning is an allocator policy, never a stream change
    def drive(**kw):
        e = Engine(cfg, params, max_slots=4, max_len=48,
                   cache_layout="paged", page_size=8, **kw)
        for q in shared_load(range(4)):
            e.submit(q)
        return {q.uid: q.out_tokens for q in e.run()}

    r = np.random.RandomState(11)            # replay the same tails
    ref = drive()
    r = np.random.RandomState(11)
    assert drive(pin_prefixes=2) == ref


def test_pin_prefixes_requires_paged_layout():
    cfg, params = _model("latent")
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN, pin_prefixes=2)
    with pytest.raises(ValueError):
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN,
               cache_layout="paged", pin_prefixes=-1)


def test_page_budget_gates_admission():
    cfg, params = _model("latent")
    prompts = _prompts(cfg, n=4)
    # room for ~one request at a time: reach = ceil((plen + 8)/8) pages
    ring, _ = _serve(cfg, params, prompts)
    paged, eng = _serve(cfg, params, prompts, cache_layout="paged",
                        n_pages=6)
    assert ring == paged                      # smaller pool, same streams
    assert eng.metrics()["pages_total"] == 6


def test_paged_rejects_bad_config():
    cfg, params = _model("latent")
    with pytest.raises(ValueError):
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN,
               cache_layout="slab")
    with pytest.raises(ValueError):           # page_size without paged
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN, page_size=8)
    with pytest.raises(ValueError):           # does not divide max_len
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN,
               cache_layout="paged", page_size=7)
    with pytest.raises(ValueError):           # pool below one request
        Engine(cfg, params, max_slots=2, max_len=MAX_LEN,
               cache_layout="paged", page_size=8, n_pages=3)
