"""Scheduler: chunked prefill interleaving, FIFO admission under slot
churn, shard-aware wave packing, submit-time validation, engine metrics
window-boundary consistency, and run() timeout reporting."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Engine, Request, Scheduler

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    cfg = get_config("qwen3-4b", smoke=True, **kw)
    return dataclasses.replace(cfg, dtype=jnp.float32)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg(recalkv_ratio=0.5)
    return cfg, T.init_params(cfg, KEY)


class TestChunkedPrefill:
    def test_decode_progresses_between_chunks(self, model):
        """A long prompt admitted in prefill_chunk pieces must not stall an
        already-decoding slot: the decoder emits tokens while the long
        prompt is still being ingested."""
        cfg, params = model
        g = np.random.default_rng(0)
        eng = Engine(cfg, params, max_slots=2, max_len=40, sync_every=2,
                     prefill_chunk=3)
        short = g.integers(0, cfg.vocab_size, 4).astype(np.int32)
        long_ = g.integers(0, cfg.vocab_size, 24).astype(np.int32)
        eng.submit(Request(uid=0, prompt=short.copy(), max_new_tokens=20))
        eng.step()                      # admits+starts the decoder slot
        eng.submit(Request(uid=1, prompt=long_.copy(), max_new_tokens=4))
        req1 = eng.queue[0]
        progress = []                   # decoder token count per window
        for _ in range(64):
            if req1.out_tokens:         # long prompt fully ingested
                break
            eng.step()
            req0 = eng.slot_req[0] or next(
                r for r in eng.finished if r.uid == 0)
            progress.append(len(req0.out_tokens))
        assert req1.out_tokens, "long prompt never finished ingesting"
        # the decoder kept emitting across >= 2 ingest windows
        assert len(progress) >= 2
        assert progress[-1] > progress[0]

    def test_chunked_tokens_match_unchunked(self, model):
        """Streaming a prompt through the ingest path must produce the
        same greedy continuation as one full prefill."""
        cfg, params = model
        g = np.random.default_rng(1)
        long_ = g.integers(0, cfg.vocab_size, 21).astype(np.int32)

        def serve(chunk):
            eng = Engine(cfg, params, max_slots=2, max_len=40, sync_every=4,
                         prefill_chunk=chunk)
            eng.submit(Request(uid=0, prompt=long_.copy(), max_new_tokens=6))
            return eng.run()[0].out_tokens

        ref = serve(None)
        assert serve(4) == ref
        assert serve(7) == ref          # chunk not dividing the prompt

    def test_cap_length_prompt_chunked_matches_unchunked(self, model):
        """Regression: the ring-cap stop used to fire one step early on
        the ingest path — a max_len-1 prompt admitted chunked lost its
        final token vs the same prompt through one full prefill."""
        cfg, params = model
        g = np.random.default_rng(8)
        prompt = g.integers(0, cfg.vocab_size, 15).astype(np.int32)

        def serve(chunk):
            eng = Engine(cfg, params, max_slots=1, max_len=16,
                         prefill_chunk=chunk)
            eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=5))
            return eng.run()[0].out_tokens

        ref = serve(None)
        assert len(ref) == 2            # ring full after one decode write
        assert serve(4) == ref

    def test_sampled_stream_invariant_to_chunking(self, model):
        """Regression: the first generated token used to be the prefill
        argmax for unchunked admission but a sampler draw for chunked —
        a sampled request's stream must not depend on prefill_chunk or
        sync_every."""
        from repro.serving import SamplingParams
        cfg, params = model
        g = np.random.default_rng(7)
        long_ = g.integers(0, cfg.vocab_size, 18).astype(np.int32)
        sp = SamplingParams(temperature=0.9, top_k=64, seed=13)

        def serve(sync_every, chunk):
            eng = Engine(cfg, params, max_slots=2, max_len=40, sampling=sp,
                         sync_every=sync_every, prefill_chunk=chunk)
            eng.submit(Request(uid=0, prompt=long_.copy(), max_new_tokens=8))
            return eng.run()[0].out_tokens

        ref = serve(8, None)
        assert serve(8, 5) == ref
        assert serve(3, 4) == ref

    def test_chunk_boundary_cases(self, model):
        """chunk == len, chunk > len, chunk == 1 all serve correctly."""
        cfg, params = model
        g = np.random.default_rng(2)
        prompt = g.integers(0, cfg.vocab_size, 6).astype(np.int32)

        def serve(chunk):
            eng = Engine(cfg, params, max_slots=1, max_len=40,
                         prefill_chunk=chunk)
            eng.submit(Request(uid=0, prompt=prompt.copy(), max_new_tokens=4))
            return eng.run()[0].out_tokens

        ref = serve(None)
        assert serve(6) == ref
        assert serve(100) == ref
        assert serve(1) == ref


class TestFIFO:
    def test_admission_order_preserved_under_churn(self, model):
        """Requests with wildly different lengths/budgets must still be
        admitted strictly in submission order as slots free up."""
        cfg, params = model
        g = np.random.default_rng(3)
        eng = Engine(cfg, params, max_slots=2, max_len=40, sync_every=2)
        n = 7
        for i in range(n):
            plen = int(g.integers(3, 12))
            eng.submit(Request(
                uid=i, prompt=g.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=int(g.integers(2, 9))))
        done = eng.run()
        assert len(done) == n
        assert eng.scheduler.admitted_uids == list(range(n))

    def test_fifo_with_chunked_long_prompt_in_front(self, model):
        """A long chunked prompt at the head of the queue must not be
        overtaken at admission by later short requests."""
        cfg, params = model
        g = np.random.default_rng(4)
        eng = Engine(cfg, params, max_slots=1, max_len=40, sync_every=2,
                     prefill_chunk=4)
        eng.submit(Request(uid=0,
                           prompt=g.integers(0, cfg.vocab_size, 20).astype(np.int32),
                           max_new_tokens=3))
        eng.submit(Request(uid=1,
                           prompt=g.integers(0, cfg.vocab_size, 4).astype(np.int32),
                           max_new_tokens=3))
        done = eng.run()
        assert eng.scheduler.admitted_uids == [0, 1]
        assert len(done) == 2


class TestShardAwareWaves:
    """With the engine's cache pool slot-sharded over a mesh, slots
    [k*B/shards, (k+1)*B/shards) live on shard k: admission packs a wave
    into as few shard groups as possible (host-only bookkeeping — no
    devices involved)."""

    @staticmethod
    def _req(uid):
        return Request(uid=uid, prompt=np.array([1, 2, 3], np.int32))

    def _sched(self):
        return Scheduler(4, 32, slot_shards=2)

    def test_small_wave_packs_fullest_group(self):
        s = self._sched()
        s.slot_req[0] = self._req(99)       # group 0 has one free slot
        s.submit(self._req(0))
        assert [sl for sl, _ in s.take_wave()] == [1]

    def test_wave_prefers_single_group_best_fit(self):
        s = self._sched()
        s.slot_req[0] = self._req(99)       # group 0: [1]; group 1: [2, 3]
        s.submit(self._req(0))
        s.submit(self._req(1))
        assert [sl for sl, _ in s.take_wave()] == [2, 3]

    def test_spill_wave_spans_fewest_groups(self):
        s = self._sched()
        s.slot_req[0] = self._req(99)
        for i in range(3):
            s.submit(self._req(i))
        assert [sl for sl, _ in s.take_wave()] == [2, 3, 1]

    def test_fifo_order_of_requests_is_preserved(self):
        s = self._sched()
        s.slot_req[0] = self._req(99)
        s.submit(self._req(7))
        s.submit(self._req(8))
        wave = s.take_wave()
        assert [r.uid for _, r in wave] == [7, 8]
        assert s.admitted_uids == [7, 8]

    def test_single_shard_keeps_plain_order(self):
        s = Scheduler(4, 32)
        for i in range(3):
            s.submit(self._req(i))
        assert [sl for sl, _ in s.take_wave()] == [0, 1, 2]

    def test_indivisible_slot_shards_rejected(self):
        with pytest.raises(ValueError, match="slot_shards"):
            Scheduler(4, 32, slot_shards=3)


class TestStagedAdmission:
    """The staged set between queue and slots (the admission worker's
    input): ``take_staged`` commits to queue-head requests in FIFO
    order, ``place``/``place_wave`` bind them to slots later — and the
    head-of-line contract survives the indirection."""

    @staticmethod
    def _req(uid):
        return Request(uid=uid, prompt=np.array([1, 2, 3], np.int32))

    def test_take_staged_pops_queue_head_fifo(self):
        s = Scheduler(4, 32)
        for i in range(5):
            s.submit(self._req(i))
        got = s.take_staged(3)
        assert [r.uid for r in got] == [0, 1, 2]
        assert [r.uid for r in s.staged] == [0, 1, 2]
        assert [r.uid for r in s.queue] == [3, 4]
        assert s.queue_depth == 5            # staged still count as waiting
        assert s.has_work

    def test_place_binds_staged_head_and_frees_it(self):
        s = Scheduler(4, 32)
        s.submit(self._req(0))
        (req,) = s.take_staged(1)
        s.place(2, req)
        assert s.slot_req[2] is req
        assert not s.staged
        assert s.admitted_uids == [0]

    def test_place_out_of_staged_order_raises(self):
        s = Scheduler(4, 32)
        s.submit(self._req(0))
        s.submit(self._req(1))
        a, b = s.take_staged(2)
        with pytest.raises(RuntimeError, match="out of staged FIFO"):
            s.place(0, b)
        s.place(0, a)                        # head still placeable
        s.place(1, b)

    def test_place_into_occupied_slot_raises(self):
        s = Scheduler(4, 32)
        s.slot_req[1] = self._req(99)
        s.submit(self._req(0))
        (req,) = s.take_staged(1)
        with pytest.raises(RuntimeError, match="occupied"):
            s.place(1, req)

    def test_place_wave_is_shard_aware_like_take_wave(self):
        s = Scheduler(4, 32, slot_shards=2)
        s.slot_req[0] = self._req(99)        # group 0: [1]; group 1: [2, 3]
        for i in range(2):
            s.submit(self._req(i))
        reqs = s.take_staged(2)
        placed = s.place_wave(reqs)
        assert [sl for sl, _ in placed] == [2, 3]
        assert [r.uid for _, r in placed] == [0, 1]


class TestMetricsWindowBoundary:
    def test_metrics_consistent_between_windows(self, model):
        """Regression: occupancy/queue-depth counters must advance
        atomically with ``windows`` at each harvest, and the
        instantaneous values must come from the scheduler (host truth at
        the window boundary), never the device mirror's active flags —
        a request that finished inside the window is already retired
        when metrics() is called."""
        cfg, params = model
        g = np.random.default_rng(13)
        eng = Engine(cfg, params, max_slots=2, max_len=40, sync_every=4)
        eng.submit(Request(
            uid=0, prompt=g.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=2))
        eng.submit(Request(
            uid=1, prompt=g.integers(0, cfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=12))
        eng.step()
        m = eng.metrics()
        assert m["windows"] == 1
        # uid=0 finished inside window 1 and was retired at the harvest:
        # the snapshot reflects that, while the mean reflects the load
        # the window actually ran with
        assert m["occupancy"] == 1 and m["queue_depth"] == 0
        assert m["occupancy_mean"] == 2.0
        eng.step()
        m2 = eng.metrics()
        assert m2["windows"] == 2
        assert m2["occupancy_mean"] == pytest.approx(1.5)   # (2 + 1) / 2
        eng.run()
        mf = eng.metrics()
        assert mf["occupancy"] == 0 and mf["queue_depth"] == 0
        assert mf["host_syncs"] == mf["windows"] + mf["admission_syncs"]

    def test_mesh_field_reports_degenerate_mesh(self, model):
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        assert eng.metrics()["mesh"] == "1x1"

    def test_bare_step_calls_accrue_tokens_per_s(self, model):
        """Regression: _run_seconds only accrued inside run(), so callers
        driving step() directly (benches, external event loops) read
        tokens_per_s == 0.0 from metrics() despite real decoded work."""
        cfg, params = model
        g = np.random.default_rng(14)
        eng = Engine(cfg, params, max_slots=2, max_len=40, sync_every=4)
        eng.submit(Request(
            uid=0, prompt=g.integers(0, cfg.vocab_size, 5).astype(np.int32),
            max_new_tokens=6))
        while eng.scheduler.has_work:
            eng.step()
        m = eng.metrics()
        assert m["tokens"] > 0
        assert m["run_seconds"] > 0.0
        assert m["tokens_per_s"] > 0.0
        # run() stays additive on top of step()-accrued time
        before = m["run_seconds"]
        eng.run()
        assert eng.metrics()["run_seconds"] >= before


class TestSubmitValidation:
    def test_overlong_prompt_rejected_with_clear_message(self, model):
        """Regression: the seed engine crashed deep inside prefill when a
        prompt exceeded max_len; now submit() rejects it up front."""
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        prompt = np.arange(40, dtype=np.int32) % cfg.vocab_size
        with pytest.raises(ValueError, match=r"max_len"):
            eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=2))
        assert eng.unfinished == {"queued": 0, "in_flight": 0}

    def test_truncate_flag_keeps_tail_and_marks_request(self, model):
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        prompt = (np.arange(40, dtype=np.int32) % cfg.vocab_size)
        req = eng.submit(Request(uid=0, prompt=prompt.copy(),
                                 max_new_tokens=3, truncate=True))
        assert req.truncated
        np.testing.assert_array_equal(req.prompt, prompt[-15:])
        done = eng.run()
        assert len(done) == 1 and len(done[0].out_tokens) >= 1

    def test_exact_cap_prompt_is_accepted(self, model):
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        prompt = (np.arange(15, dtype=np.int32) % cfg.vocab_size)
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        done = eng.run()
        assert len(done) == 1

    def test_empty_prompt_rejected(self, model):
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        with pytest.raises(ValueError, match="empty"):
            eng.submit(Request(uid=0, prompt=np.zeros(0, np.int32)))

    @pytest.mark.parametrize("budget", [0, -3])
    def test_nonpositive_token_budget_rejected(self, model, budget):
        """Regression: submit() accepted max_new_tokens <= 0 but _admit
        still emitted the first sampled token (and left the budget at
        -1) — the request overshot a budget it declared as zero."""
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                               max_new_tokens=budget))
        assert eng.unfinished == {"queued": 0, "in_flight": 0}

    def test_min_budget_of_one_emits_exactly_one(self, model):
        cfg, params = model
        eng = Engine(cfg, params, max_slots=1, max_len=16)
        eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=1))
        done = eng.run()
        assert len(done) == 1 and len(done[0].out_tokens) == 1


class TestRunTimeout:
    def test_timeout_warns_and_reports_unfinished(self, model):
        """Regression: run(max_steps) used to return silently with work
        still queued/mid-flight — callers could not tell drain from
        timeout."""
        cfg, params = model
        g = np.random.default_rng(5)
        eng = Engine(cfg, params, max_slots=1, max_len=40, sync_every=1)
        for i in range(3):
            eng.submit(Request(
                uid=i, prompt=g.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new_tokens=8))
        with pytest.warns(RuntimeWarning, match="max_steps=1"):
            eng.run(max_steps=1)
        u = eng.unfinished
        assert u["queued"] == 2 and u["in_flight"] == 1

    def test_drain_does_not_warn(self, model):
        cfg, params = model
        g = np.random.default_rng(6)
        eng = Engine(cfg, params, max_slots=2, max_len=40)
        eng.submit(Request(uid=0,
                           prompt=g.integers(0, cfg.vocab_size, 5).astype(np.int32),
                           max_new_tokens=3))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            done = eng.run()
        assert not [w for w in caught if "max_steps" in str(w.message)]
        assert len(done) == 1
        assert eng.unfinished == {"queued": 0, "in_flight": 0}
