"""End-to-end system behaviour: train -> calibrate -> compress -> serve.

The full paper workflow on a unit-scale model, driven through the public
``repro.api`` surface: Algorithm 1 consumes a trained dense checkpoint and
emits a durable artifact whose latent-cache model (a) serves through the
engine straight from disk, (b) halves resident cache bytes, and (c) keeps
held-out quality close to dense.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CompressionSpec, RankPolicy, calibrate, compress,
                       save_artifact)
from repro.configs import get_config
from repro.data import DataConfig, batch as data_batch
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, train_loop
from repro.serving import Engine, Request


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(
        get_config("minicpm-2b", smoke=True), dtype=jnp.float32,
        scan_layers=False, remat=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, copy_frac=0.7)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in data_batch(dc, "train", step, 8).items()}
    out = train_loop(cfg, AdamWConfig(lr=2e-3),
                     TrainConfig(warmup_steps=5, total_steps=50),
                     batch_fn, logger=lambda *_: None)
    return cfg, out["params"], dc


@pytest.mark.slow
def test_full_workflow(trained, tmp_path):
    cfg, params, dc = trained
    batches = [{k: jnp.asarray(v) for k, v in data_batch(dc, "calib", s, 4).items()}
               for s in range(3)]
    calib = calibrate(cfg, params, batches, fisher=True)
    assert len(calib.fisher_k) == cfg.num_layers
    assert all(f > 0 for f in calib.fisher_k)

    spec = CompressionSpec(
        "recalkv",
        rank_policy=RankPolicy(keep_ratio=0.5, group_size=4, use_fisher=True))
    art = compress(cfg, params, spec, calib)
    ccfg, cparams = art.cfg, art.params
    assert art.provenance["calib_tokens"] == sum(
        int(b["tokens"].size) for b in batches)

    # (b) resident cache halves
    dense_cache = T.init_decode_cache(cfg, 2, 64)
    comp_cache = T.init_decode_cache(ccfg, 2, 64)
    size = lambda t: sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(t))
    assert size(comp_cache) < 0.62 * size(dense_cache)

    # (c) held-out quality near dense
    def eval_loss(cfg2, p2):
        b = {k: jnp.asarray(v) for k, v in data_batch(dc, "valid", 0, 8).items()}
        return float(T.loss_fn(cfg2, p2, b)[0])
    l_dense, l_comp = eval_loss(cfg, params), eval_loss(ccfg, cparams)
    assert l_comp < l_dense + 0.5, (l_dense, l_comp)

    # (a) serves through the engine, booting from the persisted artifact
    save_artifact(art, str(tmp_path / "artifact"))
    g = np.random.default_rng(0)
    eng = Engine.from_artifact(str(tmp_path / "artifact"),
                               max_slots=2, max_len=64)
    for i in range(3):
        eng.submit(Request(
            uid=i, prompt=g.integers(0, ccfg.vocab_size, 6).astype(np.int32),
            max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3 and all(len(r.out_tokens) == 4 for r in done)


@pytest.mark.slow
def test_compressed_greedy_continuations_track_dense(trained):
    """At 75% kept rank the compressed model's greedy continuations should
    mostly agree with the dense model (sanity on real information flow)."""
    cfg, params, dc = trained
    batches = [{k: jnp.asarray(v) for k, v in data_batch(dc, "calib", s, 4).items()}
               for s in range(2)]
    art = compress(cfg, params, CompressionSpec(
        "recalkv", rank_policy=RankPolicy(keep_ratio=0.75, group_size=4)),
        batches)
    ccfg, cparams = art.cfg, art.params

    g = np.random.default_rng(1)
    toks = jnp.asarray(g.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    agree = total = 0
    ref = None
    for cfg2, p2 in ((cfg, params), (ccfg, cparams)):
        lg, cache = T.prefill(cfg2, p2, toks, jnp.full((2,), 12), max_len=32)
        outs = [jnp.argmax(lg, -1)]
        for t in range(4):
            lg, cache = T.decode_step(cfg2, p2, cache,
                                      outs[-1].astype(jnp.int32),
                                      jnp.full((2,), 12 + t))
            outs.append(jnp.argmax(lg, -1))
        if ref is None:
            ref = outs
        else:
            for a, b in zip(ref, outs):
                agree += int((a == b).sum())
                total += a.size
    assert agree / total >= 0.5, f"only {agree}/{total} greedy tokens agree"
