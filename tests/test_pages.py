"""Unit + property tests for the paged latent KV pool allocator.

The property test drives random alloc / retain (share) / fork (COW) /
free sequences against a shadow model and checks the allocator's
invariants after every op: refcounts equal holder counts, used + free
always partitions the pool (minus the reserved null page), page 0 is
never handed out, and double-frees raise.  Runs under hypothesis when
installed, else a seeded numpy fallback driver exercises the same ops.
"""

import numpy as np
import pytest

from repro.serving.pages import (NULL_PAGE, PagePool, PrefixRegistry,
                                 prefix_key)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- basic allocator behavior -------------------------------------------------

def test_null_page_reserved():
    pool = PagePool(4)
    assert NULL_PAGE == 0
    got = pool.alloc(3)
    assert sorted(got) == [1, 2, 3]          # page 0 never allocated
    assert pool.free_count == 0


def test_alloc_exhaustion_raises_and_leaves_pool_intact():
    pool = PagePool(4)
    pool.alloc(2)
    with pytest.raises(RuntimeError):
        pool.alloc(2)                        # only 1 left
    assert pool.free_count == 1              # failed alloc took nothing


def test_retain_and_free_refcounting():
    pool = PagePool(8)
    (pg,) = pool.alloc(1)
    pool.retain(pg)
    pool.retain(pg)
    assert pool.refcount(pg) == 3
    assert pool.share_events == 2
    assert not pool.free(pg)                 # still held
    assert not pool.free(pg)
    assert pool.free(pg)                     # last holder -> released
    assert pool.free_count == 7


def test_double_free_raises():
    pool = PagePool(4)
    (pg,) = pool.alloc(1)
    assert pool.free(pg)
    with pytest.raises(ValueError):
        pool.free(pg)
    with pytest.raises(ValueError):
        pool.free(NULL_PAGE)                 # null page is never live
    with pytest.raises(ValueError):
        pool.free(99)                        # out of range


def test_fork_counts_and_swaps_pages():
    pool = PagePool(8)
    (pg,) = pool.alloc(1)
    pool.retain(pg)                          # two holders
    new = pool.fork(pg)                      # one holder diverges
    assert new != pg and pool.refcount(new) == 1
    assert pool.refcount(pg) == 1            # forker dropped its hold
    assert pool.cow_forks == 1


def test_peak_used_high_watermark():
    pool = PagePool(8)
    a = pool.alloc(5)
    for pg in a:
        pool.free(pg)
    assert pool.peak_used == 5
    assert pool.used == 0


def test_alloc_recycles_least_recently_freed_first():
    pool = PagePool(6)
    a, b, c = pool.alloc(3)                  # free list now [4, 5]
    pool.free(b)                             # [4, 5, b]
    pool.free(a)                             # [4, 5, b, a]
    # never-used pages are colder than anything freed after them
    assert pool.alloc(2) == [4, 5]
    # then the oldest free, NOT the most recently freed
    assert pool.alloc(1) == [b]
    assert pool.alloc(1) == [a]
    pool.free(c)


def test_resurrect_revives_free_page_and_counts():
    pool = PagePool(6)
    (pg,) = pool.alloc(1)
    pool.free(pg)
    assert pool.resurrect(pg) == pg
    assert pool.refcount(pg) == 1
    assert pool.prefix_resurrections == 1
    # a live page cannot be resurrected, only retained
    with pytest.raises(ValueError):
        pool.resurrect(pg)
    # a resurrected page behaves like any allocated page afterwards
    pool.retain(pg)
    assert not pool.free(pg)
    assert pool.free(pg)


def test_resurrect_pulls_from_middle_of_free_list():
    pool = PagePool(8)
    pages = pool.alloc(4)
    for pg in pages:
        pool.free(pg)                        # free order = pages order
    victim = pages[1]
    pool.resurrect(victim)
    # LRU recycling skips the resurrected page and keeps relative order
    rest = [pg for pg in [5, 6, 7] + pages if pg != victim]
    assert pool.alloc(len(rest)) == rest
    with pytest.raises(RuntimeError):
        pool.alloc(1)                        # victim is held, pool is dry


# -- pinning ------------------------------------------------------------------

def test_pinned_page_survives_alloc_flood():
    pool = PagePool(6)
    (pg,) = pool.alloc(1)
    pool.pin(pg)
    pool.free(pg)                            # refcount 0: parks, not freed
    assert pool.pinned == 1
    assert pool.is_pinned(pg)
    # a flood that drains the whole free list never recycles the pin
    flood = pool.alloc(pool.free_count)
    assert pg not in flood
    with pytest.raises(RuntimeError):
        pool.alloc(1)                        # dry, yet the pin still parked
    assert pool.resurrect(pg) == pg          # content stayed resident
    pool.free(pg)
    for p in flood:
        pool.free(p)


def test_pin_free_page_pulls_it_off_free_list():
    pool = PagePool(4)
    (pg,) = pool.alloc(1)
    pool.free(pg)                            # on the free list
    free_before = pool.free_count
    pool.pin(pg)                             # pin-after-free: parks it
    assert pool.free_count == free_before - 1
    assert pg not in pool.alloc(pool.free_count)


def test_unpin_returns_parked_page_to_free_list():
    pool = PagePool(4)
    (pg,) = pool.alloc(1)
    pool.pin(pg)
    pool.free(pg)
    free_before = pool.free_count
    pool.unpin(pg)
    assert pool.free_count == free_before + 1
    assert not pool.is_pinned(pg)
    assert pg in pool.alloc(pool.free_count)  # recyclable again


def test_unpin_live_page_keeps_it_allocated():
    pool = PagePool(4)
    (pg,) = pool.alloc(1)
    pool.pin(pg)
    pool.unpin(pg)                           # still refcount 1
    assert pool.refcount(pg) == 1
    assert pool.free(pg)                     # normal lifecycle afterwards


def test_pin_unpin_idempotent_and_range_checked():
    pool = PagePool(4)
    (pg,) = pool.alloc(1)
    pool.pin(pg)
    pool.pin(pg)
    assert pool.pinned == 1
    pool.unpin(pg)
    pool.unpin(pg)
    assert pool.pinned == 0
    with pytest.raises(ValueError):
        pool.pin(NULL_PAGE)
    with pytest.raises(ValueError):
        pool.pin(99)


def test_pinned_page_counts_stay_consistent():
    """A parked pinned page is resident, so it counts as used (it is off
    the free list) — used + free always partitions the allocatable pool,
    pins included."""
    pool = PagePool(8)
    pages = pool.alloc(3)
    pool.pin(pages[0])
    pool.free(pages[0])                      # parked: resident, not free
    assert pool.refcount(pages[0]) == 0
    assert pool.used == 3                    # 2 live + 1 parked
    assert pool.used + pool.free_count == pool.n_pages - 1
    pool.unpin(pages[0])                     # rejoins the free list
    assert pool.used == 2
    assert pool.used + pool.free_count == pool.n_pages - 1


# -- prefix registry ----------------------------------------------------------

def test_prefix_key_depends_on_full_prefix():
    p1 = np.array([1, 2, 3, 4, 5, 6], np.int32)
    p2 = np.array([9, 2, 3, 4, 5, 6], np.int32)
    # page 1's latent content depends on ALL tokens before it (attention),
    # so differing page-0 tokens must give page 1 different keys
    assert prefix_key(p1, 1, 2) != prefix_key(p2, 1, 2)
    assert prefix_key(p1, 0, 2) == prefix_key(p1[:4], 0, 2)


def test_registry_register_lookup_drop():
    reg = PrefixRegistry()
    p = np.array([1, 2, 3, 4], np.int32)
    k = prefix_key(p, 0, 2)
    assert reg.lookup(k) is None
    reg.register(k, 5)
    assert reg.lookup(k) == 5
    reg.register(k, 7)                       # idempotent: first wins
    assert reg.lookup(k) == 5
    reg.drop_page(5)
    assert reg.lookup(k) is None
    assert len(reg) == 0


# -- property test: random op sequences against a shadow model ---------------

def _check_invariants(pool: PagePool, holders: dict[int, int],
                      n_pages: int):
    live = {pg: n for pg, n in holders.items() if n > 0}
    for pg, n in live.items():
        assert pool.refcount(pg) == n, (pg, n)
    assert pool.used == len(live)
    assert pool.used + pool.free_count == n_pages - 1   # null page apart
    assert NULL_PAGE not in live
    pool.assert_consistent()


def _run_ops(n_pages: int, ops: list[tuple[int, int]]):
    """Interpret (op, arg) pairs against a PagePool + shadow holder map."""
    pool = PagePool(n_pages)
    holders: dict[int, int] = {}

    def live_pages():
        return sorted(pg for pg, n in holders.items() if n > 0)

    for op, arg in ops:
        live = live_pages()
        if op == 0:                                    # alloc k pages
            k = 1 + arg % 3
            if pool.can_alloc(k):
                for pg in pool.alloc(k):
                    assert pg != NULL_PAGE
                    assert holders.get(pg, 0) == 0     # was truly free
                    holders[pg] = 1
            else:
                with pytest.raises(RuntimeError):
                    pool.alloc(k)
        elif op == 1 and live:                         # retain (share)
            pg = live[arg % len(live)]
            pool.retain(pg)
            holders[pg] += 1
        elif op == 2 and live:                         # fork (COW)
            pg = live[arg % len(live)]
            if pool.can_alloc(1):
                new = pool.fork(pg)
                holders[pg] -= 1
                assert holders.get(new, 0) == 0
                holders[new] = 1
        elif op == 3 and live:                         # free one hold
            pg = live[arg % len(live)]
            released = pool.free(pg)
            holders[pg] -= 1
            assert released == (holders[pg] == 0)
        elif op == 4:                                  # double-free guard
            dead = [pg for pg, n in holders.items() if n == 0]
            if dead:
                with pytest.raises(ValueError):
                    pool.free(dead[arg % len(dead)])
        _check_invariants(pool, holders, n_pages)
    # drain: every release balances, nothing leaks
    for pg in live_pages():
        while holders[pg] > 0:
            released = pool.free(pg)
            holders[pg] -= 1
            assert released == (holders[pg] == 0)
    assert pool.used == 0
    assert pool.free_count == n_pages - 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(n_pages=hyp_st.integers(min_value=2, max_value=17),
           ops=hyp_st.lists(hyp_st.tuples(
               hyp_st.integers(min_value=0, max_value=4),
               hyp_st.integers(min_value=0, max_value=10 ** 6)),
               max_size=60))
    def test_pool_invariants_property(n_pages, ops):
        _run_ops(n_pages, ops)
else:
    @pytest.mark.parametrize("seed", range(30))
    def test_pool_invariants_property(seed):
        # hypothesis not installed: a seeded driver over the same op space
        r = np.random.RandomState(seed)
        n_pages = int(r.randint(2, 18))
        ops = [(int(r.randint(0, 5)), int(r.randint(0, 10 ** 6)))
               for _ in range(int(r.randint(5, 61)))]
        _run_ops(n_pages, ops)
