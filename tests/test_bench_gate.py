"""The serving perf-regression gate: row matching on (variant, backend,
mesh, spec_depth, draft, cache_layout, page_size, workload, overlap,
pipeline_depth, continuous), threshold
semantics, and the skip paths (no prior artifact / changed bench
identity) that keep CI bootstrappable."""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from check_serving_regression import compare_entries, main, row_key


def _entry(rows, arch="qwen3-4b", cfg=None):
    return {"arch": arch,
            "config": cfg or {"slots": 4, "max_len": 48},
            "rows": rows}


def _row(variant="latent", backend="einsum", mesh="1x1", tps=20.0, **kw):
    return {"variant": variant, "backend": backend, "mesh": mesh,
            "tokens_per_s": tps, **kw}


class TestCompareEntries:
    def test_no_regression_within_threshold(self):
        prev = _entry([_row(tps=20.0), _row(variant="dense", tps=10.0)])
        new = _entry([_row(tps=17.0), _row(variant="dense", tps=9.0)])
        rep = compare_entries(prev, new, threshold=0.2)
        assert rep["compared"] == 2
        assert rep["regressions"] == []

    def test_drop_past_threshold_fails(self):
        prev = _entry([_row(tps=20.0)])
        new = _entry([_row(tps=15.0)])          # -25%
        rep = compare_entries(prev, new, threshold=0.2)
        assert len(rep["regressions"]) == 1
        assert rep["regressions"][0]["row"] == "latent/einsum/1x1/-/-/ring/0/-/False/2/False/fifo/False"
        assert rep["regressions"][0]["drop"] == pytest.approx(0.25)

    def test_spec_rows_match_on_depth_and_draft(self):
        """A spec row only compares against the same (depth, draft) row —
        never against the unspeculated baseline."""
        prev = _entry([_row(tps=20.0),
                       _row(tps=5.0, spec_depth=2, draft="ngram")])
        new = _entry([_row(tps=20.0),
                      _row(tps=4.5, spec_depth=2, draft="ngram"),
                      _row(tps=1.0, spec_depth=2, draft="layers:2")])
        rep = compare_entries(prev, new, threshold=0.2)
        assert rep["compared"] == 2
        assert rep["regressions"] == []
        assert rep["only_new"] == ["latent/einsum/1x1/2/layers:2/ring/0/-/False/2/False/fifo/False"]

    def test_mesh_rows_distinct(self):
        prev = _entry([_row(mesh="1x1", tps=20.0),
                       _row(mesh="2x4", tps=4.0)])
        new = _entry([_row(mesh="1x1", tps=20.0),
                      _row(mesh="2x4", tps=3.0)])       # -25% on the mesh
        rep = compare_entries(prev, new)
        assert [r["row"] for r in rep["regressions"]] == \
            ["latent/einsum/2x4/-/-/ring/0/-/False/2/False/fifo/False"]

    def test_changed_bench_identity_skips(self):
        prev = _entry([_row(tps=20.0)])
        new = _entry([_row(tps=1.0)], cfg={"slots": 8, "max_len": 48})
        rep = compare_entries(prev, new)
        assert rep["skipped_reason"] is not None
        assert rep["regressions"] == []

    def test_row_key_ignores_measurements(self):
        a = _row(tps=20.0, tokens=96, bench_seconds=5.0)
        b = _row(tps=1.0)
        assert row_key(a) == row_key(b)

    def test_old_ring_rows_match_layoutless_baselines(self):
        """Rows written before cache_layout/page_size existed must keep
        matching today's ring rows, so old baselines stay comparable."""
        old = _row(tps=20.0)
        new = _row(tps=20.0, cache_layout="ring", page_size=0)
        assert row_key(old) == row_key(new)

    def test_overlap_rows_distinct_from_sync(self):
        """An overlapped-pipeline row is a new identity — its (much
        higher) throughput never compares against the sync baseline, and
        pre-overlap rows keep matching today's sync rows."""
        prev = _entry([_row(tps=20.0)])
        new = _entry([_row(tps=20.0),
                      _row(tps=120.0, overlap=True, aot=True)])
        rep = compare_entries(prev, new, threshold=0.2)
        assert rep["compared"] == 1
        assert rep["regressions"] == []
        assert rep["only_new"] == ["latent/einsum/1x1/-/-/ring/0/-/True/2/False/fifo/False"]

    def test_old_overlap_rows_match_depth2_baselines(self):
        """The classic double buffer IS pipeline_depth=2: rows written
        before the depth knob existed must keep matching today's
        explicit depth-2 rows, and non-continuous rows match rows
        predating the continuous flag."""
        old = _row(tps=20.0, overlap=True)
        new = _row(tps=20.0, overlap=True, pipeline_depth=2,
                   continuous=False)
        assert row_key(old) == row_key(new)

    def test_depth3_and_continuous_rows_are_new_identities(self):
        """A deeper pipeline or the mid-window slot swap changes what is
        being measured — those rows never compare against the depth-2
        boundary-only baseline."""
        prev = _entry([_row(tps=100.0, overlap=True)])
        new = _entry([_row(tps=100.0, overlap=True),
                      _row(tps=40.0, overlap=True, pipeline_depth=3),
                      _row(tps=40.0, overlap=True, pipeline_depth=3,
                           continuous=True)])
        rep = compare_entries(prev, new, threshold=0.2)
        assert rep["compared"] == 1
        assert rep["regressions"] == []
        assert rep["only_new"] == [
            "latent/einsum/1x1/-/-/ring/0/-/True/3/False/fifo/False",
            "latent/einsum/1x1/-/-/ring/0/-/True/3/True/fifo/False"]

    def test_paged_rows_distinct_from_ring(self):
        prev = _entry([_row(tps=20.0)])
        new = _entry([_row(tps=20.0),
                      _row(tps=1.0, cache_layout="paged", page_size=8)])
        rep = compare_entries(prev, new, threshold=0.2)
        assert rep["regressions"] == []
        assert rep["only_new"] == ["latent/einsum/1x1/-/-/paged/8/-/False/2/False/fifo/False"]


class TestMainCLI:
    def test_missing_prev_artifact_skips(self, tmp_path):
        new = tmp_path / "new.json"
        new.write_text(json.dumps([_entry([_row()])]))
        rc = main(["--prev", str(tmp_path / "absent.json"),
                   "--new", str(new)])
        assert rc == 0

    def test_regression_exits_nonzero(self, tmp_path):
        prev = tmp_path / "prev.json"
        new = tmp_path / "new.json"
        prev.write_text(json.dumps([_entry([_row(tps=20.0)])]))
        new.write_text(json.dumps([_entry([_row(tps=10.0)])]))
        assert main(["--prev", str(prev), "--new", str(new)]) == 1
        # a looser threshold tolerates the same drop
        assert main(["--prev", str(prev), "--new", str(new),
                     "--threshold", "0.6"]) == 0

    def test_compares_latest_entries_only(self, tmp_path):
        """Trajectories accumulate one entry per run; the gate compares
        last-vs-last, so an ancient fast entry cannot fail today's run."""
        prev = tmp_path / "prev.json"
        new = tmp_path / "new.json"
        prev.write_text(json.dumps([_entry([_row(tps=100.0)]),
                                    _entry([_row(tps=10.0)])]))
        new.write_text(json.dumps([_entry([_row(tps=9.5)])]))
        assert main(["--prev", str(prev), "--new", str(new)]) == 0
