import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibrate, svd


@pytest.fixture
def rng():
    return np.random.default_rng(3)


def setup(rng, m=24, n=32, N=500, subspace=6):
    W = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    basis = rng.normal(size=(subspace, m))
    X = jnp.asarray(rng.normal(size=(N, subspace)) @ basis
                    + 0.1 * rng.normal(size=(N, m)), jnp.float32)
    return W, X, X.T @ X


class TestCalibration:
    def test_error_monotonically_decreases(self, rng):
        W, X, C = setup(rng)
        init = svd.truncated_svd(W, 8)
        res = calibrate.calibrate_factors(W, C, init, num_iters=6)
        errs = list(res.errors)
        assert all(a >= b - 1e-2 for a, b in zip(errs, errs[1:])), errs

    def test_beats_plain_svd_on_data(self, rng):
        """The paper's core claim for OCMF: calibrated factors have lower
        data-weighted error than plain truncated SVD (eq. 6)."""
        W, X, C = setup(rng)
        init = svd.truncated_svd(W, 8)
        res = calibrate.calibrate_factors(W, C, init)
        e_plain = float(calibrate.weighted_error(W, init.L, init.R, C))
        assert float(res.final_error) <= e_plain
        # strictly better when data is anisotropic
        assert float(res.final_error) < 0.999 * e_plain

    def test_matches_whitened_svd_quality(self, rng):
        """ALS from a plain-SVD start should approach whitened-SVD quality
        (both minimize the same objective; whitened SVD is the global opt
        of the rank constraint)."""
        W, X, C = setup(rng)
        res = calibrate.calibrate_factors(W, C, svd.truncated_svd(W, 8),
                                          num_iters=16)
        ew = float(svd.data_weighted_error(W, svd.whitened_svd(W, C, 8), C))
        assert float(res.final_error) <= ew * 1.05

    def test_full_rank_is_exact(self, rng):
        W, X, C = setup(rng, m=12, n=12)
        res = calibrate.calibrate_factors(W, C, svd.truncated_svd(W, 12))
        assert float(res.final_error) < 1e-3

    def test_rank_deficient_cov_is_stable(self, rng):
        """Ridge keeps the normal equations solvable when N < m."""
        W = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(5, 24)), jnp.float32)  # rank 5
        res = calibrate.calibrate_factors(W, X.T @ X, svd.truncated_svd(W, 8))
        assert np.isfinite(float(res.final_error))
